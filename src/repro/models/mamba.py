"""Selective SSM (Mamba-1) block for the Jamba hybrid architecture.

Full-sequence mode runs a chunked selective scan: an outer ``lax.scan`` over
sequence chunks (rematerialised for the backward pass) with a sequential
inner scan — the carried state is only (B, d_inner, d_state), so activation
memory is O(n_chunks) not O(seq).  Decode mode is a single recurrence step.
The TPU hot-loop version lives in ``repro/kernels/mamba``.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.sharding.spec import Param, param, shard_act

SCAN_CHUNK = 256


def _dims(cfg):
    d_inner = cfg.mamba.expand * cfg.d_model
    dt_rank = cfg.mamba.dt_rank or max(cfg.d_model // 16, 1)
    return d_inner, dt_rank, cfg.mamba.d_state, cfg.mamba.d_conv


def init_mamba(key, cfg):
    d_inner, dt_rank, d_state, d_conv = _dims(cfg)
    ks = jax.random.split(key, 6)
    # S4D-real initialisation for A
    a = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32)[None, :],
                 (d_inner, 1))
    return {
        "in_x": param(ks[0], (cfg.d_model, d_inner), ("embed", "mamba")),
        "in_z": param(ks[1], (cfg.d_model, d_inner), ("embed", "mamba")),
        "conv_w": param(ks[2], (d_conv, d_inner), (None, "mamba"),
                        scale=1.0 / math.sqrt(d_conv)),
        "conv_b": Param(jnp.zeros((d_inner,)), ("mamba",)),
        "x_proj": param(ks[3], (d_inner, dt_rank + 2 * d_state),
                        ("mamba", None)),
        "dt_w": param(ks[4], (dt_rank, d_inner), (None, "mamba"),
                      scale=dt_rank ** -0.5),
        "dt_b": Param(jnp.full((d_inner,), -4.6), ("mamba",)),  # softplus≈0.01
        "A_log": Param(jnp.log(a), ("mamba", None)),
        "D": Param(jnp.ones((d_inner,)), ("mamba",)),
        "out": param(ks[5], (d_inner, cfg.d_model), ("mamba", "embed"),
                     scale=1.0 / math.sqrt(d_inner)),
    }


def _ssm_inputs(p, cfg, xh):
    """xh: (B, T, d_inner) post-conv -> (dt, B_t, C_t)."""
    _, dt_rank, d_state, _ = _dims(cfg)
    proj = jnp.einsum("btd,dk->btk", xh, p["x_proj"].astype(xh.dtype))
    dt_low, b_t, c_t = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("btr,rd->btd", dt_low, p["dt_w"].astype(xh.dtype))
        .astype(jnp.float32) + p["dt_b"])
    return dt, b_t.astype(jnp.float32), c_t.astype(jnp.float32)


def _scan_chunk(a_log, dt, b_t, c_t, xh, h0):
    """Sequential selective scan over one chunk.

    dt: (B,T,di) f32; b_t/c_t: (B,T,ds); xh: (B,T,di); h0: (B,di,ds) f32.
    Returns (y (B,T,di) f32, hT).
    """
    a = -jnp.exp(a_log)                                   # (di, ds)

    def step(h, inp):
        dt_t, b_tt, c_tt, x_tt = inp                      # (B,di),(B,ds),(B,ds),(B,di)
        da = jnp.exp(dt_t[:, :, None] * a[None])          # (B,di,ds)
        dbx = (dt_t * x_tt)[:, :, None] * b_tt[:, None, :]
        h = da * h + dbx
        y = jnp.einsum("bds,bs->bd", h, c_tt)
        return h, y

    xs = (dt.transpose(1, 0, 2), b_t.transpose(1, 0, 2),
          c_t.transpose(1, 0, 2), xh.astype(jnp.float32).transpose(1, 0, 2))
    hT, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2), hT


def mamba_forward(p, cfg, x, *, state=None):
    """Full-sequence forward.  x: (B, S, D).

    Returns (y, final_state) where state = (ssm_h, conv_tail):
      ssm_h (B, d_inner, d_state) f32, conv_tail (B, d_conv-1, d_inner).
    """
    d_inner, _, d_state, d_conv = _dims(cfg)
    b, s, _ = x.shape
    xz = jnp.einsum("bsd,di->bsi", x, p["in_x"].astype(x.dtype))
    z = jnp.einsum("bsd,di->bsi", x, p["in_z"].astype(x.dtype))
    xz = shard_act(xz, "batch", "seq", "mamba")
    z = shard_act(z, "batch", "seq", "mamba")

    # depthwise causal conv over seq
    if state is not None:
        tail = state[1].astype(xz.dtype)
    else:
        tail = jnp.zeros((b, d_conv - 1, d_inner), xz.dtype)
    xp = jnp.concatenate([tail, xz], axis=1)
    conv_w = p["conv_w"].astype(xz.dtype)
    xh = sum(xp[:, i:i + s, :] * conv_w[i][None, None, :]
             for i in range(d_conv))
    xh = jax.nn.silu(xh + p["conv_b"].astype(xz.dtype))

    dt, b_t, c_t = _ssm_inputs(p, cfg, xh)
    h0 = (state[0] if state is not None
          else jnp.zeros((b, d_inner, d_state), jnp.float32))

    from repro.models import flags
    chunk = min(SCAN_CHUNK, s)
    if s % chunk == 0 and s > chunk and not flags.scan_unroll:
        n = s // chunk

        def body(h, inp):
            dt_c, b_c, c_c, xh_c = inp
            y, h = jax.checkpoint(
                partial(_scan_chunk, p["A_log"]))(dt_c, b_c, c_c, xh_c, h)
            return h, y

        resh = lambda t: t.reshape(b, n, chunk, t.shape[-1]).transpose(1, 0, 2, 3)
        hT, ys = jax.lax.scan(body, h0, (resh(dt), resh(b_t), resh(c_t),
                                         resh(xh)))
        y = ys.transpose(1, 0, 2, 3).reshape(b, s, d_inner)
    else:
        y, hT = _scan_chunk(p["A_log"], dt, b_t, c_t, xh, h0)

    y = (y + xh.astype(jnp.float32) * p["D"][None, None, :]).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = shard_act(y, "batch", "seq", "mamba")
    out = jnp.einsum("bsi,id->bsd", y, p["out"].astype(x.dtype))
    new_state = (hT, xp[:, -(d_conv - 1):, :] if d_conv > 1
                 else jnp.zeros((b, 0, d_inner), xz.dtype))
    return shard_act(out, "batch", "seq", None), new_state


def mamba_decode_step(p, cfg, x, state):
    """Single-token decode.  x: (B, 1, D); state as in mamba_forward."""
    y, new_state = mamba_forward(p, cfg, x, state=state)
    return y, new_state


def mamba_init_state(cfg, batch: int, dtype=jnp.bfloat16):
    d_inner, _, d_state, d_conv = _dims(cfg)
    return (jnp.zeros((batch, d_inner, d_state), jnp.float32),
            jnp.zeros((batch, d_conv - 1, d_inner), dtype))
