"""Shared transformer building blocks: norms, RoPE, GQA attention, MLPs.

Pure-functional: every block is ``init_*(key, cfg) -> Param pytree`` plus an
apply function taking the plain-value pytree.  Activation shardings are
expressed with logical axes via ``shard_act`` (no-ops off-mesh).

Attention is exact but *query-chunked* for long sequences so scores never
materialise more than (B, H, chunk, S) at once — the XLA-path analogue of the
flash kernel in ``repro/kernels/flash_attention``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.sharding.spec import Param, param, shard_act

ATTN_QUERY_CHUNK = 1024  # max query block for chunked attention


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(cfg, d: int | None = None):
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": Param(jnp.ones((d,), jnp.float32), (None,))}
    return {
        "scale": Param(jnp.ones((d,), jnp.float32), (None,)),
        "bias": Param(jnp.zeros((d,), jnp.float32), (None,)),
    }


def apply_norm(p, cfg, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (xf * p["scale"]).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf * p["scale"] + p["bias"]).astype(x.dtype)


def _rms_head(x, scale, eps: float = 1e-6):
    """Per-head RMS norm (qk_norm) over the trailing head_dim."""
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (xf * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# Positional encodings
# ---------------------------------------------------------------------------


def rope_cos_sin(positions, head_dim: int, theta: float):
    """positions: (S,) int -> cos,sin of shape (S, head_dim/2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, N, hd); cos/sin: (S, hd/2)."""
    half = x.shape[-1] // 2
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    return jnp.concatenate(
        [x1 * c - x2 * s, x2 * c + x1 * s], axis=-1
    ).astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int):
    """Whisper-style fixed sinusoidal position embedding (S, D)."""
    half = d_model // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = jnp.arange(seq_len)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def init_attention(key, cfg, *, cross: bool = False):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": param(ks[0], (cfg.d_model, cfg.num_heads, hd),
                    ("embed", "heads", None)),
        "wk": param(ks[1], (cfg.d_model, cfg.num_kv_heads, hd),
                    ("embed", "kv_heads", None)),
        "wv": param(ks[2], (cfg.d_model, cfg.num_kv_heads, hd),
                    ("embed", "kv_heads", None)),
        "wo": param(ks[3], (cfg.num_heads, hd, cfg.d_model),
                    ("heads", None, "embed"),
                    scale=1.0 / math.sqrt(cfg.num_heads * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = Param(jnp.zeros((cfg.num_heads, hd)), ("heads", None))
        p["bk"] = Param(jnp.zeros((cfg.num_kv_heads, hd)), ("kv_heads", None))
        p["bv"] = Param(jnp.zeros((cfg.num_kv_heads, hd)), ("kv_heads", None))
    if cfg.qk_norm and not cross:
        p["q_norm"] = Param(jnp.ones((hd,)), (None,))
        p["k_norm"] = Param(jnp.ones((hd,)), (None,))
    return p


def _proj_qkv(p, cfg, x, kv_input):
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dnh->bsnh", kv_input, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dnh->bsnh", kv_input, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    if "q_norm" in p:
        q = _rms_head(q, p["q_norm"])
        k = _rms_head(k, p["k_norm"])
    return q, k, v


def _repeat_kv(k, num_heads: int):
    """(B, S, Kv, hd) -> (B, S, H, hd) by group broadcast."""
    b, s, kv, hd = k.shape
    if kv == num_heads:
        return k
    g = num_heads // kv
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, g, hd))
    return k.reshape(b, s, num_heads, hd)


def _attend(q, k, v, mask, scale: float):
    """q: (B,Q,H,hd), k/v: (B,S,H,hd), mask: (Q,S) | (B,Q,S) | None."""
    scores = jnp.einsum("bqhe,bshe->bhqs", q, k).astype(jnp.float32) * scale
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None, None]            # (1,1,Q,S)
        else:
            mask = mask[:, None]               # (B,1,Q,S)
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqs,bshe->bqhe", w.astype(v.dtype), v)


def _attend_grouped(q, k, v, mask, scale: float):
    """GQA attention without materialising the KV-head repeat — the decode
    path, where cache traffic dominates.  q: (B,Q,H,hd), k/v: (B,S,Kv,hd),
    mask: (Q,S) | None."""
    b, qlen, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, qlen, kv, g, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    scores = scores * scale
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(v.dtype), v)
    return out.reshape(b, qlen, h, hd)


def _chunked_attend(q, k, v, scale, *, q_positions, kv_positions,
                    causal: bool, window: int):
    """Exact attention streamed over query chunks (bounded scores memory).

    With a sliding window, each query chunk attends only to a *sliced*
    (window + chunk)-sized KV segment — masking alone would still compute
    the full S² scores (measured: zero FLOP/byte effect; §Perf minitron
    iteration), whereas slicing makes windowed prefill cost
    O(S·(W+C)) instead of O(S²).
    """
    from repro.models import flags

    b, qlen, h, hd = q.shape
    s_kv = k.shape[1]

    def mask_for(qpos, kpos):
        m = (kpos >= 0)[None, :]
        if causal:
            m = m & (kpos[None, :] <= qpos[:, None])
        if window:
            m = m & (kpos[None, :] > qpos[:, None] - window)
        return m  # (chunk, S_slice)

    chunk = min(ATTN_QUERY_CHUNK, qlen)
    windowed = bool(window) and causal and s_kv > window + chunk
    if qlen % chunk != 0 or (qlen == chunk and not windowed):
        return _attend(q, k, v, mask_for(q_positions, kv_positions), scale)

    n = qlen // chunk
    qc = q.reshape(b, n, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    pc = q_positions.reshape(n, chunk)
    unroll = {"unroll": True} if flags.scan_unroll else {}

    if windowed:
        seg = window + chunk  # KV segment a chunk can see

        def body(_, xs):
            qi, pi = xs
            start = jnp.clip(pi[0] - window, 0, s_kv - seg)
            ki = jax.lax.dynamic_slice_in_dim(k, start, seg, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v, start, seg, axis=1)
            kpi = jax.lax.dynamic_slice_in_dim(kv_positions, start, seg)
            return None, _attend(qi, ki, vi, mask_for(pi, kpi), scale)

        _, out = jax.lax.scan(body, None, (qc, pc), **unroll)
        return out.transpose(1, 0, 2, 3, 4).reshape(b, qlen, h, hd)

    def body(_, xs):
        qi, pi = xs
        return None, _attend(qi, k, v, mask_for(pi, kv_positions), scale)

    _, out = jax.lax.scan(body, None, (qc, pc), **unroll)
    return out.transpose(1, 0, 2, 3, 4).reshape(b, qlen, h, hd)


def attention(p, cfg, x, *, positions, causal: bool = True, window: int = 0,
              encoder_out=None, cache=None, cache_index=None,
              use_rope: bool = True):
    """Multi-head GQA attention.

    Modes:
      * full-sequence (train / prefill / encoder): ``cache is None``;
        ``positions`` is (S,) absolute positions.  Returns (out, (k, v)).
      * self-attn decode: ``cache = (k, v, kv_pos)`` with k/v
        (B, S_cache, Kv, hd) and kv_pos (S_cache,) absolute positions
        (-1 = empty slot).  x is (B, 1, D); ``cache_index`` is the new
        token's absolute position.  RoPE is applied *before* caching so a
        ring buffer (sliding window) stays correct.
      * cross-attn decode: ``cache = (k, v)`` precomputed from encoder.
    """
    hd = cfg.resolved_head_dim
    scale = 1.0 / math.sqrt(hd)
    kv_input = encoder_out if encoder_out is not None else x
    q, k, v = _proj_qkv(p, cfg, x, kv_input)

    is_cross = encoder_out is not None or (
        cache is not None and len(cache) == 2)

    if is_cross and cache is not None:
        ck, cv = cache
        out = _attend(q, _repeat_kv(ck, cfg.num_heads),
                      _repeat_kv(cv, cfg.num_heads), None, scale)
        y = jnp.einsum("bqhe,hed->bqd", out, p["wo"].astype(x.dtype))
        return shard_act(y, "batch", "seq", None), cache

    if cache is not None:
        ck, cv, kv_pos = cache
        s_cache = ck.shape[1]
        if use_rope and not is_cross:
            pos1 = jnp.full((1,), cache_index, jnp.int32)
            cos, sin = rope_cos_sin(pos1, hd, cfg.rope_theta)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
        slot = cache_index % s_cache if window else jnp.minimum(
            cache_index, s_cache - 1)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, slot, 0, 0))
        kv_pos = jax.lax.dynamic_update_slice(
            kv_pos, jnp.full((1,), cache_index, jnp.int32), (slot,))
        ck = shard_act(ck, "batch", "kv_seq", "kv_heads", None)
        cv = shard_act(cv, "batch", "kv_seq", "kv_heads", None)
        m = (kv_pos >= 0) & (kv_pos <= cache_index)
        if window:
            m = m & (kv_pos > cache_index - window)
        out = _attend_grouped(
            q, ck.astype(q.dtype), cv.astype(q.dtype),
            m[None, :] * jnp.ones((q.shape[1], 1), bool), scale)
        y = jnp.einsum("bqhe,hed->bqd", out, p["wo"].astype(x.dtype))
        return shard_act(y, "batch", "seq", None), (ck, cv, kv_pos)

    # full-sequence path
    if use_rope and not is_cross:
        cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = shard_act(q, "batch", "seq", "heads", None)
    k = shard_act(k, "batch", "seq", "kv_heads", None)
    v = shard_act(v, "batch", "seq", "kv_heads", None)
    if is_cross:
        kv_positions = jnp.arange(kv_input.shape[1], dtype=jnp.int32)
        causal = False
    else:
        kv_positions = positions
    out = _chunked_attend(q, _repeat_kv(k, cfg.num_heads),
                          _repeat_kv(v, cfg.num_heads), scale,
                          q_positions=positions, kv_positions=kv_positions,
                          causal=causal, window=window)
    out = shard_act(out, "batch", "seq", "heads", None)
    y = jnp.einsum("bqhe,hed->bqd", out, p["wo"].astype(x.dtype))
    return shard_act(y, "batch", "seq", None), (k, v)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg, d_ff: int | None = None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_up": param(ks[0], (cfg.d_model, d_ff), ("embed", "mlp")),
        "w_down": param(ks[1], (d_ff, cfg.d_model), ("mlp", "embed"),
                        scale=1.0 / math.sqrt(d_ff)),
    }
    if cfg.act == "swiglu":
        p["w_gate"] = param(ks[2], (cfg.d_model, d_ff), ("embed", "mlp"))
    return p


def mlp_act(cfg, up, gate=None):
    if cfg.act == "swiglu":
        return jax.nn.silu(gate) * up
    if cfg.act == "gelu":
        return jax.nn.gelu(up)
    if cfg.act == "relu2":
        return jnp.square(jax.nn.relu(up))
    raise ValueError(cfg.act)


def apply_mlp(p, cfg, x):
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    g = (jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
         if cfg.act == "swiglu" else None)
    h = mlp_act(cfg, h, g)
    h = shard_act(h, "batch", "seq", "mlp")
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
    return shard_act(y, "batch", "seq", None)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def init_embedding(key, cfg):
    return {
        "table": param(key, (cfg.padded_vocab, cfg.d_model),
                       ("vocab", "embed"), scale=0.02),
    }


def embed_tokens(p, cfg, tokens, dtype):
    y = jnp.take(p["table"], tokens, axis=0).astype(dtype)
    return shard_act(y, "batch", "seq", None)


def init_lm_head(key, cfg):
    if cfg.tie_embeddings:
        return {}
    return {
        "w": param(key, (cfg.d_model, cfg.padded_vocab),
                   ("head_embed", "head_vocab"),
                   scale=1.0 / math.sqrt(cfg.d_model)),
    }


def lm_logits(head_p, embed_p, cfg, x):
    if cfg.tie_embeddings:
        w = embed_p["table"].astype(x.dtype)
        logits = jnp.einsum("bsd,vd->bsv", x, w)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, head_p["w"].astype(x.dtype))
    return shard_act(logits, "batch", "seq", "head_vocab")
