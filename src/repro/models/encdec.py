"""Whisper-style encoder-decoder backbone.

The mel-spectrogram + conv feature extractor is STUBBED per the assignment:
``frames`` arrive as precomputed (B, T_enc, d_model) embeddings.  Positions
are fixed sinusoids (Whisper uses no RoPE).  Decode uses a self-attention
ring cache plus per-layer precomputed cross-attention K/V.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import flags, layers as L
from repro.models.transformer import stack_layer_axes
from repro.sharding.spec import shard_act


def _init_enc_block(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": L.init_norm(cfg),
        "attn": L.init_attention(k1, cfg),
        "ffn_norm": L.init_norm(cfg),
        "mlp": L.init_mlp(k2, cfg),
    }


def _init_dec_block(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "attn_norm": L.init_norm(cfg),
        "attn": L.init_attention(k1, cfg),
        "cross_norm": L.init_norm(cfg),
        "cross": L.init_attention(k2, cfg, cross=True),
        "ffn_norm": L.init_norm(cfg),
        "mlp": L.init_mlp(k3, cfg),
    }


def init_model(key, cfg):
    ks = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: _init_enc_block(k, cfg))(
        jax.random.split(ks[0], cfg.encoder_layers))
    dec = jax.vmap(lambda k: _init_dec_block(k, cfg))(
        jax.random.split(ks[1], cfg.num_layers))
    return {
        "embed": L.init_embedding(ks[2], cfg),
        "enc_blocks": stack_layer_axes(enc),
        "enc_norm": L.init_norm(cfg),
        "dec_blocks": stack_layer_axes(dec),
        "final_norm": L.init_norm(cfg),
        "head": L.init_lm_head(ks[3], cfg),
    }


def encode(params, cfg, frames, *, dtype=jnp.bfloat16):
    """frames: (B, T_enc, D) stub embeddings -> (B, T_enc, D)."""
    t = frames.shape[1]
    x = frames.astype(dtype) + L.sinusoidal_positions(
        t, cfg.d_model).astype(dtype)[None]
    x = shard_act(x, "batch", "seq", None)
    positions = jnp.arange(t, dtype=jnp.int32)

    def body(x, bp):
        h, _ = L.attention(bp["attn"], cfg,
                           L.apply_norm(bp["attn_norm"], cfg, x),
                           positions=positions, causal=False, use_rope=False)
        x = x + h
        x = x + L.apply_mlp(bp["mlp"], cfg,
                            L.apply_norm(bp["ffn_norm"], cfg, x))
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"],
                        **flags.scan_kwargs())
    return L.apply_norm(params["enc_norm"], cfg, x)


def _dec_block(bp, cfg, x, enc_out, *, positions, cache=None,
               cross_cache=None, cache_index=None):
    h, new_cache = L.attention(
        bp["attn"], cfg, L.apply_norm(bp["attn_norm"], cfg, x),
        positions=positions, causal=True, use_rope=False, cache=cache,
        cache_index=cache_index)
    x = x + h
    h, _ = L.attention(
        bp["cross"], cfg, L.apply_norm(bp["cross_norm"], cfg, x),
        positions=positions, encoder_out=enc_out, cache=cross_cache,
        use_rope=False)
    x = x + h
    x = x + L.apply_mlp(bp["mlp"], cfg, L.apply_norm(bp["ffn_norm"], cfg, x))
    return x, new_cache


def forward_train(params, cfg, tokens, *, frames, dtype=jnp.bfloat16,
                  remat=True, window=None, compute_logits=True):
    enc_out = encode(params, cfg, frames, dtype=dtype)
    s = tokens.shape[1]
    x = L.embed_tokens(params["embed"], cfg, tokens, dtype)
    x = x + L.sinusoidal_positions(s, cfg.d_model).astype(dtype)[None]
    positions = jnp.arange(s, dtype=jnp.int32)

    def body(x, bp):
        x, _ = _dec_block(bp, cfg, x, enc_out, positions=positions)
        return x, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_blocks"],
                        **flags.scan_kwargs())
    x = L.apply_norm(params["final_norm"], cfg, x)
    logits = (L.lm_logits(params["head"], params["embed"], cfg, x)
              if compute_logits else None)
    return logits, jnp.float32(0.0), x


def init_cache(cfg, batch: int, cache_len: int, *, window=None,
               dtype=jnp.bfloat16):
    window = cfg.sliding_window if window is None else window
    size = min(window, cache_len) if window else cache_len
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    lyr = cfg.num_layers
    return {
        "k": jnp.zeros((lyr, batch, size, kv, hd), dtype),
        "v": jnp.zeros((lyr, batch, size, kv, hd), dtype),
        "pos": jnp.full((lyr, size), -1, jnp.int32),
        "cross_k": jnp.zeros((lyr, batch, cfg.encoder_seq_len, kv, hd),
                             dtype),
        "cross_v": jnp.zeros((lyr, batch, cfg.encoder_seq_len, kv, hd),
                             dtype),
    }


def prefill(params, cfg, tokens, *, frames, dtype=jnp.bfloat16, window=None,
            cache_len=None):
    """Encode audio, run the decoder prompt, build self+cross caches."""
    window = cfg.sliding_window if window is None else window
    enc_out = encode(params, cfg, frames, dtype=dtype)
    b, s = tokens.shape
    cache_len = cache_len or s
    size = min(window, cache_len) if window else cache_len
    x = L.embed_tokens(params["embed"], cfg, tokens, dtype)
    x = x + L.sinusoidal_positions(s, cfg.d_model).astype(dtype)[None]
    positions = jnp.arange(s, dtype=jnp.int32)

    def body(x, bp):
        xn = L.apply_norm(bp["attn_norm"], cfg, x)
        h, (k, v) = L.attention(bp["attn"], cfg, xn, positions=positions,
                                causal=True, use_rope=False)
        x = x + h
        # precompute this layer's cross K/V from encoder output
        ck = jnp.einsum("btd,dnh->btnh", enc_out,
                        bp["cross"]["wk"].astype(dtype))
        cv = jnp.einsum("btd,dnh->btnh", enc_out,
                        bp["cross"]["wv"].astype(dtype))
        if "bk" in bp["cross"]:
            ck = ck + bp["cross"]["bk"].astype(dtype)
            cv = cv + bp["cross"]["bv"].astype(dtype)
        h, _ = L.attention(bp["cross"], cfg,
                           L.apply_norm(bp["cross_norm"], cfg, x),
                           positions=positions, encoder_out=enc_out,
                           use_rope=False)
        x = x + h
        x = x + L.apply_mlp(bp["mlp"], cfg,
                            L.apply_norm(bp["ffn_norm"], cfg, x))
        if size < s:
            keep = positions[s - size:]
            slots = keep % size
            sk = jnp.zeros((b, size) + k.shape[2:], dtype).at[:, slots].set(
                k[:, s - size:].astype(dtype))
            sv = jnp.zeros((b, size) + v.shape[2:], dtype).at[:, slots].set(
                v[:, s - size:].astype(dtype))
            spos = jnp.full((size,), -1, jnp.int32).at[slots].set(keep)
        else:
            pad = size - s
            sk = jnp.pad(k.astype(dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
            sv = jnp.pad(v.astype(dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
            spos = jnp.concatenate([positions,
                                    jnp.full((pad,), -1, jnp.int32)])
        return x, {"k": sk, "v": sv, "pos": spos,
                   "cross_k": ck.astype(dtype), "cross_v": cv.astype(dtype)}

    x, cache = jax.lax.scan(body, x, params["dec_blocks"],
                            **flags.scan_kwargs())
    x = L.apply_norm(params["final_norm"], cfg, x)
    logits = L.lm_logits(params["head"], params["embed"], cfg, x[:, -1:])
    return logits, cache


def decode_step(params, cfg, cache, token, index, *, dtype=jnp.bfloat16,
                window=None):
    window = cfg.sliding_window if window is None else window
    x = L.embed_tokens(params["embed"], cfg, token, dtype)
    pos_row = L.sinusoidal_positions(cfg.max_seq_len, cfg.d_model)
    x = x + jax.lax.dynamic_slice_in_dim(
        pos_row, jnp.minimum(index, pos_row.shape[0] - 1), 1)[None].astype(
            dtype)
    positions = jnp.full((1,), index, jnp.int32)

    def body(x, xs):
        bp, k, v, pos, ck, cv = xs
        x, nc = _dec_block(bp, cfg, x, None, positions=positions,
                           cache=(k, v, pos), cross_cache=(ck, cv),
                           cache_index=index)
        return x, {"k": nc[0], "v": nc[1], "pos": nc[2],
                   "cross_k": ck, "cross_v": cv}

    x, new_cache = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"], cache["pos"],
                  cache["cross_k"], cache["cross_v"]), **flags.scan_kwargs())
    x = L.apply_norm(params["final_norm"], cfg, x)
    logits = L.lm_logits(params["head"], params["embed"], cfg, x)
    return logits, new_cache
