"""Model factory: one uniform API over all assigned architecture families.

``build_model(cfg)`` returns a ``ModelApi`` with:
  init(rng)                    -> Param pytree (annotated)
  train_loss(params, batch)    -> (loss, metrics)   [full values pytree]
  prefill(params, batch)       -> (logits, cache)
  decode_step(params, cache, token, index) -> (logits, cache)
  init_cache(batch, cache_len) -> cache pytree
  batch_spec(shape)            -> ShapeDtypeStruct inputs for the dry-run
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import encdec, hybrid, rwkv, transformer
from repro.sharding.spec import Param, shard_act

_is_param = lambda x: isinstance(x, Param)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def lm_loss(logits, labels, mask):
    """Token-mean masked cross-entropy; labels: (B,S) int32, mask (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Factory
# ---------------------------------------------------------------------------


@dataclass
class ModelApi:
    cfg: ArchConfig
    init: Callable[..., Any]
    train_loss: Callable[..., Any]
    forward_features: Callable[..., Any]   # pre-head hidden states (split point)
    head_logits: Callable[..., Any]        # features -> logits ("FC on server")
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]
    init_cache: Callable[..., Any]
    batch_spec: Callable[..., Any]


def _text_len(cfg, seq_len: int) -> int:
    if cfg.family == "vlm":
        return seq_len - cfg.num_patches
    return seq_len


def build_model(cfg: ArchConfig, *, compute_dtype=jnp.bfloat16,
                remat: bool = True, loss_chunks: int = 0) -> ModelApi:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        mod = transformer
    elif fam == "hybrid":
        mod = hybrid
    elif fam == "ssm":
        mod = rwkv
    elif fam == "encdec":
        mod = encdec
    else:
        raise ValueError(f"no LM assembly for family {fam!r}")

    def init(rng):
        return mod.init_model(rng, cfg)

    def _fwd_kwargs(batch):
        kw = {}
        if fam == "vlm":
            kw["patches"] = batch["patches"]
        if fam == "encdec":
            kw["frames"] = batch["frames"]
        return kw

    def forward_features(params, batch, *, window=None,
                         compute_logits=True):
        """Backbone forward up to the pre-head hidden states."""
        logits, aux, feats = mod.forward_train(
            params, cfg, batch["tokens"], dtype=compute_dtype, remat=remat,
            window=window, compute_logits=compute_logits,
            **_fwd_kwargs(batch))
        return logits, aux, feats

    def head_logits(params, feats):
        from repro.models import layers as L
        return L.lm_logits(params["head"], params["embed"], cfg, feats)

    def train_loss(params, batch, *, window=None):
        if loss_chunks > 1:
            # fused vocab-chunked head+loss: full logits never materialise
            _, aux, feats = forward_features(params, batch, window=window,
                                             compute_logits=False)
            if fam == "vlm":
                feats = feats[:, cfg.num_patches:]
            if cfg.tie_embeddings:
                head_w = params["embed"]["table"].T
            else:
                head_w = params["head"]["w"]
            loss = lm_loss_chunked(feats, head_w, batch["labels"],
                                   batch["mask"], n_chunks=loss_chunks)
            return loss + aux, {"loss": loss, "aux": aux}
        logits, aux, _ = forward_features(params, batch, window=window)
        if fam == "vlm":  # loss only on text positions (patches are prefix)
            logits = logits[:, cfg.num_patches:]
        loss = lm_loss(logits, batch["labels"], batch["mask"])
        return loss + aux, {"loss": loss, "aux": aux}

    def prefill(params, batch, *, window=None, cache_len=None):
        return mod.prefill(params, cfg, batch["tokens"],
                           dtype=compute_dtype, window=window,
                           cache_len=cache_len, **_fwd_kwargs(batch))

    def decode_step(params, cache, token, index, *, window=None):
        return mod.decode_step(params, cfg, cache, token, index,
                               dtype=compute_dtype, window=window)

    def init_cache(batch, cache_len, *, window=None):
        return mod.init_cache(cfg, batch, cache_len, window=window,
                              dtype=compute_dtype)

    def batch_spec(shape: InputShape, *, global_batch=None):
        b = global_batch or shape.global_batch
        s_text = _text_len(cfg, shape.seq_len)
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        if shape.kind == "train":
            spec = {
                "tokens": sds((b, s_text), i32),
                "labels": sds((b, s_text), i32),
                "mask": sds((b, s_text), jnp.float32),
            }
        else:
            spec = {"tokens": sds((b, s_text), i32)}
        if fam == "vlm":
            spec["patches"] = sds((b, cfg.num_patches), jnp.float32)
            spec["patches"] = sds((b, cfg.num_patches, cfg.d_model),
                                  jnp.float32)
        if fam == "encdec":
            spec["frames"] = sds((b, cfg.encoder_seq_len, cfg.d_model),
                                 jnp.float32)
        return spec

    return ModelApi(cfg=cfg, init=init, train_loss=train_loss,
                    forward_features=forward_features,
                    head_logits=head_logits, prefill=prefill,
                    decode_step=decode_step, init_cache=init_cache,
                    batch_spec=batch_spec)


# ---------------------------------------------------------------------------
# Analytic parameter counts (for MODEL_FLOPS = 6·N·D roofline term)
# ---------------------------------------------------------------------------


def count_params_analytic(cfg: ArchConfig, active_only: bool = False) -> int:
    """Count params via eval_shape of the real init (exact, no duplication).

    ``active_only``: MoE expert weights counted at k/E of their size.
    """
    api = build_model(cfg)
    tree = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    flat = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=_is_param)[0]
    total = 0
    expert = 0
    for path, leaf in flat:
        v = leaf.value if isinstance(leaf, Param) else leaf
        n = 1
        for d in v.shape:
            n *= d
        total += n
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if any(k == "moe" for k in keys) and any(
                str(k).startswith("w_") for k in keys):
            expert += n
    if active_only and cfg.is_moe and expert:
        frac = cfg.moe.num_experts_per_tok / cfg.moe.num_experts
        return int(total - expert * (1.0 - frac))
    return total


# ---------------------------------------------------------------------------
# Vocab-chunked fused head+loss (beyond-paper: never materialise full logits)
# ---------------------------------------------------------------------------


def lm_loss_chunked(feats, head_w, labels, mask, *, n_chunks: int = 8):
    """Cross-entropy without materialising the (B, S, V) logits tensor.

    Scans over vocab chunks of the head matmul with an online logsumexp
    (flash-attention-style running max/sum) and picks the label logit from
    whichever chunk owns it.  With the remat'd body, peak logits memory is
    V/n_chunks of the naive path.  feats: (B,S,D); head_w: (D,V).
    """
    b, s, d = feats.shape
    v = head_w.shape[1]
    assert v % n_chunks == 0, (v, n_chunks)
    vc = v // n_chunks
    w_chunks = head_w.reshape(d, n_chunks, vc).transpose(1, 0, 2)  # (K,D,Vc)
    offsets = jnp.arange(n_chunks, dtype=jnp.int32) * vc

    def body(carry, xs):
        m, ssum, gold = carry
        w_c, off = xs
        logits = jnp.einsum("bsd,dv->bsv", feats,
                            w_c.astype(feats.dtype)).astype(jnp.float32)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        ssum = ssum * jnp.exp(m - m_new) + jnp.exp(
            logits - m_new[..., None]).sum(axis=-1)
        local = labels - off
        in_chunk = (local >= 0) & (local < vc)
        picked = jnp.take_along_axis(
            logits, jnp.clip(local, 0, vc - 1)[..., None], axis=-1)[..., 0]
        gold = jnp.where(in_chunk, picked, gold)
        return (m_new, ssum, gold), None

    init = (jnp.full((b, s), -1e30, jnp.float32),
            jnp.zeros((b, s), jnp.float32),
            jnp.zeros((b, s), jnp.float32))
    from repro.models import flags
    (m, ssum, gold), _ = jax.lax.scan(
        jax.checkpoint(body), init, (w_chunks, offsets),
        **flags.scan_kwargs())
    lse = jnp.log(jnp.maximum(ssum, 1e-30)) + m
    nll = (lse - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)
