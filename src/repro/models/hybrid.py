"""Jamba-style hybrid assembly: Mamba+attention 1:7 interleave, MoE every
other layer.  The layer stack is scanned over *periods* of
``attn_layer_period`` sublayers (the repeating unit), with the period body
unrolled — HLO stays one-period-sized regardless of depth (72 layers = 9
scanned periods for Jamba-1.5-Large).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import flags, layers as L
from repro.models.mamba import (init_mamba, mamba_forward, mamba_init_state,
                                _dims as mamba_dims)
from repro.models.moe import apply_moe, init_moe
from repro.sharding.spec import Param, shard_act

_is_param = lambda x: isinstance(x, Param)


def _prepend_axis(tree, name="layers"):
    return jax.tree_util.tree_map(
        lambda p: Param(p.value, (name,) + p.axes), tree, is_leaf=_is_param)


def _index(tree, i):
    return jax.tree_util.tree_map(lambda a: a[i], tree)


def _period_layout(cfg):
    p = cfg.attn_layer_period
    attn_pos = cfg.attn_layer_offset
    moe_every = cfg.moe_layer_period
    layout = []
    for i in range(p):
        mixer = "attn" if i == attn_pos else "mamba"
        ffn = "moe" if (cfg.is_moe and i % moe_every == moe_every - 1) \
            else "dense"
        layout.append((mixer, ffn))
    return layout


def init_model(key, cfg):
    layout = _period_layout(cfg)
    p_len = len(layout)
    assert cfg.num_layers % p_len == 0, (cfg.num_layers, p_len)
    n_periods = cfg.num_layers // p_len
    n_mamba = sum(m == "mamba" for m, _ in layout)
    n_dense = sum(f == "dense" for _, f in layout)
    n_moe = sum(f == "moe" for _, f in layout)

    def init_period(key):
        ks = jax.random.split(key, 4)
        pp = {
            "norm1": {"scale": Param(jnp.ones((p_len, cfg.d_model)),
                                     ("layers", None))},
            "norm2": {"scale": Param(jnp.ones((p_len, cfg.d_model)),
                                     ("layers", None))},
            "attn": L.init_attention(ks[0], cfg),
            "mamba": _prepend_axis(jax.vmap(
                lambda k: init_mamba(k, cfg))(
                    jax.random.split(ks[1], n_mamba))),
            "dense": _prepend_axis(jax.vmap(
                lambda k: L.init_mlp(k, cfg))(
                    jax.random.split(ks[2], n_dense))),
        }
        if n_moe:
            pp["moe"] = _prepend_axis(jax.vmap(
                lambda k: init_moe(k, cfg))(jax.random.split(ks[3], n_moe)))
        return pp

    ks = jax.random.split(key, 3)
    periods = jax.vmap(init_period)(jax.random.split(ks[0], n_periods))
    return {
        "embed": L.init_embedding(ks[1], cfg),
        "periods": _prepend_axis(periods),
        "final_norm": L.init_norm(cfg),
        "head": L.init_lm_head(ks[2], cfg),
    }


def _apply_period(pp, cfg, x, *, positions, mode, attn_cache=None,
                  mamba_state=None, cache_index=None, window=0):
    """One period (unrolled).  mode: train | prefill | decode.

    Returns (x, aux, new_attn_cache, new_mamba_state).
    """
    layout = _period_layout(cfg)
    aux = jnp.float32(0.0)
    mi = di = mo = 0
    new_attn_cache = None
    new_states = []
    for i, (mixer, ffn) in enumerate(layout):
        xn = L.apply_norm(_index(pp["norm1"], i), cfg, x)
        if mixer == "attn":
            if mode == "decode":
                h, new_attn_cache = L.attention(
                    pp["attn"], cfg, xn, positions=positions, window=window,
                    cache=attn_cache, cache_index=cache_index)
            else:
                h, kv = L.attention(pp["attn"], cfg, xn, positions=positions,
                                    window=window)
                new_attn_cache = kv
        else:
            st = _index(mamba_state, mi) if mamba_state is not None else None
            h, new_st = mamba_forward(_index(pp["mamba"], mi), cfg, xn,
                                      state=st)
            new_states.append(new_st)
            mi += 1
        x = x + h
        xn = L.apply_norm(_index(pp["norm2"], i), cfg, x)
        if ffn == "moe":
            h, a = apply_moe(_index(pp["moe"], mo), cfg, xn,
                             capacity_factor=max(2.0, cfg.moe.capacity_factor) if mode == "decode" else None)
            aux = aux + a
            mo += 1
        else:
            h = L.apply_mlp(_index(pp["dense"], di), cfg, xn)
            di += 1
        x = x + h
    stacked_states = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *new_states)
    return x, aux, new_attn_cache, stacked_states


def forward_train(params, cfg, tokens, *, dtype=jnp.bfloat16, remat=True,
                  window=None, compute_logits=True):
    window = cfg.sliding_window if window is None else window
    x = L.embed_tokens(params["embed"], cfg, tokens, dtype)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(carry, pp):
        x, aux = carry
        x, a, _, _ = _apply_period(pp, cfg, x, positions=positions,
                                   mode="train", window=window)
        return (x, aux + a), None

    body_fn = jax.checkpoint(body) if remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)),
                               params["periods"], **flags.scan_kwargs())
    x = L.apply_norm(params["final_norm"], cfg, x)
    logits = (L.lm_logits(params["head"], params["embed"], cfg, x)
              if compute_logits else None)
    return logits, aux, x


def init_cache(cfg, batch: int, cache_len: int, *, window=None,
               dtype=jnp.bfloat16):
    """Hybrid cache: attention ring buffers + mamba states, per period."""
    window = cfg.sliding_window if window is None else window
    layout = _period_layout(cfg)
    n_periods = cfg.num_layers // len(layout)
    n_mamba = sum(m == "mamba" for m, _ in layout)
    size = min(window, cache_len) if window else cache_len
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    d_inner, _, d_state, d_conv = mamba_dims(cfg)
    return {
        "k": jnp.zeros((n_periods, batch, size, kv, hd), dtype),
        "v": jnp.zeros((n_periods, batch, size, kv, hd), dtype),
        "pos": jnp.full((n_periods, size), -1, jnp.int32),
        "ssm": jnp.zeros((n_periods, n_mamba, batch, d_inner, d_state),
                         jnp.float32),
        "conv": jnp.zeros((n_periods, n_mamba, batch, d_conv - 1, d_inner),
                          dtype),
    }


def prefill(params, cfg, tokens, *, dtype=jnp.bfloat16, window=None,
            cache_len: int | None = None):
    window = cfg.sliding_window if window is None else window
    x = L.embed_tokens(params["embed"], cfg, tokens, dtype)
    b, s, _ = x.shape
    cache_len = cache_len or s
    size = min(window, cache_len) if window else cache_len
    positions = jnp.arange(s, dtype=jnp.int32)
    layout = _period_layout(cfg)
    n_mamba = sum(m == "mamba" for m, _ in layout)
    d_inner, _, d_state, d_conv = mamba_dims(cfg)

    def body(x, pp):
        zero_states = (
            jnp.zeros((n_mamba, b, d_inner, d_state), jnp.float32),
            jnp.zeros((n_mamba, b, d_conv - 1, d_inner), x.dtype))
        x, _, kv, states = _apply_period(pp, cfg, x, positions=positions,
                                         mode="prefill", window=window,
                                         mamba_state=zero_states)
        k, v = kv
        if size < s:
            keep = positions[s - size:]
            slots = keep % size
            ck = jnp.zeros((b, size) + k.shape[2:], dtype).at[:, slots].set(
                k[:, s - size:].astype(dtype))
            cv = jnp.zeros((b, size) + v.shape[2:], dtype).at[:, slots].set(
                v[:, s - size:].astype(dtype))
            cpos = jnp.full((size,), -1, jnp.int32).at[slots].set(keep)
        else:
            pad = size - s
            ck = jnp.pad(k.astype(dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
            cv = jnp.pad(v.astype(dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
            cpos = jnp.concatenate([positions,
                                    jnp.full((pad,), -1, jnp.int32)])
        return x, {"k": ck, "v": cv, "pos": cpos, "ssm": states[0],
                   "conv": states[1].astype(dtype)}

    x, cache = jax.lax.scan(body, x, params["periods"],
                            **flags.scan_kwargs())
    x = L.apply_norm(params["final_norm"], cfg, x)
    logits = L.lm_logits(params["head"], params["embed"], cfg, x[:, -1:])
    return logits, cache


def decode_step(params, cfg, cache, token, index, *, dtype=jnp.bfloat16,
                window=None):
    window = cfg.sliding_window if window is None else window
    x = L.embed_tokens(params["embed"], cfg, token, dtype)
    positions = jnp.full((1,), index, jnp.int32)

    def body(x, xs):
        pp, ck, cv, cpos, ssm, conv = xs
        x, _, new_kv, new_states = _apply_period(
            pp, cfg, x, positions=positions, mode="decode", window=window,
            attn_cache=(ck, cv, cpos), cache_index=index,
            mamba_state=(ssm, conv))
        return x, {"k": new_kv[0], "v": new_kv[1], "pos": new_kv[2],
                   "ssm": new_states[0],
                   "conv": new_states[1].astype(conv.dtype)}

    x, new_cache = jax.lax.scan(
        body, x, (params["periods"], cache["k"], cache["v"], cache["pos"],
                  cache["ssm"], cache["conv"]), **flags.scan_kwargs())
    x = L.apply_norm(params["final_norm"], cfg, x)
    logits = L.lm_logits(params["head"], params["embed"], cfg, x)
    return logits, new_cache
