"""The paper's deep CNN (Sukiyaki): conv -> activation -> max-pool stacks and
a fully-connected softmax classifier (Figures 2/4 of the paper).

Exposed as two halves — ``conv_features`` (the "client" part under the
paper's distribution algorithm) and ``fc_logits`` (the "server" part) — so
``core/split_parallel.py`` can train them with the paper's concurrency.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.sharding.spec import Param, param, shard_act


def init_cnn(key, ccfg):
    ks = jax.random.split(key, len(ccfg.convs) + 1)
    convs = []
    cin = ccfg.in_channels
    for i, spec in enumerate(ccfg.convs):
        convs.append({
            "w": param(ks[i], (spec.kernel, spec.kernel, cin,
                               spec.out_channels),
                       (None, None, None, "conv_out"),
                       scale=1.0 / math.sqrt(spec.kernel ** 2 * cin)),
            "b": Param(jnp.zeros((spec.out_channels,)), ("conv_out",)),
        })
        cin = spec.out_channels
    dims = [ccfg.feature_dim, *ccfg.fc_hidden, ccfg.num_classes]
    fck = jax.random.split(ks[-1], len(dims) - 1)
    fc = [{
        "w": param(fck[i], (dims[i], dims[i + 1]),
                   ("head_embed", "head_vocab"),
                   scale=1.0 / math.sqrt(dims[i])),
        "b": Param(jnp.zeros((dims[i + 1],)), ("head_vocab",)),
    } for i in range(len(dims) - 1)]
    return {"convs": convs, "fc": fc}


def conv_features(params, ccfg, images):
    """images: (B, H, W, C) -> flat features (B, feature_dim)."""
    x = images
    for spec, cp in zip(ccfg.convs, params["convs"]):
        x = jax.lax.conv_general_dilated(
            x, cp["w"].astype(x.dtype), window_strides=(1, 1),
            padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x + cp["b"].astype(x.dtype))
        x = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, spec.pool, spec.pool, 1),
            (1, spec.pool, spec.pool, 1), "VALID")
        x = shard_act(x, "batch", None, None, "conv_out")
    return x.reshape(x.shape[0], -1)


def fc_logits(params, ccfg, feats):
    """The server-side fully-connected classifier (optionally deep)."""
    x = feats
    layers_ = params["fc"]
    for i, lp in enumerate(layers_):
        x = x @ lp["w"].astype(x.dtype) + lp["b"].astype(x.dtype)
        if i < len(layers_) - 1:
            x = jax.nn.relu(x)
    return x


def forward(params, ccfg, images):
    return fc_logits(params, ccfg, conv_features(params, ccfg, images))


def nll_loss(logits, labels):
    """Mean softmax cross-entropy; labels: (B,) int."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def error_rate(logits, labels):
    return jnp.mean((jnp.argmax(logits, -1) != labels).astype(jnp.float32))
