"""The paper's deep CNN (Sukiyaki): conv -> activation -> max-pool stacks and
a fully-connected softmax classifier (Figures 2/4 of the paper).

Exposed as two halves — ``conv_features`` (the "client" part under the
paper's distribution algorithm) and ``fc_logits`` (the "server" part) — so
``core/split_parallel.py`` can train them with the paper's concurrency.

Also exposed as **fabric ticket work**: :class:`CnnGradShard` is a
picklable task callable (registrable under a ``TaskDef``, shippable to
remote browser clients over the wire protocol) that computes the CNN's
loss + gradients for one row slice of a deterministic synthetic dataset
against the round's served weights — the payload that makes
``FederatedTrainingLoop`` rounds train the *paper's model* rather than a
toy regression (see ``benchmarks/federated_training.py``).
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.paper_cnn import CNNConfig
from repro.sharding.spec import Param, param, shard_act


def init_cnn(key, ccfg):
    ks = jax.random.split(key, len(ccfg.convs) + 1)
    convs = []
    cin = ccfg.in_channels
    for i, spec in enumerate(ccfg.convs):
        convs.append({
            "w": param(ks[i], (spec.kernel, spec.kernel, cin,
                               spec.out_channels),
                       (None, None, None, "conv_out"),
                       scale=1.0 / math.sqrt(spec.kernel ** 2 * cin)),
            "b": Param(jnp.zeros((spec.out_channels,)), ("conv_out",)),
        })
        cin = spec.out_channels
    dims = [ccfg.feature_dim, *ccfg.fc_hidden, ccfg.num_classes]
    fck = jax.random.split(ks[-1], len(dims) - 1)
    fc = [{
        "w": param(fck[i], (dims[i], dims[i + 1]),
                   ("head_embed", "head_vocab"),
                   scale=1.0 / math.sqrt(dims[i])),
        "b": Param(jnp.zeros((dims[i + 1],)), ("head_vocab",)),
    } for i in range(len(dims) - 1)]
    return {"convs": convs, "fc": fc}


def conv_features(params, ccfg, images):
    """images: (B, H, W, C) -> flat features (B, feature_dim)."""
    x = images
    for spec, cp in zip(ccfg.convs, params["convs"]):
        x = jax.lax.conv_general_dilated(
            x, cp["w"].astype(x.dtype), window_strides=(1, 1),
            padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x + cp["b"].astype(x.dtype))
        x = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, spec.pool, spec.pool, 1),
            (1, spec.pool, spec.pool, 1), "VALID")
        x = shard_act(x, "batch", None, None, "conv_out")
    return x.reshape(x.shape[0], -1)


def fc_logits(params, ccfg, feats):
    """The server-side fully-connected classifier (optionally deep)."""
    x = feats
    layers_ = params["fc"]
    for i, lp in enumerate(layers_):
        x = x @ lp["w"].astype(x.dtype) + lp["b"].astype(x.dtype)
        if i < len(layers_) - 1:
            x = jax.nn.relu(x)
    return x


def forward(params, ccfg, images):
    return fc_logits(params, ccfg, conv_features(params, ccfg, images))


def nll_loss(logits, labels):
    """Mean softmax cross-entropy; labels: (B,) int."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def error_rate(logits, labels):
    return jnp.mean((jnp.argmax(logits, -1) != labels).astype(jnp.float32))


# ---------------------------------------------------------------------------
# The CNN as fabric ticket work
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def loss_and_grads(ccfg: CNNConfig):
    """Jitted ``(params, images, labels) -> (mean NLL, grad pytree)`` for
    plain (unboxed) params, cached per config so every shard of a round
    — and every round — reuses one compiled executable."""

    @jax.jit
    def f(params, images, labels):
        def loss_fn(p):
            return nll_loss(forward(p, ccfg, images), labels)
        return jax.value_and_grad(loss_fn)(params)

    return f


@functools.lru_cache(maxsize=None)
def shard_dataset(ccfg: CNNConfig, n_rows: int, seed: int):
    """The deterministic synthetic classification set the fabric shards
    by row slice (``repro.data.clustered_images`` — learnable, so the
    round loss actually converges).  Cached: every shard of every round
    slices the same arrays."""
    from repro.data import clustered_images
    return clustered_images(n_rows, image_size=ccfg.image_size,
                            channels=ccfg.in_channels, seed=seed)


@dataclass(frozen=True)
class CnnGradShard:
    """Picklable fabric task: paper-CNN loss + gradients of one row slice.

    ``args`` is a ``(lo, hi)`` row slice of :func:`shard_dataset`;
    ``static[weights_key]`` is the round's versioned weight publish
    ``{"round": t, "params": ...}``.  Returns the training-loop contract
    ``{"grad", "loss", "round"}`` with gradients device_get'ed to plain
    numpy so the result pickles over the v2 wire protocol.

    A frozen dataclass of hashable config rather than a closure: remote
    clients receive the task by pickle, and the jitted grad function is
    looked up per-process from the :func:`loss_and_grads` cache.
    """

    ccfg: CNNConfig
    n_rows: int = 512
    seed: int = 0
    weights_key: str = "weights"

    def __call__(self, args, static):
        lo, hi = args
        images, labels = shard_dataset(self.ccfg, self.n_rows, self.seed)
        served = static[self.weights_key]
        loss, grads = loss_and_grads(self.ccfg)(
            served["params"], jnp.asarray(images[lo:hi]),
            jnp.asarray(labels[lo:hi]))
        return {"grad": jax.device_get(grads), "loss": float(loss),
                "round": served.get("round", -1)}
