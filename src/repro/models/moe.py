"""Mixture-of-Experts FFN: top-k token-choice routing with capacity-bounded
sort-based dispatch (no (tokens, E, C) one-hot blow-up).

Dispatch: assignments (token, k) are ranked within their expert via an
argsort + searchsorted trick, scattered into an (E, C, D) buffer (sharded
expert-parallel over 'model'), batched expert matmuls run as one einsum,
and results are gathered back and combined with the normalised router
weights.  Tokens beyond an expert's capacity are dropped (standard
token-choice behaviour); ``tests/test_moe.py`` checks exactness against a
dense per-token oracle when capacity is ample.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.sharding.spec import current_ctx, param, shard_act, to_pspec
from repro.models.layers import mlp_act


def init_moe(key, cfg, d_ff: int | None = None):
    d_ff = d_ff or cfg.d_ff
    e = cfg.moe.num_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": param(ks[0], (cfg.d_model, e), ("embed", None), scale=0.02),
        "w_up": param(ks[1], (e, cfg.d_model, d_ff),
                      ("expert", "embed", "mlp"),
                      scale=1.0 / math.sqrt(cfg.d_model)),
        "w_down": param(ks[2], (e, d_ff, cfg.d_model),
                        ("expert", "mlp", "embed"),
                        scale=1.0 / math.sqrt(d_ff)),
    }
    if cfg.act == "swiglu":
        p["w_gate"] = param(ks[3], (e, cfg.d_model, d_ff),
                            ("expert", "embed", "mlp"),
                            scale=1.0 / math.sqrt(cfg.d_model))
    return p


def router_topk(logits, k: int):
    """fp32 softmax over experts, take top-k, renormalise. -> (weights, idx)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, idx = jax.lax.top_k(probs, k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, -1, keepdims=True), 1e-9)
    return probs, weights, idx


def _positions_within_expert(e_flat, num_experts: int):
    """Rank of each assignment within its expert (stable arrival order)."""
    nk = e_flat.shape[0]
    order = jnp.argsort(e_flat, stable=True)
    sorted_e = e_flat[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(num_experts))
    pos_sorted = jnp.arange(nk) - seg_start[sorted_e]
    return jnp.zeros((nk,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))


def apply_moe(p, cfg, x, *, capacity_factor: float | None = None):
    """x: (B, S, D) -> (y, aux_loss).

    Two dispatch paths:
      * expert-parallel ``shard_map`` (default on a mesh with experts
        sharded): local scatter into per-shard (E, C_loc, D) buffers, each
        model shard computes only its experts, partial outputs psum over
        'model'.  No cross-shard scatter/gather — GSPMD's generic scatter
        handling replicates the dispatch buffers (measured +450 GiB/device
        on dbrx-132b train_4k, see EXPERIMENTS.md §Perf).
      * local XLA scatter (single device / replicated experts).
    """
    ctx = current_ctx()
    if ctx is not None and ctx.mesh is not None:
        expert_ax = ctx.rules.get("expert")
        if expert_ax is not None and cfg.moe.num_experts % \
                ctx.mesh.shape[expert_ax] == 0:
            return _apply_moe_sharded(p, cfg, x, ctx,
                                      capacity_factor=capacity_factor)
    return _apply_moe_local(p, cfg, x, capacity_factor=capacity_factor)


def _apply_moe_local(p, cfg, x, *, capacity_factor: float | None = None):
    """Single-shard dispatch (reference semantics)."""
    moe = cfg.moe
    e, k = moe.num_experts, moe.num_experts_per_tok
    b, s, d = x.shape
    n = b * s
    capf = capacity_factor or moe.capacity_factor
    cap = max(int(math.ceil(n * k / e * capf)), 2 * k)
    # round to a lane-friendly multiple
    cap = (cap + 7) // 8 * 8

    xf = x.reshape(n, d)
    logits = jnp.einsum("nd,de->ne", xf, p["router"].astype(x.dtype))
    probs, weights, idx = router_topk(logits, k)          # (n,e),(n,k),(n,k)

    e_flat = idx.reshape(-1)                               # (n*k,)
    tok = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)    # (n*k,)
    pos = _positions_within_expert(e_flat, e)              # (n*k,)
    keep = pos < cap
    cpos = jnp.minimum(pos, cap - 1)

    # dispatch: (E, C, D) expert-parallel buffer
    vals = xf[tok] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((e, cap, d), x.dtype).at[e_flat, cpos].add(vals)
    buf = shard_act(buf, "expert", "capacity", None)

    h = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    g = (jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype))
         if cfg.act == "swiglu" else None)
    h = mlp_act(cfg, h, g)
    h = shard_act(h, "expert", "capacity", "mlp")
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
    out_buf = shard_act(out_buf, "expert", "capacity", None)

    # combine
    contrib = out_buf[e_flat, cpos] * (
        weights.reshape(-1)[:, None] * keep[:, None]).astype(x.dtype)
    y = contrib.reshape(n, k, d).sum(axis=1).reshape(b, s, d)
    y = shard_act(y, "batch", "seq", None)

    # load-balance auxiliary loss (Switch-style)
    counts = jnp.zeros((e,), jnp.float32).at[e_flat].add(
        keep.astype(jnp.float32))
    frac_tokens = counts / jnp.maximum(counts.sum(), 1.0)
    frac_probs = probs.mean(axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs) * moe.router_aux_loss_coef
    return y, aux


# ---------------------------------------------------------------------------
# Expert-parallel shard_map dispatch
# ---------------------------------------------------------------------------


def _flat_axes(ax):
    if ax is None:
        return ()
    return ax if isinstance(ax, tuple) else (ax,)


def _apply_moe_sharded(p, cfg, x, ctx, *, capacity_factor=None):
    """Expert-parallel MoE: scatter locally per data shard, compute each
    expert only on its 'model' shard, psum partial outputs.

    Collectives per layer: all-gather of expert weights over the FSDP axis
    (+ psum of (tokens_local, D) outputs over 'model') — no distributed
    scatter/gather at all.
    """
    mesh = ctx.mesh
    rules = ctx.rules
    moe = cfg.moe
    e, k = moe.num_experts, moe.num_experts_per_tok
    b, s, d = x.shape
    capf = capacity_factor or moe.capacity_factor

    model_ax = rules.get("expert")
    batch_axes = tuple(a for a in _flat_axes(rules.get("batch"))
                       if b % max(mesh.shape[a], 1) == 0)
    # weight FSDP axis: embed rule, minus axes used elsewhere here
    fsdp_axes = tuple(a for a in _flat_axes(rules.get("embed"))
                      if a != model_ax)

    x_spec = to_pspec(("batch", None, None),
                      dict(rules) | {"batch": batch_axes or None},
                      mesh=mesh, shape=x.shape)
    w3 = ("expert", "embed", "mlp")
    specs = {
        "router": to_pspec(("embed", None), rules, mesh=mesh,
                           shape=p["router"].shape),
        "w_up": to_pspec(w3, rules, mesh=mesh, shape=p["w_up"].shape),
        "w_down": to_pspec(("expert", "mlp", "embed"), rules, mesh=mesh,
                           shape=p["w_down"].shape),
    }
    if "w_gate" in p:
        specs["w_gate"] = specs["w_up"]

    n_model = mesh.shape[model_ax]
    e_loc = e // n_model

    n_fsdp = 1
    for a in fsdp_axes:
        n_fsdp *= mesh.shape[a]

    def body(x_loc, p_loc):
        bl, sl, _ = x_loc.shape
        n = bl * sl
        cap = max(int(math.ceil(n * k / e * capf)), 2 * k)
        cap = (cap + 7) // 8 * 8

        # reassemble FSDP-sharded weights
        def gather(w, axis):
            for a in fsdp_axes:
                w = jax.lax.all_gather(w, a, axis=axis, tiled=True)
            return w

        router = gather(p_loc["router"], 0)
        xf = x_loc.reshape(n, d)
        logits = jnp.einsum("nd,de->ne", xf, router.astype(x.dtype))
        probs, weights, idx = router_topk(logits, k)

        e_flat = idx.reshape(-1)
        tok = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
        pos = _positions_within_expert(e_flat, e)
        keep = pos < cap
        cpos = jnp.minimum(pos, cap - 1)

        vals = xf[tok] * keep[:, None].astype(x.dtype)
        buf = jnp.zeros((e, cap, d), x.dtype).at[e_flat, cpos].add(vals)

        # this model shard computes only its own experts
        e0 = jax.lax.axis_index(model_ax) * e_loc
        buf_loc = jax.lax.dynamic_slice_in_dim(buf, e0, e_loc, axis=0)

        # Two expert-matmul schedules (see EXPERIMENTS.md §Perf, jamba
        # decode iteration):
        #  * weight-gather (training): all-gather the FSDP shard of the
        #    expert weights once; right when C >> D (dispatch buffers big).
        #  * partial-sum (decode): weights stay RESIDENT; each FSDP shard
        #    multiplies its D-slice of the dispatch buffer and the partial
        #    results are psum'd / gathered — comm ∝ C·(F+D) instead of
        #    3·D·F.  Right when C << D (a handful of tokens per step).
        #    VALID ONLY when the batch is replicated over the FSDP axis
        #    (otherwise different shards hold different tokens and the
        #    psum would mix them) — the replicated-batch decode layout.
        batch_uses_fsdp = any(a in batch_axes for a in fsdp_axes)
        use_partial = (bool(fsdp_axes) and not batch_uses_fsdp
                       and cap * n_fsdp < d)
        if use_partial:
            d_loc = d // n_fsdp
            di = jax.lax.axis_index(fsdp_axes[0])
            buf_slice = jax.lax.dynamic_slice_in_dim(
                buf_loc, di * d_loc, d_loc, axis=2)
            h = jnp.einsum("ecd,edf->ecf", buf_slice,
                           p_loc["w_up"].astype(x.dtype))
            if "w_gate" in p_loc:
                g = jnp.einsum("ecd,edf->ecf", buf_slice,
                               p_loc["w_gate"].astype(x.dtype))
                h, g = jax.lax.psum((h, g), fsdp_axes)
            else:
                h = jax.lax.psum(h, fsdp_axes)
                g = None
            h = mlp_act(cfg, h, g)
            out_part = jnp.einsum("ecf,efd->ecd", h,
                                  p_loc["w_down"].astype(x.dtype))
            out_buf = out_part
            for a in fsdp_axes:
                out_buf = jax.lax.all_gather(out_buf, a, axis=2, tiled=True)
        else:
            w_up = gather(p_loc["w_up"], 1)
            w_down = gather(p_loc["w_down"], 2)
            h = jnp.einsum("ecd,edf->ecf", buf_loc, w_up.astype(x.dtype))
            if "w_gate" in p_loc:
                g = jnp.einsum("ecd,edf->ecf", buf_loc,
                               gather(p_loc["w_gate"], 1).astype(x.dtype))
            else:
                g = None
            h = mlp_act(cfg, h, g)
            out_buf = jnp.einsum("ecf,efd->ecd", h, w_down.astype(x.dtype))

        # combine local experts' contributions, psum across expert shards
        le = e_flat - e0
        mine = (le >= 0) & (le < e_loc) & keep
        contrib = out_buf[jnp.clip(le, 0, e_loc - 1), cpos]
        contrib = contrib * (weights.reshape(-1)[:, None]
                             * mine[:, None]).astype(x.dtype)
        y = contrib.reshape(n, k, d).sum(axis=1)
        y = jax.lax.psum(y, model_ax)
        y = y.reshape(bl, sl, d)

        counts = jnp.zeros((e,), jnp.float32).at[e_flat].add(
            keep.astype(jnp.float32))
        frac_tokens = counts / jnp.maximum(counts.sum(), 1.0)
        aux = e * jnp.sum(frac_tokens * probs.mean(axis=0)) \
            * moe.router_aux_loss_coef
        # make the scalar identical on every shard so out_spec=P() holds
        aux = jax.lax.pmean(aux, tuple(mesh.axis_names))
        return y, aux

    try:                                  # jax >= 0.6
        from jax import shard_map
        replication_kw = {"check_vma": False}
    except ImportError:                   # jax 0.4.x spelling
        from jax.experimental.shard_map import shard_map
        replication_kw = {"check_rep": False}
    p_vals = {k2: p[k2] for k2 in specs}
    f = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, {k2: specs[k2] for k2 in p_vals}),
        out_specs=(x_spec, jax.sharding.PartitionSpec()),
        **replication_kw)
    y, aux = f(x, p_vals)
    return y, aux
