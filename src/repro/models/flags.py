"""Global model-lowering flags.

``scan_unroll``: when True, layer-stack scans lower as straight-line code
(and chunked attention runs unchunked).  Used ONLY by the dry-run's
cost-accounting compiles: XLA's HLO cost analysis counts a while-loop body
once regardless of trip count, so the roofline FLOP/byte terms are derived
from reduced-depth *unrolled* compiles and extrapolated linearly in depth
(see launch/dryrun.py).  Real execution always uses the scanned form.
"""
from __future__ import annotations

import contextlib

scan_unroll: bool = False


@contextlib.contextmanager
def unrolled_for_accounting():
    global scan_unroll
    prev = scan_unroll
    scan_unroll = True
    try:
        yield
    finally:
        scan_unroll = prev


def scan_kwargs() -> dict:
    return {"unroll": True} if scan_unroll else {}
