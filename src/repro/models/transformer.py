"""Decoder-only transformer assembly (dense / MoE / VLM) with scanned layers.

Layer parameters are *stacked* (leading 'layers' axis, never sharded) and the
forward pass is a single ``lax.scan`` over the stack — tiny HLO regardless of
depth, remat-friendly, and identical math to an unrolled loop.

Modes:
  * ``forward_train``: full sequence, returns (logits, aux_loss)
  * ``prefill``: full sequence, returns (logits_last, cache)
  * ``decode_step``: one token against a KV cache (ring buffer when a
    sliding window is configured)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import flags, layers as L
from repro.models.moe import apply_moe, init_moe
from repro.sharding.spec import Param, param, shard_act

_is_param = lambda x: isinstance(x, Param)


def stack_layer_axes(tree):
    """Prepend the 'layers' logical axis to every Param in a vmapped stack."""
    return jax.tree_util.tree_map(
        lambda p: Param(p.value, ("layers",) + p.axes), tree,
        is_leaf=_is_param)


# ---------------------------------------------------------------------------
# Block init
# ---------------------------------------------------------------------------


def init_block(key, cfg, *, moe_block: bool):
    k1, k2 = jax.random.split(key)
    p = {
        "attn_norm": L.init_norm(cfg),
        "attn": L.init_attention(k1, cfg),
        "ffn_norm": L.init_norm(cfg),
    }
    if moe_block:
        p["moe"] = init_moe(k2, cfg)
    else:
        p["mlp"] = L.init_mlp(k2, cfg)
    return p


def init_model(key, cfg):
    """-> Param pytree for dense / moe / vlm decoder families."""
    ks = jax.random.split(key, 4)
    moe_block = cfg.is_moe
    layer_keys = jax.random.split(ks[0], cfg.num_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg, moe_block=moe_block))(
        layer_keys)
    p = {
        "embed": L.init_embedding(ks[1], cfg),
        "blocks": stack_layer_axes(blocks),
        "final_norm": L.init_norm(cfg),
        "head": L.init_lm_head(ks[2], cfg),
    }
    if cfg.family == "vlm":
        # projector stub: patch embeddings arrive pre-extracted (frontend is
        # stubbed per assignment); a single linear maps them into d_model.
        p["patch_proj"] = {
            "w": param(ks[3], (cfg.d_model, cfg.d_model),
                       ("embed", None), scale=0.02),
        }
    return p


# ---------------------------------------------------------------------------
# Block apply
# ---------------------------------------------------------------------------


def _apply_block(bp, cfg, x, *, positions, window, cache=None,
                 cache_index=None):
    h, new_cache = L.attention(
        bp["attn"], cfg, L.apply_norm(bp["attn_norm"], cfg, x),
        positions=positions, window=window, cache=cache,
        cache_index=cache_index)
    x = x + h
    hn = L.apply_norm(bp["ffn_norm"], cfg, x)
    if "moe" in bp:
        h, aux = apply_moe(bp["moe"], cfg, hn)
    else:
        h, aux = L.apply_mlp(bp["mlp"], cfg, hn), jnp.float32(0.0)
    return x + h, aux, new_cache


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg, tokens, patches, dtype):
    x = L.embed_tokens(params["embed"], cfg, tokens, dtype)
    if cfg.family == "vlm":
        pe = jnp.einsum("bpd,de->bpe", patches.astype(dtype),
                        params["patch_proj"]["w"].astype(dtype))
        x = jnp.concatenate([pe, x], axis=1)
    return x


def forward_train(params, cfg, tokens, *, patches=None,
                  dtype=jnp.bfloat16, window=None, remat=True,
                  compute_logits=True):
    """tokens: (B, S_text).  VLM: patches (B, P, D) prepended (S = P+S_text).

    Returns (logits, aux_loss, features) — ``features`` are the pre-head
    hidden states (the paper's split point between "conv" and "FC").
    """
    window = cfg.sliding_window if window is None else window
    x = _embed_inputs(params, cfg, tokens, patches, dtype)
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)

    def body(carry, bp):
        x, aux = carry
        x, a, _ = _apply_block(bp, cfg, x, positions=positions, window=window)
        return (x, aux + a), None

    body_fn = jax.checkpoint(body) if remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)),
                               params["blocks"], **flags.scan_kwargs())
    x = L.apply_norm(params["final_norm"], cfg, x)
    logits = (L.lm_logits(params["head"], params["embed"], cfg, x)
              if compute_logits else None)
    return logits, aux, x


def init_cache(cfg, batch: int, cache_len: int, *, window=None,
               dtype=jnp.bfloat16):
    window = cfg.sliding_window if window is None else window
    size = min(window, cache_len) if window else cache_len
    kv = cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((cfg.num_layers, batch, size, kv, hd), dtype),
        "v": jnp.zeros((cfg.num_layers, batch, size, kv, hd), dtype),
        "pos": jnp.full((cfg.num_layers, size), -1, jnp.int32),
    }


def prefill(params, cfg, tokens, *, patches=None, dtype=jnp.bfloat16,
            window=None, cache_len: int | None = None):
    """Full-sequence forward that also builds the KV cache.

    Returns (last_logits (B,1,V), cache).  The cache covers positions
    [0, S) (ring-compressed to the window if one is set).
    """
    window = cfg.sliding_window if window is None else window
    x = _embed_inputs(params, cfg, tokens, patches, dtype)
    b, s, _ = x.shape
    cache_len = cache_len or s
    size = min(window, cache_len) if window else cache_len
    positions = jnp.arange(s, dtype=jnp.int32)

    def body(carry, bp):
        x = carry
        xn = L.apply_norm(bp["attn_norm"], cfg, x)
        h, kv = L.attention(bp["attn"], cfg, xn, positions=positions,
                            window=window)
        x = x + h
        hn = L.apply_norm(bp["ffn_norm"], cfg, x)
        if "moe" in bp:
            h, _ = apply_moe(bp["moe"], cfg, hn)
        else:
            h = L.apply_mlp(bp["mlp"], cfg, hn)
        k, v = kv
        if size < s:  # keep the trailing window, ring-ordered by position
            keep_pos = positions[s - size:]
            slots = keep_pos % size
            ck = jnp.zeros((b, size) + k.shape[2:], dtype).at[:, slots].set(
                k[:, s - size:].astype(dtype))
            cv = jnp.zeros((b, size) + v.shape[2:], dtype).at[:, slots].set(
                v[:, s - size:].astype(dtype))
            cpos = jnp.full((size,), -1, jnp.int32).at[slots].set(keep_pos)
        else:
            pad = size - s
            ck = jnp.pad(k.astype(dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
            cv = jnp.pad(v.astype(dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
            cpos = jnp.concatenate(
                [positions, jnp.full((pad,), -1, jnp.int32)])
        return x + h, {"k": ck.astype(dtype), "v": cv.astype(dtype),
                       "pos": cpos}

    x, cache = jax.lax.scan(body, x, params["blocks"],
                            **flags.scan_kwargs())
    x = L.apply_norm(params["final_norm"], cfg, x)
    logits = L.lm_logits(params["head"], params["embed"], cfg, x[:, -1:])
    return logits, cache


def decode_step(params, cfg, cache, token, index, *, dtype=jnp.bfloat16,
                window=None):
    """token: (B, 1) int32; index: scalar absolute position.

    Returns (logits (B,1,V), new_cache).
    """
    window = cfg.sliding_window if window is None else window
    x = L.embed_tokens(params["embed"], cfg, token, dtype)
    positions = jnp.full((1,), index, jnp.int32)

    def scan_body(x, xs):
        bp, ck, cv, cpos = xs
        xn = L.apply_norm(bp["attn_norm"], cfg, x)
        h, nc = L.attention(bp["attn"], cfg, xn, positions=positions,
                            window=window, cache=(ck, cv, cpos),
                            cache_index=index)
        y = x + h
        hn = L.apply_norm(bp["ffn_norm"], cfg, y)
        if "moe" in bp:
            h2, _ = apply_moe(bp["moe"], cfg, hn, capacity_factor=max(2.0, cfg.moe.capacity_factor))
        else:
            h2 = L.apply_mlp(bp["mlp"], cfg, hn)
        return y + h2, {"k": nc[0], "v": nc[1], "pos": nc[2]}

    x, new_cache = jax.lax.scan(
        scan_body, x,
        (params["blocks"], cache["k"], cache["v"], cache["pos"]),
        **flags.scan_kwargs())
    x = L.apply_norm(params["final_norm"], cfg, x)
    logits = L.lm_logits(params["head"], params["embed"], cfg, x)
    return logits, new_cache
