"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

The hallmark of RWKV-6 over v5 is the *data-dependent* per-channel decay
``w_t = exp(-exp(w0 + lora_w(x_t)))``.  State per head is an (hd, hd)
key-value outer-product matrix:

    y_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ

Full-sequence mode is a chunked scan (remat'd chunk bodies, carried state
only); decode is a single recurrence.  TPU hot-loop in ``repro/kernels/rwkv6``.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.sharding.spec import Param, param, shard_act
from repro.models import flags

SCAN_CHUNK = 256
DECAY_LORA = 64


def _dims(cfg):
    hd = cfg.rwkv.head_size
    heads = cfg.d_model // hd
    return heads, hd


def init_time_mix(key, cfg):
    h, hd = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    # per-channel decay base: spread across channels (RWKV init)
    w0 = -5.0 + 8.0 * (jnp.arange(d) / max(d - 1, 1)) ** 3.0
    return {
        "mu_r": Param(jnp.full((d,), 0.5), (None,)),
        "mu_k": Param(jnp.full((d,), 0.5), (None,)),
        "mu_v": Param(jnp.full((d,), 0.5), (None,)),
        "mu_g": Param(jnp.full((d,), 0.5), (None,)),
        "mu_w": Param(jnp.full((d,), 0.5), (None,)),
        "w_r": param(ks[0], (d, d), ("embed", "rwkv_head")),
        "w_k": param(ks[1], (d, d), ("embed", "rwkv_head")),
        "w_v": param(ks[2], (d, d), ("embed", "rwkv_head")),
        "w_g": param(ks[3], (d, d), ("embed", "rwkv_head")),
        "w_o": param(ks[4], (d, d), ("rwkv_head", "embed"),
                     scale=1.0 / math.sqrt(d)),
        # data-dependent decay LoRA (the Finch mechanism)
        "w0": Param(w0, (None,)),
        "w_lora_a": param(ks[5], (d, DECAY_LORA), ("embed", None), scale=0.01),
        "w_lora_b": param(ks[6], (DECAY_LORA, d), (None, "rwkv_head"),
                          scale=0.01),
        "u": param(ks[7], (h, hd), ("rwkv_head", None), scale=0.1),
        "ln_scale": Param(jnp.ones((d,)), (None,)),
        "ln_bias": Param(jnp.zeros((d,)), (None,)),
    }


def init_channel_mix(key, cfg):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "mu_k": Param(jnp.full((d,), 0.5), (None,)),
        "mu_r": Param(jnp.full((d,), 0.5), (None,)),
        "w_k": param(ks[0], (d, cfg.d_ff), ("embed", "mlp")),
        "w_v": param(ks[1], (cfg.d_ff, d), ("mlp", "embed"),
                     scale=1.0 / math.sqrt(cfg.d_ff)),
        "w_r": param(ks[2], (d, d), ("embed", None)),
    }


def _shift(x, prev):
    """Token shift: x_{t-1} with ``prev`` (B, D) as the t=0 predecessor."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def _group_norm(y, scale, bias, heads: int, eps: float = 1e-5):
    """Per-head group norm on (B, T, D)."""
    b, t, d = y.shape
    yf = y.reshape(b, t, heads, d // heads).astype(jnp.float32)
    mu = yf.mean(-1, keepdims=True)
    var = ((yf - mu) ** 2).mean(-1, keepdims=True)
    yf = (yf - mu) * jax.lax.rsqrt(var + eps)
    return (yf.reshape(b, t, d) * scale + bias).astype(y.dtype)


def _wkv_chunk(u, r, k, v, w, s0):
    """Sequential WKV over one chunk.

    r/k/v: (B,T,H,hd); w: (B,T,H,hd) decay in (0,1); s0: (B,H,hd,hd) f32.
    Returns (y (B,T,H,hd) f32, sT).
    """
    def step(s, inp):
        r_t, k_t, v_t, w_t = (z.astype(jnp.float32) for z in inp)  # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]                  # (B,H,hd,hd)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y

    xs = tuple(z.transpose(1, 0, 2, 3) for z in (r, k, v, w))
    sT, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), sT


def time_mix_forward(p, cfg, x, *, state=None):
    """x: (B, S, D) -> (y, (wkv_state, shift_prev))."""
    h, hd = _dims(cfg)
    b, s, d = x.shape
    prev = state[1].astype(x.dtype) if state is not None else jnp.zeros(
        (b, d), x.dtype)
    xs = _shift(x, prev)
    xr = _mix(x, xs, p["mu_r"])
    xk = _mix(x, xs, p["mu_k"])
    xv = _mix(x, xs, p["mu_v"])
    xg = _mix(x, xs, p["mu_g"])
    xw = _mix(x, xs, p["mu_w"])

    r = jnp.einsum("bsd,de->bse", xr, p["w_r"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", xk, p["w_k"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", xv, p["w_v"].astype(x.dtype))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["w_g"].astype(x.dtype)))
    # data-dependent decay (Finch): w_t = exp(-exp(w0 + lora(x_w)))
    lora = jnp.einsum("bsd,dr->bsr", jnp.tanh(xw.astype(jnp.float32)),
                      p["w_lora_a"])
    dec_log = p["w0"][None, None, :] + jnp.einsum("bsr,re->bse", lora,
                                                  p["w_lora_b"])
    w = jnp.exp(-jnp.exp(dec_log))                      # (B,S,D) f32

    r4 = shard_act(r.reshape(b, s, h, hd), "batch", "seq", "rwkv_head", None)
    k4 = shard_act(k.reshape(b, s, h, hd), "batch", "seq", "rwkv_head", None)
    v4 = shard_act(v.reshape(b, s, h, hd), "batch", "seq", "rwkv_head", None)
    w4 = shard_act(w.reshape(b, s, h, hd), "batch", "seq", "rwkv_head", None)
    s0 = (state[0] if state is not None
          else jnp.zeros((b, h, hd, hd), jnp.float32))

    chunk = min(SCAN_CHUNK, s)
    if s % chunk == 0 and s > chunk and not flags.scan_unroll:
        n = s // chunk
        resh = lambda t: t.reshape(b, n, chunk, h, hd).transpose(
            1, 0, 2, 3, 4)

        def body(carry, inp):
            r_c, k_c, v_c, w_c = inp
            y, carry = jax.checkpoint(partial(_wkv_chunk, p["u"]))(
                r_c, k_c, v_c, w_c, carry)
            return carry, y

        sT, ys = jax.lax.scan(body, s0, (resh(r4), resh(k4), resh(v4),
                                         resh(w4)))
        y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)
    else:
        y, sT = _wkv_chunk(p["u"], r4, k4, v4, w4, s0)

    y = y.reshape(b, s, d).astype(x.dtype)
    y = _group_norm(y, p["ln_scale"], p["ln_bias"], h) * g
    out = jnp.einsum("bse,ed->bsd", y, p["w_o"].astype(x.dtype))
    return shard_act(out, "batch", "seq", None), (sT, x[:, -1, :])


def channel_mix_forward(p, cfg, x, *, state=None):
    """x: (B, S, D) -> (y, shift_prev)."""
    b, s, d = x.shape
    prev = state.astype(x.dtype) if state is not None else jnp.zeros(
        (b, d), x.dtype)
    xs = _shift(x, prev)
    xk = _mix(x, xs, p["mu_k"])
    xr = _mix(x, xs, p["mu_r"])
    k = jnp.einsum("bsd,df->bsf", xk, p["w_k"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    k = shard_act(k, "batch", "seq", "mlp")
    kv = jnp.einsum("bsf,fd->bsd", k, p["w_v"].astype(x.dtype))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr,
                                  p["w_r"].astype(x.dtype)))
    return shard_act(r * kv, "batch", "seq", None), x[:, -1, :]


def rwkv_init_state(cfg, batch: int, dtype=jnp.bfloat16):
    h, hd = _dims(cfg)
    return {
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "shift_tm": jnp.zeros((batch, cfg.d_model), dtype),
        "shift_cm": jnp.zeros((batch, cfg.d_model), dtype),
    }


# ---------------------------------------------------------------------------
# Full-model assembly (attention-free decoder)
# ---------------------------------------------------------------------------


def init_block(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": {"scale": Param(jnp.ones((cfg.d_model,)), (None,)),
                "bias": Param(jnp.zeros((cfg.d_model,)), (None,))},
        "ln2": {"scale": Param(jnp.ones((cfg.d_model,)), (None,)),
                "bias": Param(jnp.zeros((cfg.d_model,)), (None,))},
        "tm": init_time_mix(k1, cfg),
        "cm": init_channel_mix(k2, cfg),
    }


def init_model(key, cfg):
    from repro.models import layers as L
    from repro.models.transformer import stack_layer_axes

    ks = jax.random.split(key, 3)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(
        jax.random.split(ks[0], cfg.num_layers))
    return {
        "embed": L.init_embedding(ks[1], cfg),
        "embed_norm": L.init_norm(cfg),
        "blocks": stack_layer_axes(blocks),
        "final_norm": L.init_norm(cfg),
        "head": L.init_lm_head(ks[2], cfg),
    }


def _apply_block(bp, cfg, x, *, state=None):
    from repro.models import layers as L

    tm_state = (state["wkv"], state["shift_tm"]) if state is not None else None
    h, (wkv, shift_tm) = time_mix_forward(
        bp["tm"], cfg, L.apply_norm(bp["ln1"], cfg, x), state=tm_state)
    x = x + h
    cm_state = state["shift_cm"] if state is not None else None
    h, shift_cm = channel_mix_forward(
        bp["cm"], cfg, L.apply_norm(bp["ln2"], cfg, x), state=cm_state)
    x = x + h
    return x, {"wkv": wkv, "shift_tm": shift_tm.astype(x.dtype),
               "shift_cm": shift_cm.astype(x.dtype)}


def forward_train(params, cfg, tokens, *, dtype=jnp.bfloat16, remat=True,
                  window=None, compute_logits=True):
    from repro.models import layers as L

    x = L.embed_tokens(params["embed"], cfg, tokens, dtype)
    x = L.apply_norm(params["embed_norm"], cfg, x)

    def body(x, bp):
        x, _ = _apply_block(bp, cfg, x)
        return x, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["blocks"],
                        **flags.scan_kwargs())
    x = L.apply_norm(params["final_norm"], cfg, x)
    logits = (L.lm_logits(params["head"], params["embed"], cfg, x)
              if compute_logits else None)
    return logits, jnp.float32(0.0), x


def init_cache(cfg, batch: int, cache_len: int = 0, *, window=None,
               dtype=jnp.bfloat16):
    """RWKV 'cache' is the recurrent state (O(1) in sequence length)."""
    h, hd = _dims(cfg)
    L_ = cfg.num_layers
    return {
        "wkv": jnp.zeros((L_, batch, h, hd, hd), jnp.float32),
        "shift_tm": jnp.zeros((L_, batch, cfg.d_model), dtype),
        "shift_cm": jnp.zeros((L_, batch, cfg.d_model), dtype),
    }


def prefill(params, cfg, tokens, *, dtype=jnp.bfloat16, window=None,
            cache_len=None):
    from repro.models import layers as L

    x = L.embed_tokens(params["embed"], cfg, tokens, dtype)
    x = L.apply_norm(params["embed_norm"], cfg, x)
    b = x.shape[0]

    def body(x, bp):
        zero = {
            "wkv": jnp.zeros((b,) + ( _dims(cfg)[0], _dims(cfg)[1],
                                      _dims(cfg)[1]), jnp.float32),
            "shift_tm": jnp.zeros((b, cfg.d_model), x.dtype),
            "shift_cm": jnp.zeros((b, cfg.d_model), x.dtype),
        }
        x, st = _apply_block(bp, cfg, x, state=zero)
        return x, st

    x, cache = jax.lax.scan(body, x, params["blocks"],
                            **flags.scan_kwargs())
    x = L.apply_norm(params["final_norm"], cfg, x)
    logits = L.lm_logits(params["head"], params["embed"], cfg, x[:, -1:])
    return logits, cache


def decode_step(params, cfg, cache, token, index, *, dtype=jnp.bfloat16,
                window=None):
    from repro.models import layers as L

    x = L.embed_tokens(params["embed"], cfg, token, dtype)
    x = L.apply_norm(params["embed_norm"], cfg, x)

    def body(x, xs):
        bp, wkv, stm, scm = xs
        x, st = _apply_block(bp, cfg, x,
                             state={"wkv": wkv, "shift_tm": stm,
                                    "shift_cm": scm})
        return x, st

    x, new_cache = jax.lax.scan(
        body, x, (params["blocks"], cache["wkv"], cache["shift_tm"],
                  cache["shift_cm"]), **flags.scan_kwargs())
    x = L.apply_norm(params["final_norm"], cfg, x)
    logits = L.lm_logits(params["head"], params["embed"], cfg, x)
    return logits, new_cache
