"""Deterministic synthetic data: learnable LM token streams, clustered
image sets (MNIST/CIFAR stand-ins for the paper's benchmarks), and the
ticket-sharded data loader that feeds training through the Sashimi queue.
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np

from repro.core.tickets import TicketQueue


# ---------------------------------------------------------------------------
# LM token streams
# ---------------------------------------------------------------------------


def make_lm_batch(rng: np.random.Generator, batch: int, seq: int,
                  vocab: int, *, noise: float = 0.1):
    """Markov-structured token batch: next = (5·prev + 17) mod V with noise.

    Learnable by any of the assigned LMs, so training-loss decrease is a
    meaningful integration check.
    """
    toks = np.empty((batch, seq + 1), np.int64)
    toks[:, 0] = rng.integers(0, vocab, size=batch)
    for t in range(seq):
        nxt = (5 * toks[:, t] + 17) % vocab
        flip = rng.random(batch) < noise
        nxt = np.where(flip, rng.integers(0, vocab, size=batch), nxt)
        toks[:, t + 1] = nxt
    return {
        "tokens": toks[:, :-1].astype(np.int32),
        "labels": toks[:, 1:].astype(np.int32),
        "mask": np.ones((batch, seq), np.float32),
    }


def lm_batches(batch: int, seq: int, vocab: int, *, seed: int = 0,
               noise: float = 0.1) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    while True:
        yield make_lm_batch(rng, batch, seq, vocab, noise=noise)


# ---------------------------------------------------------------------------
# Clustered images (MNIST / CIFAR stand-ins)
# ---------------------------------------------------------------------------


def clustered_images(n: int, *, num_classes: int = 10, image_size: int = 32,
                     channels: int = 3, seed: int = 0, spread: float = 0.35,
                     means_seed: int = 1234):
    """Gaussian class-cluster images: kNN/CNN-learnable, deterministic.
    Class means come from ``means_seed`` so train/test splits share them."""
    rng = np.random.default_rng(seed)
    means = np.random.default_rng(means_seed).normal(
        0.0, 1.0, (num_classes, image_size, image_size, channels))
    labels = rng.integers(0, num_classes, size=n)
    imgs = (means[labels]
            + rng.normal(0.0, spread,
                         (n, image_size, image_size, channels)))
    return imgs.astype(np.float32), labels.astype(np.int32)


# ---------------------------------------------------------------------------
# Ticket-sharded loader (Sashimi-driven input pipeline)
# ---------------------------------------------------------------------------


class TicketDataLoader:
    """Carves each global batch into microbatch *tickets* via the paper's
    queue, so stragglers/dead input workers are tolerated by redistribution.

    In the SPMD framework the actual step is synchronous; this loader covers
    the host-side input path (the analogue of browsers pulling work).
    """

    def __init__(self, make_microbatch, *, num_microbatches: int,
                 timeout: float = 5.0, redistribute_min: float = 0.05,
                 clock=None):
        import time as _time
        self.make_microbatch = make_microbatch
        self.num_microbatches = num_microbatches
        self.queue = TicketQueue(timeout=timeout,
                                 redistribute_min=redistribute_min,
                                 clock=clock or _time.monotonic)

    def global_batch(self, step: int, workers) -> dict:
        """Enqueue microbatch tickets, let ``workers`` produce them, then
        concatenate into a global batch (ordered, exactly-once)."""
        tids = self.queue.add_many(
            "microbatch", [(step, i) for i in range(self.num_microbatches)])
        for w in workers:
            w.drain(self.queue, self.make_microbatch)
        if not self.queue.wait_all(timeout=60):
            raise TimeoutError("input tickets unfinished")
        res = self.queue.results()
        parts = [res[t] for t in tids]
        return {
            k: np.concatenate([p[k] for p in parts], axis=0)
            for k in parts[0]
        }


class InlineWorker:
    """Trivial in-process worker for the ticket loader (tests/benchmarks
    swap in thread workers with failure profiles)."""

    def drain(self, queue: TicketQueue, fn):
        while True:
            t = queue.request()
            if t is None:
                return
            queue.submit(t.ticket_id, fn(*t.args), "inline")
