from repro.data.synthetic import (
    clustered_images,
    make_lm_batch,
    lm_batches,
    TicketDataLoader,
)

__all__ = ["clustered_images", "make_lm_batch", "lm_batches",
           "TicketDataLoader"]
