"""Logical-axis -> mesh-axis rule tables, one per distribution strategy.

Mesh axes: ('pod', 'data', 'model') multi-pod, ('data', 'model') single pod.
Logical axes used by the models:

  batch        activation batch dim                 -> ('pod','data')
  seq          sequence (only sharded for long KV)  -> usually None
  vocab        vocab dim of embedding / lm head
  embed        d_model dim of weights (FSDP shard)
  mlp          FFN hidden dim
  heads        attention query heads
  kv_heads     attention kv heads
  expert       MoE expert dim
  capacity     MoE dispatch buffer token dim
  mamba        mamba inner dim
  rwkv_head    rwkv head dim
  layers       stacked-layer leading dim (never sharded)
  conv_out     CNN channels
"""
from __future__ import annotations

from typing import Mapping

_DATA = ("pod", "data")  # resolved against the actual mesh axis names


def _filter(rules: Mapping, mesh_axes) -> dict:
    """Drop mesh axes that don't exist in the current mesh (e.g. 'pod')."""
    out = {}
    for k, v in rules.items():
        if v is None:
            out[k] = None
        elif isinstance(v, tuple):
            kept = tuple(a for a in v if a in mesh_axes)
            out[k] = kept if len(kept) > 1 else (kept[0] if kept else None)
        else:
            out[k] = v if v in mesh_axes else None
    return out


# FSDP over 'data' + tensor/expert parallel over 'model'.  This is the
# modern baseline mapping; also used for all inference shapes.
FSDP_TP = {
    "batch": _DATA,
    "seq": None,
    "kv_seq": "data",      # sequence-sharded KV cache for long decode
    "vocab": "model",
    "embed": "data",
    "mlp": "model",
    "heads": "model",
    "kv_heads": None,      # kv heads are few (<=8); replicate, shard q heads
    "expert": "model",
    "capacity": "data",
    "mamba": "model",
    "rwkv_head": "model",
    "layers": None,
    "conv_out": None,
}

# MLitB-style pure data parallelism: weights replicated, grads all-reduced.
DP_FULL = {k: None for k in FSDP_TP} | {"batch": _DATA, "kv_seq": None}

# Paper's split strategy.  Head placement, measured on the 16x16 dry-run
# (see EXPERIMENTS.md §Perf, iteration 0):
#   * ('data','model') "parameter-server" vocab sharding — the literal
#     mapping of "FC on the server" — makes GSPMD all-gather the full-batch
#     dlogits over the data axis: 16x head FLOPs, +105 GiB temp at train_4k.
#     The paper's byte-saving regime requires B·S < 2·V (small batches); at
#     train_4k B·S ≈ 1M >> 2V.  Kept as the opt-in 'split_server_sharded'
#     rule set for decode/small-batch fine-tuning regimes.
#   * default SPLIT therefore places the head like FSDP_TP; the paper's
#     transferable contribution on a fast-interconnect mesh is the
#     CONCURRENCY (stale client head + feature-replay server training),
#     which removes the head-update from the critical path.
SPLIT = dict(FSDP_TP) | {
    "head_vocab": "model",
    "head_embed": "data",
}
SPLIT_PS = dict(FSDP_TP) | {
    "head_vocab": ("data", "model"),
    "head_embed": None,
}
FSDP_TP = dict(FSDP_TP) | {"head_vocab": "model", "head_embed": "data"}
DP_FULL = dict(DP_FULL) | {"head_vocab": None, "head_embed": None}

AXIS_RULES = {
    "dp_full": DP_FULL,
    "fsdp_tp": FSDP_TP,
    "split_concurrent": SPLIT,
    "split_sequential": SPLIT,
    "split_server_sharded": SPLIT_PS,
}


def rules_for_strategy(strategy: str, mesh_axes) -> dict:
    """Logical-axis -> mesh-axis rule table for a strategy, filtered to
    the axes present on ``mesh_axes``."""
    if strategy not in AXIS_RULES:
        raise KeyError(f"unknown strategy {strategy!r}; known {sorted(AXIS_RULES)}")
    return _filter(AXIS_RULES[strategy], tuple(mesh_axes))
