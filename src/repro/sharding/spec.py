"""Logical-axis parameter/activation sharding machinery.

Parameters are created as ``Param(value, axes)`` where ``axes`` is a tuple of
*logical* axis names (or ``None``).  A strategy supplies *rules* mapping
logical names to mesh axes; ``to_pspec`` resolves them to PartitionSpecs.
Activation constraints (``shard_act``) are no-ops unless a ``ShardCtx`` is
installed, so all model code runs unchanged on a single CPU device.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Param pytree node
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclass
class Param:
    """A parameter value annotated with logical sharding axes."""

    value: Any
    axes: tuple

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)


def _is_param(x) -> bool:
    return isinstance(x, Param)


def values_tree(tree):
    """Strip Param wrappers -> plain array pytree."""
    return jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=_is_param)


def axes_tree(tree):
    """Extract the logical-axes pytree (same structure as values_tree)."""
    return jax.tree_util.tree_map(lambda p: p.axes, tree, is_leaf=_is_param)


def param(key, shape, axes, *, dtype=jnp.float32, init: str = "normal",
          scale: float | None = None) -> Param:
    """Create an annotated parameter."""
    assert len(axes) == len(shape), (shape, axes)
    if init == "zeros":
        v = jnp.zeros(shape, dtype)
    elif init == "ones":
        v = jnp.ones(shape, dtype)
    elif init == "normal":
        if scale is None:
            scale = 1.0 / (shape[0] ** 0.5) if len(shape) >= 2 else 0.02
        v = (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
    else:
        raise ValueError(init)
    return Param(v, tuple(axes))


def abstract_params(init_fn: Callable[[], Any]):
    """eval_shape an init function -> pytree of ShapeDtypeStruct (no alloc)."""
    return jax.eval_shape(init_fn)


# ---------------------------------------------------------------------------
# Logical axis -> mesh axis resolution
# ---------------------------------------------------------------------------


def to_pspec(axes: Sequence, rules: Mapping[str, Any], *, mesh=None,
             shape: Sequence[int] | None = None) -> P:
    """Resolve a tuple of logical axes to a PartitionSpec under ``rules``.

    With ``mesh``+``shape``, any mapping whose mesh-axis product does not
    divide the tensor dim is dropped (e.g. 12 heads on a 16-way model axis).
    """
    out = []
    used: list = []
    for i, ax in enumerate(axes):
        if ax is None:
            out.append(None)
            continue
        mesh_ax = rules.get(ax)
        if mesh_ax is not None:
            flat = mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
            if any(m in used for m in flat):
                mesh_ax = None
            elif mesh is not None and shape is not None:
                n = 1
                for m in flat:
                    n *= mesh.shape[m]
                if shape[i] % n != 0:
                    # try a prefix of the axes that does divide
                    kept = []
                    n = 1
                    for m in flat:
                        if shape[i] % (n * mesh.shape[m]) == 0:
                            kept.append(m)
                            n *= mesh.shape[m]
                    mesh_ax = (tuple(kept) if len(kept) > 1
                               else (kept[0] if kept else None))
                    if mesh_ax is not None:
                        used.extend(kept)
                else:
                    used.extend(flat)
            else:
                used.extend(flat)
        out.append(mesh_ax)
    return P(*out)


def spec_tree(axes: Any, rules: Mapping[str, Any], mesh=None):
    """Map an axes pytree to PartitionSpecs (or NamedShardings if mesh given)."""

    def one(ax):
        ps = to_pspec(ax, rules)
        return NamedSharding(mesh, ps) if mesh is not None else ps

    return jax.tree_util.tree_map(
        one, axes, is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# Activation sharding context
# ---------------------------------------------------------------------------


@dataclass
class ShardCtx:
    """Ambient (mesh, rules) pair consulted by :func:`shard_act`."""

    mesh: Any
    rules: Mapping[str, Any]


_tls = threading.local()


def current_ctx() -> ShardCtx | None:
    """The thread-local sharding context, or None outside one."""
    return getattr(_tls, "ctx", None)


@contextlib.contextmanager
def use_shard_ctx(ctx: ShardCtx | None):
    """Install ``ctx`` as the ambient sharding context for the block."""
    prev = getattr(_tls, "ctx", None)
    _tls.ctx = ctx
    try:
        yield
    finally:
        _tls.ctx = prev


def shard_act(x, *axes):
    """Constrain an activation's sharding by logical axes; no-op w/o context."""
    ctx = current_ctx()
    if ctx is None or ctx.mesh is None:
        return x
    ps = to_pspec(axes, ctx.rules, mesh=ctx.mesh, shape=x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, ps))
