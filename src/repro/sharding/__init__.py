from repro.sharding.spec import (
    Param,
    ShardCtx,
    abstract_params,
    axes_tree,
    current_ctx,
    param,
    shard_act,
    spec_tree,
    to_pspec,
    use_shard_ctx,
    values_tree,
)
from repro.sharding.rules import AXIS_RULES, rules_for_strategy

__all__ = [
    "AXIS_RULES",
    "Param",
    "ShardCtx",
    "abstract_params",
    "axes_tree",
    "current_ctx",
    "param",
    "rules_for_strategy",
    "shard_act",
    "spec_tree",
    "to_pspec",
    "use_shard_ctx",
    "values_tree",
]
