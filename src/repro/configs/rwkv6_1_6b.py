"""RWKV-6 "Finch" 1.6B — attention-free, data-dependent decay. [arXiv:2404.05892]"""
import dataclasses

from repro.configs.base import ArchConfig, RWKVConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,              # d_model / head_size
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    head_dim=64,
    norm="layernorm",
    act="relu2",               # channel-mix uses squared relu
    rwkv=RWKVConfig(head_size=64),
    source="arXiv:2404.05892",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="rwkv6-smoke",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=896,
        vocab_size=1024,
        rwkv=RWKVConfig(head_size=64),
    )
