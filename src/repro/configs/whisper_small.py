"""Whisper-small — encoder-decoder audio backbone; conv/mel frontend STUBBED.

input_specs() supplies precomputed frame embeddings (B, 1500, d_model).
[arXiv:2212.04356]
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,            # decoder layers
    encoder_layers=12,
    encoder_seq_len=1500,     # 30 s audio -> 1500 frames after conv frontend (stub)
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    head_dim=64,
    qkv_bias=True,
    norm="layernorm",
    act="gelu",
    source="arXiv:2212.04356",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="whisper-smoke",
        num_layers=2,
        encoder_layers=2,
        encoder_seq_len=64,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab_size=1024,
    )
