"""Qwen3-4B — dense, qk_norm, GQA kv=8. [hf:Qwen/Qwen3-8B family]"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b",
    family="dense",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=9728,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=False,
    qk_norm=True,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=1000000.0,
    source="hf:Qwen/Qwen3-8B",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="qwen3-4b-smoke",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=704,
        vocab_size=1024,
    )
