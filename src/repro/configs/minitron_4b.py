"""Minitron-4B — pruned Nemotron, GQA kv=8, squared-relu MLP. [arXiv:2407.14679]"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=9216,
    vocab_size=256000,
    head_dim=128,
    qkv_bias=False,
    norm="layernorm",
    act="relu2",
    rope_theta=10000.0,
    source="arXiv:2407.14679",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="minitron-smoke",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=768,
        vocab_size=1024,
    )
