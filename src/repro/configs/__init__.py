from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    ArchConfig,
    InputShape,
    MambaConfig,
    MoEConfig,
    RWKVConfig,
    RunConfig,
    all_arch_configs,
    get_arch_config,
    get_smoke_config,
)

__all__ = [
    "ARCH_IDS",
    "INPUT_SHAPES",
    "ArchConfig",
    "InputShape",
    "MambaConfig",
    "MoEConfig",
    "RWKVConfig",
    "RunConfig",
    "all_arch_configs",
    "get_arch_config",
    "get_smoke_config",
]
