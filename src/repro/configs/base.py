"""Config system: architecture + run configuration dataclasses.

Every assigned architecture gets one module in this package defining
``CONFIG`` (the exact full-size config, used only via the AOT dry-run) and
``smoke_config()`` (a reduced same-family variant for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Any, Sequence

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0           # 0 => dense FFN
    num_experts_per_tok: int = 0   # top-k
    # capacity factor for expert-parallel dispatch (dense one-hot dispatch
    # is exact; capacity only bounds the per-expert buffer in dispatch mode)
    capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.01


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 => d_model // 16


@dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64


@dataclass(frozen=True)
class ArchConfig:
    """Architecture hyper-parameters.

    ``family`` selects the block assembly:
      dense | moe | hybrid (mamba+attn interleave) | ssm (rwkv6) |
      encdec (whisper) | vlm (decoder + patch-embedding stub) | cnn
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 => d_model // num_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    tie_embeddings: bool = False
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    act: str = "swiglu"                # swiglu | gelu
    rope_theta: float = 10000.0
    max_seq_len: int = 131072
    sliding_window: int = 0            # 0 => full attention
    moe: MoEConfig = field(default_factory=MoEConfig)
    moe_layer_period: int = 1          # every k-th layer is MoE (jamba: 2)
    mamba: MambaConfig = field(default_factory=MambaConfig)
    rwkv: RWKVConfig = field(default_factory=RWKVConfig)
    attn_layer_period: int = 0         # hybrid: 1 attn per this many layers
    attn_layer_offset: int = 0
    # enc-dec / vlm stubs
    encoder_layers: int = 0
    encoder_seq_len: int = 0           # whisper: 1500 frames
    num_patches: int = 0               # vlm: vision patch embeddings
    # provenance
    source: str = ""

    def __post_init__(self):
        # keep field order stable for dataclasses.replace users
        pass

    # embedding/head tables are padded so the vocab dim divides any mesh
    # axis combination (Megatron-style); labels never reference pad rows.
    vocab_pad_multiple: int = 512

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab_size + m - 1) // m * m

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_moe(self) -> bool:
        return self.moe.num_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if long_500k decode is native (sub-quadratic) for this family."""
        return self.family in ("ssm", "hybrid")

    def with_sliding_window(self, window: int) -> "ArchConfig":
        return dataclasses.replace(self, sliding_window=window)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline term)."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic

        return count_params_analytic(self, active_only=True)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Run config (training/serving hyper-params + distribution strategy)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RunConfig:
    arch: str = "qwen1.5-0.5b"
    shape: str = "train_4k"
    strategy: str = "dp_full"      # dp_full | split_concurrent | split_sequential
    optimizer: str = "adagrad"     # paper's modified adagrad by default
    learning_rate: float = 1e-2
    adagrad_beta: float = 1.0      # the paper's β (inside the sqrt)
    weight_decay: float = 0.0
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    head_sync_period: int = 4      # split_concurrent: stale head refresh K
    grad_accum: int = 1            # microbatches per step (gradient accumulation)
    # decode activation layout: "batch_sharded" | "replicated_batch" | "auto"
    # (auto -> replicated_batch + 2D KV sharding when the per-model-shard
    # weight bytes exceed ~2 GiB, i.e. when per-step FSDP weight gathers
    # would dominate; see EXPERIMENTS.md §Perf, jamba decode iterations)
    decode_layout: str = "auto"
    # fused vocab-chunked head+loss (full logits never materialise);
    # 0 = off.  Applies to dp_full/fsdp_tp/split_sequential train paths.
    loss_chunks: int = 0
    seed: int = 0
    steps: int = 10
    log_every: int = 1
    # sashimi ticket scheduler
    ticket_timeout_s: float = 300.0   # paper: five minutes
    ticket_redistribute_min_s: float = 10.0  # paper: at least 10 seconds
    microbatch_per_ticket: int = 1
    multi_pod: bool = False


ARCH_IDS: Sequence[str] = (
    "dbrx-132b",
    "qwen1.5-0.5b",
    "qwen3-moe-30b-a3b",
    "qwen3-4b",
    "command-r-35b",
    "whisper-small",
    "jamba-1.5-large-398b",
    "internvl2-26b",
    "rwkv6-1.6b",
    "minitron-4b",
)

_MODULE_FOR_ARCH = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_arch_config(name: str) -> ArchConfig:
    """Load the full-size config for an assigned architecture id."""
    if name not in _MODULE_FOR_ARCH:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULE_FOR_ARCH)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR_ARCH[name]}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    """Load the reduced smoke-test config (2 layers, d_model<=512, <=4 experts)."""
    if name not in _MODULE_FOR_ARCH:
        raise KeyError(f"unknown arch {name!r}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR_ARCH[name]}")
    return mod.smoke_config()


def all_arch_configs() -> dict[str, ArchConfig]:
    return {a: get_arch_config(a) for a in ARCH_IDS}
