"""Jamba-1.5-Large 398B — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

72 layers = 9 periods of (7 mamba + 1 attention); MoE every other layer.
[arXiv:2403.19887]
"""
import dataclasses

from repro.configs.base import ArchConfig, MambaConfig, MoEConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    qkv_bias=False,
    norm="rmsnorm",
    act="swiglu",
    moe=MoEConfig(num_experts=16, num_experts_per_tok=2),
    moe_layer_period=2,        # every other layer's FFN is MoE
    attn_layer_period=8,       # 1 attention layer per 8 (1:7 attn:mamba)
    attn_layer_offset=4,
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    source="arXiv:2403.19887",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="jamba-smoke",
        num_layers=2,              # 1 mamba + 1 attention
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=1024,
        moe=MoEConfig(num_experts=4, num_experts_per_tok=2),
        moe_layer_period=2,
        attn_layer_period=2,
        attn_layer_offset=1,
    )
