"""Command-R 35B — dense, GQA kv=8, no biases, layernorm. [hf:CohereForAI/c4ai-command-r-v01]"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    head_dim=128,
    qkv_bias=False,
    tie_embeddings=True,
    norm="layernorm",
    act="swiglu",
    rope_theta=8000000.0,
    source="hf:CohereForAI/c4ai-command-r-v01",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="command-r-smoke",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=704,
        vocab_size=1024,
    )
