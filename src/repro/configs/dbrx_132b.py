"""DBRX-132B — fine-grained MoE, 16 experts top-4. [hf:databricks/dbrx-base]"""
import dataclasses

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    head_dim=128,
    qkv_bias=False,
    norm="layernorm",
    act="swiglu",
    rope_theta=500000.0,
    moe=MoEConfig(num_experts=16, num_experts_per_tok=4),
    source="hf:databricks/dbrx-base",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="dbrx-smoke",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=448,
        vocab_size=1024,
        moe=MoEConfig(num_experts=4, num_experts_per_tok=2),
    )
