"""Qwen1.5-0.5B — dense, QKV bias, MHA (kv=16). [hf:Qwen/Qwen1.5-0.5B]"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    head_dim=64,
    qkv_bias=True,
    tie_embeddings=True,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=1000000.0,
    source="hf:Qwen/Qwen1.5-0.5B",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="qwen1.5-smoke",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=704,
        vocab_size=1024,
    )
