"""The paper's own deep CNNs (Sukiyaki benchmarks, Figures 2 and 4).

Figure-2 net (stand-alone benchmark, CIFAR-10): three 5x5 conv layers
(16/20/20 maps) each followed by activation + 2x2 max-pool, then a
fully-connected 320 -> 10 softmax layer.  Mini-batch 50.
"""
from dataclasses import dataclass, field
from typing import Sequence


@dataclass(frozen=True)
class ConvSpec:
    out_channels: int
    kernel: int = 5
    pool: int = 2          # max-pool window/stride after activation


@dataclass(frozen=True)
class CNNConfig:
    name: str = "paper-cnn-fig2"
    image_size: int = 32
    in_channels: int = 3
    num_classes: int = 10
    convs: Sequence[ConvSpec] = field(
        default_factory=lambda: (
            ConvSpec(16), ConvSpec(20), ConvSpec(20),
        )
    )
    fc_hidden: Sequence[int] = ()   # hidden widths of the FC classifier
    batch_size: int = 50   # paper: 50 images per mini-batch

    @property
    def feature_dim(self) -> int:
        size = self.image_size
        for c in self.convs:
            size //= c.pool
        return size * size * self.convs[-1].out_channels


FIG2_CNN = CNNConfig()

# Figure-4 net (distributed benchmark) — same family, slightly larger maps.
FIG4_CNN = CNNConfig(
    name="paper-cnn-fig4",
    convs=(ConvSpec(32), ConvSpec(32), ConvSpec(64)),
    fc_hidden=(512,),   # heavier server-side classifier (distributed bench)
)

# Fabric cell net (benchmarks/federated_training.py, tests): the Fig-2
# family scaled down so a real conv→pool→softmax gradient shard runs in
# a CI-sized federated round — still every layer kind of the paper net.
FABRIC_CNN = CNNConfig(
    name="paper-cnn-fabric", image_size=16,
    convs=(ConvSpec(8), ConvSpec(8)), batch_size=32,
)


def smoke_config() -> CNNConfig:
    return CNNConfig(name="paper-cnn-smoke", image_size=16,
                     convs=(ConvSpec(8), ConvSpec(8)), batch_size=4)
