"""InternVL2-26B — InternLM2-20B language backbone; InternViT frontend STUBBED.

input_specs() supplies precomputed patch embeddings (B, num_patches, d_model)
prepended to the token sequence. [arXiv:2404.16821]
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    head_dim=128,
    qkv_bias=False,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=1000000.0,
    num_patches=256,          # one image tile after pixel-shuffle projector (stub)
    source="arXiv:2404.16821",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="internvl2-smoke",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=512,
        vocab_size=1024,
        num_patches=16,
    )
