"""Qwen3-30B-A3B — 128-expert top-8 MoE, qk_norm, GQA kv=4. [hf:Qwen/Qwen3-30B-A3B]"""
import dataclasses

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=768,  # per-expert FFN width (fine-grained experts)
    vocab_size=151936,
    head_dim=128,
    qkv_bias=False,
    qk_norm=True,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=1000000.0,
    moe=MoEConfig(num_experts=128, num_experts_per_tok=8),
    source="hf:Qwen/Qwen3-30B-A3B",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        name="qwen3-moe-smoke",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=128,
        vocab_size=1024,
        moe=MoEConfig(num_experts=4, num_experts_per_tok=2),
    )
