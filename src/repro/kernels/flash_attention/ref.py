"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None):
    """q: (B, Hq, Sq, hd); k/v: (B, Hkv, Skv, hd) -> (B, Hq, Sq, hd)."""
    b, hq, sq, hd = q.shape
    _, hkv, skv, _ = k.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    g = hq // hkv
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqe,bhke->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhke->bhqe", w,
                      v.astype(jnp.float32)).astype(q.dtype)
