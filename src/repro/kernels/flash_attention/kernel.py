"""Flash attention TPU kernel (Pallas): online-softmax over KV blocks.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — the innermost KV
dimension is sequential, carrying (m, l, acc) running statistics in VMEM
scratch; the output block is revisited and written on the last KV step.
GQA is handled in the BlockSpec index maps (each q head reads its KV group's
head — no materialised repeat).  Causal and sliding-window masking skip
fully-masked KV blocks entirely.

VMEM per grid step ≈ BQ·hd (q, acc) + 2·BK·hd (k, v) + scores BQ·BK, all
fp32 in scratch — with the default BQ=BK=256, hd=128 that is ~0.7 MB, well
inside the ~16 MB VMEM budget, and the 128-multiple tile shapes keep the
MXU aligned.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 256
DEFAULT_BK = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, bq: int, bk: int,
                  kv_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq
    k_start = ik * bk

    # skip KV blocks that are fully masked (above the causal diagonal or
    # entirely below the sliding window)
    run = jnp.bool_(True)
    if causal:
        run &= k_start <= q_start + bq - 1
    if window:
        run &= (k_start + bk) > (q_start - window + 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)         # (BQ, hd)
        k = k_ref[0, 0].astype(jnp.float32)         # (BK, hd)
        v = v_ref[0, 0].astype(jnp.float32)         # (BK, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (BQ, BK)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                          # (BQ, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                       # (BQ, BK)
        alpha = jnp.exp(m_prev - m_new)              # (BQ, 1)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _writeout():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           scale: float | None = None,
                           block_q: int = DEFAULT_BQ,
                           block_k: int = DEFAULT_BK,
                           interpret: bool = True):
    """q: (B, Hq, Sq, hd); k/v: (B, Hkv, Skv, hd) -> (B, Hq, Sq, hd)."""
    b, hq, sq, hd = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    # pad seq dims to block multiples
    sq_p = (sq + bq - 1) // bq * bq
    skv_p = (skv + bk - 1) // bk * bk
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))

    grid = (b, hq, sq_p // bq, skv_p // bk)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, kv_len=skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, h, i, j, g=g: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, h, i, j, g=g: (b_, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq, :]
