"""Jit'd public wrapper for the flash attention kernel.

Accepts the model's (B, S, H, hd) layout, handles GQA, picks interpret mode
automatically off-TPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    interpret: bool | None = None):
    """q: (B, Sq, Hq, hd); k/v: (B, Skv, Hkv, hd) -> (B, Sq, Hq, hd)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_kernel(qt, kt, vt, causal=causal, window=window,
                                 interpret=interp)
    return out.transpose(0, 2, 1, 3)
