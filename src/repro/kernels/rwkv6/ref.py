"""Pure-jnp oracle for the RWKV-6 WKV kernel (lax.scan recurrence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_ref(r, k, v, w, u, s0):
    """Same contract as kernel.wkv_kernel."""
    def step(s, inp):
        r_t, k_t, v_t, w_t = (z.astype(jnp.float32) for z in inp)  # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", r_t,
                       s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y

    xs = tuple(z.transpose(1, 0, 2, 3) for z in (r, k, v, w))
    sT, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), sT
