"""Jit'd public wrapper for the RWKV-6 WKV kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.rwkv6.kernel import wkv_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("interpret",))
def wkv(r, k, v, w, u, s0, *, interpret: bool | None = None):
    """r/k/v/w: (B,T,H,hd); u: (H,hd); s0: (B,H,hd,hd) -> (y, sT)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    return wkv_kernel(r, k, v, w, u, s0, interpret=interp)
