"""RWKV-6 WKV recurrence TPU kernel (Pallas).

    y_t = r_t · (S_{t-1} + diag(u·k_t) v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ

Grid: (batch, heads, num_time_blocks) — time is the sequential innermost
dimension; the (hd, hd) state matrix lives in VMEM scratch and is carried
across time blocks.  Inside a block the recurrence is a ``fori_loop`` over
single steps (rank-1 update + matvec on an (hd, hd) tile; hd=64 keeps the
tile lane-aligned).  Outputs: per-token y and the final state (for the
prefill→decode handoff).  VMEM per step ≈ 4·BT·hd inputs + hd² state ≈
0.15 MB at BT=128, hd=64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BT = 128


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
                state_ref, *, bt: int):
    it = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(it == 0)
    def _init():
        state_ref[...] = s0_ref[...][0, 0].astype(jnp.float32)

    u = u_ref[...][0].astype(jnp.float32)                # (hd,)
    one = pl.dslice(0, 1)  # python-int indices break 0.4.x interpret mode

    def step(t, _):
        tt = pl.dslice(t, 1)
        r_t = pl.load(r_ref, (one, tt, one,
                              slice(None)))[0, 0, 0].astype(jnp.float32)
        k_t = pl.load(k_ref, (one, tt, one,
                              slice(None)))[0, 0, 0].astype(jnp.float32)
        v_t = pl.load(v_ref, (one, tt, one,
                              slice(None)))[0, 0, 0].astype(jnp.float32)
        w_t = pl.load(w_ref, (one, tt, one,
                              slice(None)))[0, 0, 0].astype(jnp.float32)
        s = state_ref[...]                               # (hd_k, hd_v)
        kv = k_t[:, None] * v_t[None, :]
        att = s + (u * k_t)[:, None] * v_t[None, :]
        y = jnp.einsum("k,kv->v", r_t, att)
        pl.store(y_ref, (one, tt, one, slice(None)),
                 y[None, None, None].astype(y_ref.dtype))
        state_ref[...] = w_t[:, None] * s + kv
        return 0

    jax.lax.fori_loop(0, bt, step, 0)

    @pl.when(it == nt - 1)
    def _writeout():
        sT_ref[...] = state_ref[...][None, None].astype(sT_ref.dtype)


def wkv_kernel(r, k, v, w, u, s0, *, block_t: int = DEFAULT_BT,
               interpret: bool = True):
    """r/k/v/w: (B, T, H, hd); u: (H, hd); s0: (B, H, hd, hd) f32.

    Returns (y (B, T, H, hd) f32-cast-to-input-dtype, sT (B, H, hd, hd) f32).
    """
    b, t, h, hd = r.shape
    bt = min(block_t, t)
    t_p = (t + bt - 1) // bt * bt
    if t_p != t:
        pad = ((0, 0), (0, t_p - t), (0, 0), (0, 0))
        r = jnp.pad(r, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        w = jnp.pad(w, pad, constant_values=1.0)  # identity decay on pad

    grid = (b, h, t_p // bt)
    seq_spec = pl.BlockSpec((1, bt, 1, hd), lambda b_, h_, i: (b_, i, h_, 0))
    y, sT = pl.pallas_call(
        functools.partial(_wkv_kernel, bt=bt),
        grid=grid,
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, hd), lambda b_, h_, i: (h_, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b_, h_, i: (b_, h_, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, hd, hd), lambda b_, h_, i: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t_p, h, hd), r.dtype),
            jax.ShapeDtypeStruct((b, h, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y[:, :t], sT
