"""Jit'd public wrapper for the Mamba selective-scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.mamba.kernel import mamba_scan_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("interpret",))
def mamba_scan(dt, x, b_t, c_t, a, h0, *, interpret: bool | None = None):
    """dt/x: (B,T,DI); b_t/c_t: (B,T,ds); a: (DI,ds); h0: (B,DI,ds)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    return mamba_scan_kernel(dt, x, b_t, c_t, a, h0, interpret=interp)
