"""Pure-jnp oracle for the Mamba selective-scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_scan_ref(dt, x, b_t, c_t, a, h0):
    """Same contract as kernel.mamba_scan_kernel."""
    af = a.astype(jnp.float32)

    def step(h, inp):
        dt_t, x_t, b_tt, c_tt = (z.astype(jnp.float32) for z in inp)
        da = jnp.exp(dt_t[:, :, None] * af[None])
        h = da * h + (dt_t * x_t)[:, :, None] * b_tt[:, None, :]
        y = jnp.einsum("bcs,bs->bc", h, c_tt)
        return h, y

    xs = tuple(z.transpose(1, 0, 2) for z in (dt, x, b_t, c_t))
    hT, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2), hT
