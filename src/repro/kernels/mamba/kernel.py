"""Selective-SSM (Mamba) scan TPU kernel (Pallas).

    h_t = exp(Δ_t ⊙ A) h_{t-1} + (Δ_t ⊙ x_t) B_tᵀ
    y_t = h_t C_t + D x_t   (D-skip applied in the wrapper)

Grid: (batch, channel_blocks, time_blocks) — time is the sequential
innermost dimension; the (BC, d_state) state tile is carried in VMEM
scratch.  Channels (d_inner) are blocked at 512 lanes; B_t/C_t (d_state
columns) are shared across channel blocks via their index map.  VMEM per
step ≈ BT·BC (dt, x) + 2·BT·ds (B, C) + BC·ds state ≈ 0.6 MB at
BT=64, BC=512, ds=16.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BT = 64
DEFAULT_BC = 512


def _mamba_kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, h0_ref, y_ref, hT_ref,
                  state_ref, *, bt: int):
    it = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(it == 0)
    def _init():
        state_ref[...] = h0_ref[...][0].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)                    # (BC, ds)
    one = pl.dslice(0, 1)  # python-int indices break 0.4.x interpret mode

    def step(t, _):
        tt = pl.dslice(t, 1)
        dt_t = pl.load(dt_ref, (one, tt,
                                slice(None)))[0, 0].astype(jnp.float32)
        x_t = pl.load(x_ref, (one, tt,
                              slice(None)))[0, 0].astype(jnp.float32)
        b_t = pl.load(b_ref, (one, tt,
                              slice(None)))[0, 0].astype(jnp.float32)
        c_t = pl.load(c_ref, (one, tt,
                              slice(None)))[0, 0].astype(jnp.float32)
        h = state_ref[...]                                # (BC, ds)
        da = jnp.exp(dt_t[:, None] * a)
        h = da * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y = jnp.einsum("cs,s->c", h, c_t)
        pl.store(y_ref, (one, tt, slice(None)),
                 y[None, None].astype(y_ref.dtype))
        state_ref[...] = h
        return 0

    jax.lax.fori_loop(0, bt, step, 0)

    @pl.when(it == nt - 1)
    def _writeout():
        hT_ref[...] = state_ref[...][None].astype(hT_ref.dtype)


def mamba_scan_kernel(dt, x, b_t, c_t, a, h0, *, block_t: int = DEFAULT_BT,
                      block_c: int = DEFAULT_BC, interpret: bool = True):
    """dt/x: (B, T, DI); b_t/c_t: (B, T, ds); a: (DI, ds);
    h0: (B, DI, ds) f32.  Returns (y (B,T,DI) f32, hT (B, DI, ds) f32).
    """
    b, t, di = dt.shape
    ds = b_t.shape[-1]
    bc = min(block_c, di)
    bt = min(block_t, t)
    assert di % bc == 0, (di, bc)
    t_p = (t + bt - 1) // bt * bt
    if t_p != t:
        pad3 = ((0, 0), (0, t_p - t), (0, 0))
        dt = jnp.pad(dt, pad3)
        x = jnp.pad(x, pad3)
        b_t = jnp.pad(b_t, pad3)
        c_t = jnp.pad(c_t, pad3)

    grid = (b, di // bc, t_p // bt)
    chan_spec = pl.BlockSpec((1, bt, bc), lambda b_, c, i: (b_, i, c))
    state_spec = pl.BlockSpec((1, bt, ds), lambda b_, c, i: (b_, i, 0))
    y, hT = pl.pallas_call(
        functools.partial(_mamba_kernel, bt=bt),
        grid=grid,
        in_specs=[
            chan_spec, chan_spec, state_spec, state_spec,
            pl.BlockSpec((bc, ds), lambda b_, c, i: (c, 0)),
            pl.BlockSpec((1, bc, ds), lambda b_, c, i: (b_, c, 0)),
        ],
        out_specs=[
            chan_spec,
            pl.BlockSpec((1, bc, ds), lambda b_, c, i: (b_, c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t_p, di), jnp.float32),
            jax.ShapeDtypeStruct((b, di, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bc, ds), jnp.float32)],
        interpret=interpret,
    )(dt, x, b_t, c_t, a, h0)
    return y[:, :t], hT
