"""Fused modified-AdaGrad update TPU kernel (Pallas) — the paper's optimizer:

    acc += g²;   θ -= α · g / sqrt(β + acc)

One fused elementwise pass over (param, grad, acc) producing (param', acc')
— 3 reads + 2 writes instead of the ~7 transfers of the unfused update.
Tensors are flattened and tiled (8, 1024) to match the VPU lane layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 8
BLOCK_COLS = 1024


def _adagrad_kernel(p_ref, g_ref, a_ref, po_ref, ao_ref, *, lr: float,
                    beta: float, weight_decay: float):
    g = g_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    if weight_decay:
        g = g + weight_decay * p
    a = a_ref[...] + jnp.square(g)
    step = lr * g * jax.lax.rsqrt(beta + a)
    po_ref[...] = (p - step).astype(po_ref.dtype)
    ao_ref[...] = a


def adagrad_kernel(p, g, acc, *, lr: float, beta: float = 1.0,
                   weight_decay: float = 0.0, interpret: bool = True):
    """p/g: any shape; acc: f32 same shape.  Returns (p', acc')."""
    shape, dtype = p.shape, p.dtype
    n = p.size
    cols = BLOCK_COLS
    rows_per_block = BLOCK_ROWS
    block = rows_per_block * cols
    n_p = (n + block - 1) // block * block
    flat = lambda x, dt: jnp.pad(x.reshape(-1).astype(dt),
                                 (0, n_p - n)).reshape(n_p // cols, cols)
    pf = flat(p, dtype)
    gf = flat(g, g.dtype)
    af = flat(acc, jnp.float32)

    grid = (n_p // block,)
    spec = pl.BlockSpec((rows_per_block, cols), lambda i: (i, 0))
    po, ao = pl.pallas_call(
        functools.partial(_adagrad_kernel, lr=lr, beta=beta,
                          weight_decay=weight_decay),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(pf.shape, dtype),
            jax.ShapeDtypeStruct(af.shape, jnp.float32),
        ],
        interpret=interpret,
    )(pf, gf, af)
    return (po.reshape(-1)[:n].reshape(shape),
            ao.reshape(-1)[:n].reshape(shape))
