"""Jit'd public wrapper for the fused modified-AdaGrad kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.adagrad.kernel import adagrad_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("lr", "beta", "weight_decay", "interpret"))
def adagrad_update(p, g, acc, *, lr: float, beta: float = 1.0,
                   weight_decay: float = 0.0,
                   interpret: bool | None = None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return adagrad_kernel(p, g, acc, lr=lr, beta=beta,
                          weight_decay=weight_decay, interpret=interp)
