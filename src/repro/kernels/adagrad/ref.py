"""Pure-jnp oracle for the fused modified-AdaGrad kernel.

The oracle IS the optimizer's own per-leaf update
(``repro.optim.adagrad_math.adagrad_leaf_update``) — one shared pure
function, so the kernel reference and ``repro.optim.optimizers.adagrad``
cannot drift.
"""
from __future__ import annotations

from repro.optim.adagrad_math import adagrad_leaf_update


def adagrad_ref(p, g, acc, *, lr: float, beta: float = 1.0,
                weight_decay: float = 0.0):
    return adagrad_leaf_update(p, g, acc, lr=lr, beta=beta,
                               weight_decay=weight_decay)
