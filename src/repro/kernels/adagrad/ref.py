"""Pure-jnp oracle for the fused modified-AdaGrad kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adagrad_ref(p, g, acc, *, lr: float, beta: float = 1.0,
                weight_decay: float = 0.0):
    gf = g.astype(jnp.float32)
    if weight_decay:
        gf = gf + weight_decay * p.astype(jnp.float32)
    a = acc + jnp.square(gf)
    step = lr * gf * jax.lax.rsqrt(beta + a)
    return (p.astype(jnp.float32) - step).astype(p.dtype), a
