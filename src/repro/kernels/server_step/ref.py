"""Pure-jnp oracle for the fused federated server-step kernel.

The oracle composes the two pieces the kernel fuses, in the exact
operation order the kernel uses: a left-to-right f32 accumulation of
``coeff_m · g_m`` (per-member clip scale × work weight), then the shared
modified-AdaGrad per-leaf update
(``repro.optim.adagrad_math.adagrad_leaf_update`` — the same function
the pure-pytree optimizer runs).  Interpret-mode kernel output is
bit-equal to this oracle; it also doubles as the jit-fused XLA fallback
on hosts without a TPU (see ``ops.server_step_update``).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.optim.adagrad_math import adagrad_leaf_update


def weighted_member_sum(g_stack, coeffs):
    """Σ_m coeffs[m] · g_stack[m] in f32, accumulated left to right —
    the kernel's (and the tree_map reference's) exact order."""
    g = coeffs[0] * g_stack[0].astype(jnp.float32)
    for m in range(1, g_stack.shape[0]):
        g = g + coeffs[m] * g_stack[m].astype(jnp.float32)
    return g


def server_step_ref(p, g_stack, acc, coeffs, *, lr: float, beta: float = 1.0,
                    weight_decay: float = 0.0):
    """``p``/``acc``: any shape; ``g_stack``: (M, *p.shape); ``coeffs``:
    (M,).  Returns (p', acc') — p' in p.dtype, acc' f32."""
    g = weighted_member_sum(g_stack, jnp.asarray(coeffs, jnp.float32))
    return adagrad_leaf_update(p, g, acc, lr=lr, beta=beta,
                               weight_decay=weight_decay)
