"""Fused federated server-step TPU kernel (Pallas).

One pass over the round's flattened parameter buffer performs the whole
server-side hot path of a federated training round:

    g     = Σ_m  coeff_m · g_m        (per-member clip × work weight,
                                       folded into one f32 coefficient)
    acc  += g²                        (modified-AdaGrad accumulator)
    θ    −= α · g / sqrt(β + acc)

i.e. per-member gradient clipping, the work-weighted mean, and the
paper's modified-AdaGrad update in a single kernel launch — (M + 2)
reads + 2 writes per element instead of the ~(3M + 7) transfers of the
unfused clip → ``weighted_grad_mean`` → optimizer chain.

Layout follows the adagrad kernel template: the caller flattens and
concatenates every leaf into one f32 buffer, pads it to (rows, 1024)
VPU tiles, and stacks the M member gradients on a leading axis.  The
member loop is a static Python loop, so the f32 accumulation order is
exactly the reference's left-to-right order — interpret mode is
bit-equal to ``repro.kernels.server_step.ref.server_step_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_ROWS = 8
BLOCK_COLS = 1024


def _server_step_kernel(c_ref, p_ref, g_ref, a_ref, po_ref, ao_ref, *,
                        lr: float, beta: float, weight_decay: float,
                        members: int):
    # static member loop: left-to-right f32 accumulate, same order as the
    # tree_map reference (bit-equivalence contract)
    g = c_ref[0] * g_ref[0].astype(jnp.float32)
    for m in range(1, members):
        g = g + c_ref[m] * g_ref[m].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    if weight_decay:
        g = g + weight_decay * p
    a = a_ref[...] + jnp.square(g)
    step = lr * g * jax.lax.rsqrt(beta + a)
    po_ref[...] = (p - step).astype(po_ref.dtype)
    ao_ref[...] = a


def pad_to_blocks(x, n_padded: int):
    """Flatten ``x`` and zero-pad to the (rows, BLOCK_COLS) tile grid."""
    flat = x.reshape(-1)
    return jnp.pad(flat, (0, n_padded - flat.shape[0])).reshape(
        n_padded // BLOCK_COLS, BLOCK_COLS)


def padded_size(n: int, row_multiple: int = BLOCK_ROWS) -> int:
    """Elements after padding ``n`` up to whole (row_multiple, 1024)
    blocks — ``row_multiple`` is raised by the sharded path so every
    device slice is itself whole blocks."""
    block = row_multiple * BLOCK_COLS
    return (n + block - 1) // block * block


def server_step_blocks(p2, g3, acc2, coeffs, *, lr: float, beta: float = 1.0,
                       weight_decay: float = 0.0, interpret: bool = True):
    """The raw kernel over pre-tiled buffers.

    ``p2``/``acc2``: (R, 1024) f32 with R a multiple of BLOCK_ROWS;
    ``g3``: (M, R, 1024) f32; ``coeffs``: (M,) f32 (clip scale × work
    weight per member).  Returns (p2', acc2') f32.
    """
    m, rows = g3.shape[0], p2.shape[0]
    grid = (rows // BLOCK_ROWS,)
    spec2 = pl.BlockSpec((BLOCK_ROWS, BLOCK_COLS), lambda i: (i, 0))
    spec3 = pl.BlockSpec((m, BLOCK_ROWS, BLOCK_COLS), lambda i: (0, i, 0))
    cspec = pl.BlockSpec(memory_space=pltpu.SMEM)
    return pl.pallas_call(
        functools.partial(_server_step_kernel, lr=lr, beta=beta,
                          weight_decay=weight_decay, members=m),
        grid=grid,
        in_specs=[cspec, spec2, spec3, spec2],
        out_specs=[spec2, spec2],
        out_shape=[
            jax.ShapeDtypeStruct(p2.shape, jnp.float32),
            jax.ShapeDtypeStruct(p2.shape, jnp.float32),
        ],
        interpret=interpret,
    )(coeffs, p2, g3, acc2)


def server_step_kernel(p, g_stack, acc, coeffs, *, lr: float,
                       beta: float = 1.0, weight_decay: float = 0.0,
                       interpret: bool = True):
    """Convenience single-array form: ``p``/``acc`` any shape, ``g_stack``
    (M, *p.shape).  Pads, tiles, runs the kernel, un-pads.  Returns
    (p', acc') f32 in ``p``'s shape."""
    shape, n = p.shape, p.size
    n_p = padded_size(n)
    p2 = pad_to_blocks(p.astype(jnp.float32), n_p)
    acc2 = pad_to_blocks(acc.astype(jnp.float32), n_p)
    g3 = jnp.stack([pad_to_blocks(g.astype(jnp.float32), n_p)
                    for g in g_stack])
    po, ao = server_step_blocks(p2, g3, acc2,
                                jnp.asarray(coeffs, jnp.float32),
                                lr=lr, beta=beta,
                                weight_decay=weight_decay,
                                interpret=interpret)
    return (po.reshape(-1)[:n].reshape(shape),
            ao.reshape(-1)[:n].reshape(shape))
