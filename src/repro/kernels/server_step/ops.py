"""Jit'd public wrappers for the fused federated server-step kernel.

Three execution modes behind one call:

  * ``"pallas"``    — the real TPU kernel (``interpret=False``);
  * ``"interpret"`` — the same kernel through the Pallas interpreter —
                      the CPU path the bit-equivalence tests pin;
  * ``"xla"``       — the oracle (``ref.server_step_ref``) under
                      ``jax.jit``: identical math, XLA-fused.  The fast
                      off-TPU path — one fused elementwise computation
                      over the flat buffer instead of the interpreter's
                      per-block Python loop.

Default mode is ``"pallas"`` on TPU, ``"xla"`` elsewhere.

Sharding (the olmax ``pjit``/``with_sharding_constraint`` idiom, resolved
through ``repro.sharding.spec.to_pspec``): pass a ``mesh`` and the padded
row dimension of the flat buffer is partitioned across ``data_axis`` —
``shard_map`` hands each device its own whole-block row slice for the
kernel modes, and GSPMD partitions the constrained oracle in ``"xla"``
mode.  Row padding is raised to ``devices × BLOCK_ROWS`` so every device
slice is itself whole VPU tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.kernels.server_step.kernel import (BLOCK_ROWS, pad_to_blocks,
                                              padded_size,
                                              server_step_blocks)
from repro.kernels.server_step.ref import server_step_ref
from repro.sharding.spec import to_pspec

MODES = ("pallas", "interpret", "xla")

try:                                  # jax >= 0.6
    from jax import shard_map as _shard_map
    _REPL_KW = {"check_vma": False}
except ImportError:                   # jax 0.4.x spelling
    from jax.experimental.shard_map import shard_map as _shard_map
    _REPL_KW = {"check_rep": False}


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_mode(mode: str | None) -> str:
    if mode is None:
        return "pallas" if _on_tpu() else "xla"
    if mode not in MODES:
        raise KeyError(f"server-step mode must be one of {MODES}, "
                       f"got {mode!r}")
    return mode


def _row_specs(data_axis: str):
    """PartitionSpecs for the padded buffers, resolved through the
    sharding layer's logical-axis machinery: the flat buffer's row dim
    is the one sharded ('flat_rows' -> the mesh's data axis)."""
    rules = {"flat_rows": data_axis}
    return (to_pspec(("flat_rows", None), rules),           # p2 / acc2
            to_pspec((None, "flat_rows", None), rules),     # g3
            to_pspec((), rules))                            # coeffs


@functools.lru_cache(maxsize=None)
def _jit_impl(lr: float, beta: float, weight_decay: float, mode: str,
              mesh, data_axis: str):
    """One compiled callable per (hyperparams, mode, mesh) combination."""
    ndev = 1 if mesh is None else mesh.shape[data_axis]
    ps2, ps3, psc = _row_specs(data_axis)

    def impl(p, g_stack, acc, coeffs):
        shape, n = p.shape, p.size
        n_p = padded_size(n, BLOCK_ROWS * ndev)
        p2 = pad_to_blocks(p.astype(jnp.float32), n_p)
        acc2 = pad_to_blocks(acc.astype(jnp.float32), n_p)
        g3 = jnp.stack([pad_to_blocks(g.astype(jnp.float32), n_p)
                        for g in g_stack])
        coeffs_f = jnp.asarray(coeffs, jnp.float32)
        kw = dict(lr=lr, beta=beta, weight_decay=weight_decay)
        if mesh is not None and ndev > 1:
            # olmax idiom: constrain, then run the sharded computation
            p2 = jax.lax.with_sharding_constraint(
                p2, NamedSharding(mesh, ps2))
            acc2 = jax.lax.with_sharding_constraint(
                acc2, NamedSharding(mesh, ps2))
            g3 = jax.lax.with_sharding_constraint(
                g3, NamedSharding(mesh, ps3))
        if mode == "xla":
            po, ao = server_step_ref(p2, g3, acc2, coeffs_f, **kw)
        elif mesh is not None and ndev > 1:
            body = functools.partial(server_step_blocks,
                                     interpret=(mode == "interpret"), **kw)
            po, ao = _shard_map(
                lambda pp, gg, aa, cc: body(pp, gg, aa, cc),
                mesh=mesh, in_specs=(ps2, ps3, ps2, psc),
                out_specs=(ps2, ps2), **_REPL_KW)(p2, g3, acc2, coeffs_f)
        else:
            po, ao = server_step_blocks(p2, g3, acc2, coeffs_f,
                                        interpret=(mode == "interpret"),
                                        **kw)
        return (po.reshape(-1)[:n].reshape(shape),
                ao.reshape(-1)[:n].reshape(shape))

    return jax.jit(impl)


def server_step_update(p, g_stack, acc, coeffs, *, lr: float,
                       beta: float = 1.0, weight_decay: float = 0.0,
                       mode: str | None = None, mesh=None,
                       data_axis: str = "data"):
    """Fused clip×weight mean + modified-AdaGrad update.

    ``p``/``acc``: any shape (``acc`` f32); ``g_stack``: (M, *p.shape);
    ``coeffs``: (M,) f32 — each member's clip scale × normalised work
    weight.  Returns ``(p', acc')`` f32 in ``p``'s shape.
    """
    mode = resolve_mode(mode)
    fn = _jit_impl(float(lr), float(beta), float(weight_decay), mode,
                   mesh, data_axis)
    return fn(p, g_stack, acc, coeffs)
