"""Unified metrics registry for the Sashimi fabric.

One :class:`MetricsRegistry` holds every labelled counter, gauge, and
histogram the fabric exposes, behind a single :meth:`snapshot` /
:meth:`export` API.  It absorbs the ad-hoc telemetry that grew across
PRs 2-6 (``EdgeCache`` hit counters, origin ``download_count``,
``FederationMember.steals``, transport frame counters, ticket-queue EWMA
rates, barrier wait times) — see ``repro.obs.collect`` for the
collectors that map those legacy counters in.

Naming convention (linted by ``tools/check_metric_names.py``, catalog
in ``docs/ARCHITECTURE.md`` §Observability)::

    subsystem.noun_unit        e.g.  cache.hits_total
                                     round.barrier_wait_seconds

where ``subsystem`` is a single lowercase token, and the final
underscore-separated token of the noun part is one of the allowed units
(:data:`UNITS`).  Invalid names are rejected at registration, so the
lint and the runtime cannot drift.
"""
from __future__ import annotations

import re
import threading
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = ["UNITS", "METRIC_NAME_RE", "valid_metric_name",
           "Counter", "Gauge", "Histogram", "MetricsRegistry"]

#: allowed unit suffixes — the last ``_``-separated token of every name
UNITS = ("total", "seconds", "bytes", "count", "rate", "ratio")

METRIC_NAME_RE = re.compile(
    r"^[a-z][a-z0-9]*\.[a-z][a-z0-9]*(?:_[a-z0-9]+)*_(%s)$"
    % "|".join(UNITS))


def valid_metric_name(name: str) -> bool:
    return bool(METRIC_NAME_RE.match(name))


DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0,
                   60.0, float("inf"))


class _Metric:
    kind = "abstract"

    def __init__(self, name: str, help: str, label_names: Tuple[str, ...],
                 lock: threading.Lock):
        self.name = name
        self.help = help
        self.label_names = label_names
        self._lock = lock
        self._values: Dict[Tuple[str, ...], float] = {}

    def _key(self, labels: dict) -> Tuple[str, ...]:
        if set(labels) != set(self.label_names):
            raise ValueError(
                "metric %r takes labels %r, got %r"
                % (self.name, self.label_names, tuple(labels)))
        return tuple(str(labels[k]) for k in self.label_names)

    def _value_rows(self) -> List[dict]:
        rows = []
        for key in sorted(self._values):
            rows.append({"labels": dict(zip(self.label_names, key)),
                         "value": self._values[key]})
        return rows


class Counter(_Metric):
    """Monotonic counter.  ``set_total`` exists for snapshot-time
    collectors that absorb an externally-maintained cumulative count."""
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def set_total(self, value: float, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = float(value)

    def value(self, **labels) -> float:
        with self._lock:
            return self._values.get(self._key(labels), 0.0)

    def total(self) -> float:
        with self._lock:
            return sum(self._values.values())


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = float(value)

    def value(self, **labels) -> float:
        with self._lock:
            return self._values.get(self._key(labels), 0.0)


class Histogram(_Metric):
    """Cumulative-bucket histogram (Prometheus-style ``le`` buckets)."""
    kind = "histogram"

    def __init__(self, name, help, label_names, lock,
                 buckets: Iterable[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, label_names, lock)
        bs = tuple(sorted(float(b) for b in buckets))
        if not bs or bs[-1] != float("inf"):
            bs = bs + (float("inf"),)
        self.buckets = bs
        # key -> [counts per bucket..., count, sum]
        self._hvalues: Dict[Tuple[str, ...], List[float]] = {}

    def observe(self, value: float, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            row = self._hvalues.get(key)
            if row is None:
                row = self._hvalues[key] = [0.0] * (len(self.buckets) + 2)
            for i, b in enumerate(self.buckets):
                if value <= b:
                    row[i] += 1
            row[-2] += 1
            row[-1] += value

    def count(self, **labels) -> int:
        key = self._key(labels)
        with self._lock:
            row = self._hvalues.get(key)
            return int(row[-2]) if row else 0

    def sum(self, **labels) -> float:
        key = self._key(labels)
        with self._lock:
            row = self._hvalues.get(key)
            return row[-1] if row else 0.0

    def _value_rows(self) -> List[dict]:
        rows = []
        for key in sorted(self._hvalues):
            row = self._hvalues[key]
            rows.append({
                "labels": dict(zip(self.label_names, key)),
                "count": int(row[-2]),
                "sum": row[-1],
                "buckets": {("inf" if b == float("inf") else repr(b)): int(c)
                            for b, c in zip(self.buckets, row)},
            })
        return rows


class MetricsRegistry:
    """Registry of named metrics; registration is idempotent per name.

    Re-registering a name with the same kind returns the existing
    instrument (so collectors can run repeatedly); a kind clash or a
    name violating the ``subsystem.noun_unit`` convention raises.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Metric] = {}

    def _register(self, cls, name: str, help: str,
                  labels: Tuple[str, ...], **kw) -> _Metric:
        if not valid_metric_name(name):
            raise ValueError(
                "metric name %r violates the subsystem.noun_unit "
                "convention (unit suffix must be one of %s)"
                % (name, "/".join(UNITS)))
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                if existing.kind != cls.kind:
                    raise ValueError(
                        "metric %r already registered as %s, not %s"
                        % (name, existing.kind, cls.kind))
                if tuple(existing.label_names) != tuple(labels):
                    raise ValueError(
                        "metric %r already registered with labels %r"
                        % (name, existing.label_names))
                return existing
            m = cls(name, help, tuple(labels), self._lock, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labels: Tuple[str, ...] = ()) -> Counter:
        return self._register(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Tuple[str, ...] = ()) -> Gauge:
        return self._register(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Tuple[str, ...] = (),
                  buckets: Iterable[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._register(Histogram, name, help, labels,
                              buckets=buckets)

    def get(self, name: str) -> Optional[_Metric]:
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self) -> dict:
        """JSON-safe dump of every metric: name -> {kind, help, values}."""
        with self._lock:
            metrics = dict(self._metrics)
        return {name: {"kind": m.kind, "help": m.help,
                       "values": m._value_rows()}
                for name, m in sorted(metrics.items())}

    def export(self) -> List[dict]:
        """Flat row-per-series export (for BENCH json and dashboards)."""
        rows = []
        for name, body in self.snapshot().items():
            for v in body["values"]:
                rows.append({"name": name, "kind": body["kind"], **v})
        return rows
