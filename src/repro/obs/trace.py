"""Causal ticket tracing for the Sashimi fabric.

A :class:`Tracer` records spans and instant events for the full ticket
lifecycle (enqueue -> shard-route -> lease -> wire-transfer ->
client-execute -> submit -> barrier-fold) and exports them as Chrome
trace-event JSON, loadable directly in Perfetto (ui.perfetto.dev).

Design constraints, in order:

  * **Zero-cost when disabled.**  Nothing in the fabric holds a tracer by
    default; every instrumentation site is guarded by a single
    ``if tracer is not None`` attribute check.  There is no global
    registry and no no-op call overhead on the hot path.
  * **Deterministic on the virtual clock.**  The tracer never reads wall
    time on its own when a caller supplies ``ts``; when it must, it uses
    its injectable ``clock`` (set it to the queue's clock).  Two
    same-seed virtual-clock runs therefore produce byte-identical
    traces (``benchmarks/run.py --only obs`` asserts this).
  * **Balanced by construction.**  ``begin`` returns an opaque span id;
    every code path that retires the underlying fabric object (submit,
    release, cancel, fold) ends the span exactly once because the span
    id lives *in* the bookkeeping dict whose pop already happens exactly
    once.  ``balanced()`` is the invariant the property tests check.

Span encoding: lifecycle spans that overlap arbitrarily on one lane
(ticket lifetimes, lease windows) are emitted as Chrome *async* events
(``ph: "b"/"e"`` pairs keyed by span id); per-lane sequential spans
(client execute, wire transfer, round barriers) are emitted as complete
``ph: "X"`` slices so Perfetto nests them on their track.

Two long-running-fleet modes sit on top of the default
record-everything behaviour, both off unless asked for:

  * **Ring buffer** (``max_events=N``): finished events live in a
    bounded deque; the oldest are discarded (counted in
    ``events_dropped``) so a tracer can stay attached to a server for
    days.  :meth:`drain` pops the buffered events for shipping — the
    client-side telemetry flush uses it.
  * **Flight recorder** (:meth:`dump_on`): named instants (the PR 9
    failure signals — ``round.stall``, ``transport.evict``,
    ``transport.busy``) arm a trigger that writes the current buffer to
    a Perfetto file the moment the instant fires, so the evidence
    window around a failure is captured without anyone watching.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = ["Tracer", "render_chrome_trace"]

_US = 1e6      # Chrome trace-event timestamps are microseconds


def render_chrome_trace(events: List[dict],
                        process_name: str = "sashimi-fabric") -> dict:
    """Render decoded events (the :meth:`Tracer.events` schema) to the
    Chrome trace-event JSON object format.  Tracks become threads of a
    single process: tid assignment is by sorted track name, with
    ``thread_name`` / ``thread_sort_index`` metadata so Perfetto shows
    one labelled lane per track.  Shared by :meth:`Tracer.chrome_trace`
    and the fleet aggregator's merged export."""
    tracks = sorted({e["track"] for e in events})
    tid = {t: i + 1 for i, t in enumerate(tracks)}
    out: List[dict] = []
    for t in tracks:
        out.append({"ph": "M", "name": "thread_name", "pid": 1,
                    "tid": tid[t], "args": {"name": t}})
        out.append({"ph": "M", "name": "thread_sort_index", "pid": 1,
                    "tid": tid[t], "args": {"sort_index": tid[t]}})
    out.append({"ph": "M", "name": "process_name", "pid": 1,
                "args": {"name": process_name}})
    for e in events:
        ev = {"name": e["name"], "cat": e["cat"], "ph": e["ph"],
              "ts": round(e["ts"] * _US, 3), "pid": 1,
              "tid": tid[e["track"]]}
        if e["ph"] == "X":
            ev["dur"] = round(e["dur"] * _US, 3)
        elif e["ph"] in ("b", "e"):
            ev["id"] = e["id"]
        elif e["ph"] == "i":
            ev["s"] = "t"
        if e.get("args"):
            ev["args"] = e["args"]
        out.append(ev)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


class Tracer:
    """Collects lifecycle spans and exports Chrome trace-event JSON.

    ``clock`` is the fallback timestamp source for calls that do not
    pass ``ts`` explicitly; wire it to the same injectable clock the
    ticket queue uses so simulated time and trace time agree.
    """

    def __init__(self, clock: Callable[[], float] = time.monotonic, *,
                 max_events: Optional[int] = None):
        self.clock = clock
        self._lock = threading.Lock()
        # finished events, in completion order (deterministic under the
        # single-threaded virtual-clock sims).  Stored as compact tuples
        # (ph, name, cat, track, ts0, ts1, sid, args) — ph "X" lane
        # slice, "a" async begin/end pair, "i" instant — and decoded to
        # the dict schema lazily in events()/chrome_trace(), keeping the
        # record path (the only part on the fabric's hot path) cheap.
        # With max_events set the store is a bounded ring: the oldest
        # finished events fall off (counted), so a long-lived fleet
        # tracer holds a sliding evidence window instead of growing
        # without bound.
        self.max_events = max_events
        self._events = ([] if max_events is None
                        else deque(maxlen=int(max_events)))
        # hot-path dispatch: the default (unbounded) tracer appends via
        # the list's own bound method — zero added cost over the pre-ring
        # implementation; only ring mode pays for drop accounting.  Both
        # drain() and clear() keep container identity, so the binding
        # stays valid for the tracer's lifetime.
        self._append = (self._events.append if max_events is None
                        else self._ring_append)
        self.events_dropped = 0
        # sid -> (name, cat, track, lane, ts0, args)
        self._open: Dict[int, Tuple[str, str, str, bool, float,
                                    Optional[dict]]] = {}
        self._next_sid = 0
        self.spans_opened = 0
        self.spans_closed = 0
        # ends on unknown / already-closed ids; must stay 0 (see
        # balanced()) — counted rather than raised so a bug in one
        # instrumentation site cannot take down the fabric itself
        self.end_errors = 0
        # flight-recorder triggers: instant name -> mutable state dict
        # {path, after, seen, limit, fired} (see dump_on)
        self._triggers: Dict[str, dict] = {}
        self.dumps_written: List[str] = []

    def _ring_append(self, event: tuple) -> None:
        """Ring-mode append under the lock, counting evictions."""
        ev = self._events
        if len(ev) == ev.maxlen:
            self.events_dropped += 1
        ev.append(event)

    # -- recording ---------------------------------------------------------

    def begin(self, name: str, *, track: str = "fabric", cat: str = "fabric",
              ts: Optional[float] = None, lane: bool = False,
              args: Optional[dict] = None) -> int:
        """Open a span; returns an id to pass to :meth:`end` exactly once.

        ``lane=True`` emits a complete-slice event (for sequential,
        properly-nested spans on one track); the default emits an async
        begin/end pair (safe for spans that overlap arbitrarily).
        """
        if ts is None:
            ts = self.clock()
        with self._lock:
            sid = self._next_sid = self._next_sid + 1
            self._open[sid] = (name, cat, track, lane, ts, args)
            self.spans_opened += 1
        return sid

    def begin_many(self, name: str, args_list, *, track: str = "fabric",
                   cat: str = "fabric",
                   ts: Optional[float] = None) -> List[int]:
        """Open one async span per element of ``args_list`` (each element
        the span's args dict) under a single lock acquisition — the bulk
        path for per-ticket spans in ``add_many``."""
        if ts is None:
            ts = self.clock()
        with self._lock:
            sid = self._next_sid
            sids = []
            for a in args_list:
                sid += 1
                self._open[sid] = (name, cat, track, False, ts, a)
                sids.append(sid)
            self._next_sid = sid
            self.spans_opened += len(sids)
        return sids

    def end(self, sid: Optional[int], *, ts: Optional[float] = None,
            args: Optional[dict] = None) -> None:
        """Close a span opened by :meth:`begin`.  ``sid=None`` is a no-op
        so call sites can pass ``spans.pop(key, None)`` unconditionally."""
        if sid is None:
            return
        if ts is None:
            ts = self.clock()
        with self._lock:
            rec = self._open.pop(sid, None)
            if rec is None:
                self.end_errors += 1
                return
            self.spans_closed += 1
            # begin-args and end-args ride as-is; merged lazily at decode
            self._append(("X" if rec[3] else "a", rec[0], rec[1],
                          rec[2], rec[4], ts, sid, rec[5], args))

    def instant(self, name: str, *, track: str = "fabric",
                cat: str = "fabric", ts: Optional[float] = None,
                args: Optional[dict] = None) -> None:
        """Record a zero-duration event (enqueue, route, policy firing)."""
        if ts is None:
            ts = self.clock()
        dump_path = None
        with self._lock:
            self._append(("i", name, cat, track, ts, ts, 0, args, None))
            if self._triggers:           # falsy-check: free when unused
                trig = self._triggers.get(name)
                if trig is not None and trig["fired"] < trig["limit"]:
                    trig["seen"] += 1
                    if trig["seen"] >= trig["after"]:
                        trig["fired"] += 1
                        trig["seen"] = 0
                        dump_path = trig["path"]
        if dump_path is not None:
            # outside the lock: write() re-enters via events()
            self.write(dump_path)
            self.dumps_written.append(dump_path)

    # -- flight recorder ---------------------------------------------------

    def dump_on(self, trigger: str, path: str, *, after: int = 1,
                limit: int = 1) -> None:
        """Arm the flight recorder: when the instant named ``trigger``
        has fired ``after`` times, write the current (ring-bounded)
        trace to ``path``.  At most ``limit`` dumps per trigger; the
        occurrence count resets after each dump so ``after=N`` means
        "every N-th occurrence" (busy *storms*, not single refusals).
        Written paths are recorded in ``dumps_written``."""
        if after < 1 or limit < 1:
            raise ValueError("dump_on requires after >= 1 and limit >= 1")
        with self._lock:
            self._triggers[trigger] = {"path": path, "after": int(after),
                                       "seen": 0, "limit": int(limit),
                                       "fired": 0}

    def drain(self) -> List[dict]:
        """Pop and return every buffered finished event in the decoded
        schema (see :meth:`events`).  Open spans stay open; counters
        (``spans_opened``/``closed``, ``events_dropped``) are untouched.
        The client-side telemetry flush ships these over the wire."""
        with self._lock:
            raw = list(self._events)
            self._events.clear()
        return self._decode(raw)

    # -- invariants --------------------------------------------------------

    def balanced(self) -> bool:
        """True iff every opened span was closed exactly once."""
        with self._lock:
            return not self._open and self.end_errors == 0 \
                and self.spans_opened == self.spans_closed

    def open_spans(self) -> List[dict]:
        """Snapshot of still-open spans (for stall diagnostics)."""
        with self._lock:
            return [{"name": n, "track": tr, "since": ts0,
                     "args": a or {}}
                    for (n, c, tr, lane, ts0, a) in self._open.values()]

    def event_count(self) -> int:
        """Finished events in the decoded schema (async spans count as
        their begin/end pair — two events)."""
        return len(self.events())

    def events(self) -> List[dict]:
        """Finished events decoded to the internal dict schema (seconds
        timestamps): lane spans as ``ph "X"`` with ``dur``, async spans
        as ``ph "b"/"e"`` pairs sharing an ``id``, instants as ``ph
        "i"``."""
        with self._lock:
            raw = list(self._events)
        return self._decode(raw)

    @staticmethod
    def _decode(raw: List[tuple]) -> List[dict]:
        out: List[dict] = []
        for ph, name, cat, track, ts0, ts1, sid, args, args_end in raw:
            if args_end:
                args = {**args, **args_end} if args else args_end
            base = {"name": name, "cat": cat, "track": track}
            if ph == "X":
                out.append({**base, "ph": "X", "ts": ts0,
                            "dur": max(0.0, ts1 - ts0), "args": args or {}})
            elif ph == "a":
                out.append({**base, "ph": "b", "id": sid, "ts": ts0,
                            "args": args or {}})
                out.append({**base, "ph": "e", "id": sid, "ts": ts1})
            else:
                out.append({**base, "ph": "i", "ts": ts0,
                            "args": args or {}})
        return out

    # -- export ------------------------------------------------------------

    def chrome_trace(self) -> dict:
        """Render to the Chrome trace-event JSON object format.

        Tracks become threads of a single process: tid assignment is by
        sorted track name, with ``thread_name`` / ``thread_sort_index``
        metadata so Perfetto shows one labelled lane per track
        (per-client lanes, per-member lanes, the queue, the trainer).
        """
        return render_chrome_trace(self.events())

    def to_json(self) -> str:
        """Deterministic serialization (same-seed runs compare equal)."""
        return json.dumps(self.chrome_trace(), sort_keys=True,
                          separators=(",", ":"))

    def write(self, path: str) -> None:
        with open(path, "w") as fh:
            fh.write(self.to_json())
