"""Fleet telemetry aggregation: the server-side half of the telemetry
plane (docs/PROTOCOL.md §telemetry, docs/ARCHITECTURE.md §Observability).

Remote browsers are the one part of the fabric you can never attach a
profiler to — everything a :class:`~repro.core.transport.
RemoteBrowserClient` measures locally (execute lanes, backoff sleeps,
busy refusals) either crosses the wire or dies with the tab.  A
:class:`FleetAggregator` is handed to the :class:`TransportServer`
(``fleet=``) and receives every tolerantly-parsed ``telemetry`` batch:

* **Metrics** merge into one fleet-wide snapshot with a ``client=``
  label injected into every series row, so ``client.execute_seconds``
  from forty browsers reads as one labelled metric family.  Ingestion
  is last-write-wins per (client, series) — clients ship cumulative
  snapshots, so re-ingestion is idempotent by construction.
* **Spans** buffer per client (bounded, oldest dropped and counted)
  with their timestamps remapped from the client's clock to the
  server's via the per-connection skew estimate, so the merged
  :meth:`chrome_trace` shows server round lanes, wire spans, and
  *remote* client execute lanes on one common timeline.
* **Clock skew** is estimated NTP-style from heartbeat echoes: the
  client reports ``(t0, server_ts, t1)`` — its send time, the server's
  stamp, its receive time — giving ``offset = server_ts - (t0+t1)/2``
  with uncertainty ``rtt = t1 - t0``.  The minimum-RTT sample wins
  (least queueing delay → tightest bound on the true offset).

Everything here is defensive: batches arrive pre-sanitized by
:func:`repro.core.wire.parse_telemetry`, but the aggregator still
bounds every buffer and counts every drop rather than trusting a peer.
"""
from __future__ import annotations

import json
import threading
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

from .trace import Tracer, render_chrome_trace

__all__ = ["ClockSkew", "FleetAggregator"]

#: async-span ids from remote clients are renumbered into this range so
#: they can never collide with the server tracer's own span ids.
_REMOTE_ID_BASE = 1 << 32


@dataclass
class ClockSkew:
    """Best clock-skew estimate for one client (server − client)."""
    offset: float = 0.0     # add to a client timestamp → server time
    rtt: float = float("inf")  # uncertainty of the winning sample
    samples: int = 0


class FleetAggregator:
    """Merges per-client telemetry into one fleet view.

    ``tracer`` is the *server's* tracer: its events form the local half
    of the merged export.  ``max_spans_per_client`` bounds each span
    buffer (oldest evicted, counted in ``spans_dropped``);
    ``max_clients`` bounds how many distinct clients may hold state
    (batches from the overflow are dropped whole, counted in
    ``batches_dropped``).
    """

    def __init__(self, tracer: Optional[Tracer] = None, *,
                 max_spans_per_client: int = 4096,
                 max_clients: int = 1024):
        self.tracer = tracer
        self.max_spans_per_client = int(max_spans_per_client)
        self.max_clients = int(max_clients)
        self._lock = threading.Lock()
        # client -> latest metrics snapshot (name -> {kind, help, values})
        self._series: Dict[str, Dict[str, dict]] = {}
        # client -> bounded buffer of decoded span events (client clock)
        self._spans: Dict[str, deque] = {}
        self._skew: Dict[str, ClockSkew] = {}
        self.batches_total = 0
        self.batches_dropped = 0
        self.spans_total = 0
        self.spans_dropped = 0       # buffer evictions on this side
        self.series_dropped = 0      # malformed rows discarded here
        self.remote_dropped = 0      # peers' self-reported drop counts
        self.parse_dropped = 0       # entries parse_telemetry discarded

    # -- ingestion ---------------------------------------------------------

    def ingest(self, client: str, parsed: Optional[dict], *,
               recv_ts: Optional[float] = None) -> bool:
        """Absorb one parsed ``telemetry`` batch (the output of
        :func:`repro.core.wire.parse_telemetry`) from ``client``.
        Returns False — never raises — when the batch was dropped
        (unparseable, or a brand-new client past ``max_clients``)."""
        if not isinstance(client, str) or not client or parsed is None:
            with self._lock:
                self.batches_dropped += 1
            return False
        with self._lock:
            if (client not in self._spans
                    and len(self._spans) >= self.max_clients):
                self.batches_dropped += 1
                return False
            self.batches_total += 1
            self.remote_dropped += parsed.get("dropped", 0)
            self.parse_dropped += parsed.get("local_drops", 0)

            snap = self._series.setdefault(client, {})
            for name, body in parsed.get("metrics", {}).items():
                rows = []
                for row in body.get("values", ()):
                    if not isinstance(row, dict):
                        self.series_dropped += 1
                        continue
                    labels = row.get("labels")
                    rows.append({**row,
                                 "labels": {**(labels if isinstance(
                                     labels, dict) else {}),
                                     "client": client}})
                snap[name] = {"kind": body["kind"], "help": body["help"],
                              "values": rows}

            buf = self._spans.setdefault(
                client, deque(maxlen=self.max_spans_per_client))
            for ev in parsed.get("spans", ()):
                if len(buf) == buf.maxlen:
                    self.spans_dropped += 1
                self.spans_total += 1
                buf.append(ev)
        return True

    def clock_sample(self, client: str, *, offset: float,
                     rtt: float) -> None:
        """Feed one skew sample (from a heartbeat echo); the
        minimum-RTT sample seen so far wins."""
        if not isinstance(client, str) or not client or rtt < 0:
            return
        with self._lock:
            sk = self._skew.setdefault(client, ClockSkew())
            sk.samples += 1
            if rtt <= sk.rtt:
                sk.rtt = rtt
                sk.offset = float(offset)

    # -- views -------------------------------------------------------------

    def skew(self, client: str) -> Optional[ClockSkew]:
        with self._lock:
            return self._skew.get(client)

    def offset(self, client: str) -> float:
        """Current best offset to add to ``client``'s timestamps (0.0
        until a skew sample exists)."""
        with self._lock:
            sk = self._skew.get(client)
            return sk.offset if sk is not None and sk.samples else 0.0

    def clients(self) -> List[str]:
        with self._lock:
            return sorted(set(self._series) | set(self._spans))

    def snapshot(self) -> dict:
        """Fleet-wide metrics snapshot: every remote series keyed by
        name, each row carrying its ``client`` label.  Same shape as
        ``MetricsRegistry.snapshot()`` so the two merge trivially."""
        with self._lock:
            names: Dict[str, dict] = {}
            for client in sorted(self._series):
                for name, body in sorted(self._series[client].items()):
                    agg = names.setdefault(
                        name, {"kind": body["kind"], "help": body["help"],
                               "values": []})
                    if agg["kind"] == body["kind"]:
                        agg["values"].extend(body["values"])
                    else:
                        self.series_dropped += len(body["values"])
            return names

    def remote_events(self, *, corrected: bool = True) -> List[dict]:
        """Every buffered remote span, skew-corrected to server time
        (``corrected=False`` returns raw client timestamps), async ids
        renumbered clear of the server tracer's, in deterministic
        (client, arrival) order."""
        with self._lock:
            clients = sorted(self._spans)
            bufs = {c: list(self._spans[c]) for c in clients}
            offs = {c: (self._skew[c].offset
                        if c in self._skew and self._skew[c].samples
                        else 0.0)
                    for c in clients}
        out: List[dict] = []
        id_map: Dict[tuple, int] = {}
        for c in clients:
            off = offs[c] if corrected else 0.0
            for ev in bufs[c]:
                ev = dict(ev)
                ev["ts"] = ev["ts"] + off
                if "id" in ev:
                    key = (c, ev["id"])
                    if key not in id_map:
                        id_map[key] = _REMOTE_ID_BASE + len(id_map)
                    ev["id"] = id_map[key]
                out.append(ev)
        return out

    # -- export ------------------------------------------------------------

    def merged_events(self) -> List[dict]:
        """Server tracer events followed by skew-corrected remote
        events — the one-timeline view of a federated round."""
        local = self.tracer.events() if self.tracer is not None else []
        return local + self.remote_events()

    def chrome_trace(self) -> dict:
        return render_chrome_trace(self.merged_events(),
                                   process_name="sashimi-fleet")

    def to_json(self) -> str:
        """Deterministic serialization (same-seed virtual-clock runs
        compare byte-equal, exactly like ``Tracer.to_json``)."""
        return json.dumps(self.chrome_trace(), sort_keys=True,
                          separators=(",", ":"))

    def write(self, path: str) -> None:
        with open(path, "w") as fh:
            fh.write(self.to_json())

    def stats(self) -> dict:
        with self._lock:
            return {
                "clients": len(set(self._series) | set(self._spans)),
                "batches_total": self.batches_total,
                "batches_dropped": self.batches_dropped,
                "spans_total": self.spans_total,
                "spans_dropped": self.spans_dropped,
                "series_dropped": self.series_dropped,
                "remote_dropped": self.remote_dropped,
                "parse_dropped": self.parse_dropped,
                "skew_samples": sum(s.samples
                                    for s in self._skew.values()),
            }
