"""Fabric observability: causal ticket tracing + unified metrics.

Two halves, both zero-cost when unused:

  * :mod:`repro.obs.trace` — :class:`Tracer`, a virtual-clock-friendly
    span recorder for the full ticket lifecycle (enqueue → shard-route →
    lease → wire transfer → client execute → submit → barrier fold),
    exporting Chrome trace-event JSON that Perfetto / ``chrome://tracing``
    loads directly.  Every instrumented constructor takes ``tracer=None``
    and every call site is guarded by a single ``is not None`` check —
    the disabled path costs one attribute test.
  * :mod:`repro.obs.metrics` — :class:`MetricsRegistry` with labelled
    counters/gauges/histograms under the linted ``subsystem.noun_unit``
    naming convention, and :mod:`repro.obs.collect` collectors that
    absorb the fabric's legacy telemetry into it at snapshot time.

See ``docs/ARCHITECTURE.md`` §Observability for the span taxonomy and
metric catalog.
"""
from repro.obs.collect import (collect_edge, collect_fabric,
                               collect_federation, collect_origin,
                               collect_queue, collect_transport)
from repro.obs.metrics import (METRIC_NAME_RE, UNITS, Counter, Gauge,
                               Histogram, MetricsRegistry,
                               valid_metric_name)
from repro.obs.trace import Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "METRIC_NAME_RE", "MetricsRegistry",
    "Tracer", "UNITS", "collect_edge", "collect_fabric",
    "collect_federation", "collect_origin", "collect_queue",
    "collect_transport", "valid_metric_name",
]
