"""Fabric observability: causal ticket tracing + unified metrics.

Two halves, both zero-cost when unused:

  * :mod:`repro.obs.trace` — :class:`Tracer`, a virtual-clock-friendly
    span recorder for the full ticket lifecycle (enqueue → shard-route →
    lease → wire transfer → client execute → submit → barrier fold),
    exporting Chrome trace-event JSON that Perfetto / ``chrome://tracing``
    loads directly.  Every instrumented constructor takes ``tracer=None``
    and every call site is guarded by a single ``is not None`` check —
    the disabled path costs one attribute test.
  * :mod:`repro.obs.metrics` — :class:`MetricsRegistry` with labelled
    counters/gauges/histograms under the linted ``subsystem.noun_unit``
    naming convention, and :mod:`repro.obs.collect` collectors that
    absorb the fabric's legacy telemetry into it at snapshot time.

On top of them sits the **fleet telemetry plane** (PR 10):

  * :mod:`repro.obs.fleet` — :class:`FleetAggregator`, the server-side
    sink for remote clients' ``telemetry`` wire batches: per-client
    metric series under a ``client=`` label, span buffers remapped to
    server time via heartbeat-echo clock-skew estimation, and a merged
    one-timeline Perfetto export.
  * :mod:`repro.obs.slo` — declarative :class:`Slo` thresholds over a
    registry, evaluated per round by :class:`SloMonitor` (breaches emit
    ``slo.breach`` instants and gate CI in ``benchmarks/run.py``).
  * The :class:`Tracer` flight recorder — ring-buffer mode plus
    :meth:`Tracer.dump_on` triggers that write a bounded Perfetto file
    the moment a failure instant (stall, eviction, busy storm) fires.

See ``docs/ARCHITECTURE.md`` §Observability for the span taxonomy,
fleet-plane topology, and metric catalog.
"""
from repro.obs.collect import (collect_edge, collect_fabric,
                               collect_federation, collect_fleet,
                               collect_origin, collect_queue,
                               collect_transport)
from repro.obs.fleet import ClockSkew, FleetAggregator
from repro.obs.metrics import (METRIC_NAME_RE, UNITS, Counter, Gauge,
                               Histogram, MetricsRegistry,
                               valid_metric_name)
from repro.obs.slo import DEFAULT_ROUND_SLOS, Slo, SloMonitor
from repro.obs.trace import Tracer, render_chrome_trace

__all__ = [
    "ClockSkew", "Counter", "DEFAULT_ROUND_SLOS", "FleetAggregator",
    "Gauge", "Histogram", "METRIC_NAME_RE", "MetricsRegistry", "Slo",
    "SloMonitor", "Tracer", "UNITS", "collect_edge", "collect_fabric",
    "collect_federation", "collect_fleet", "collect_origin",
    "collect_queue", "collect_transport", "render_chrome_trace",
    "valid_metric_name",
]
