"""Collectors: map the fabric's legacy telemetry into a
:class:`~repro.obs.metrics.MetricsRegistry`.

Every subsystem grown across PRs 2-6 kept its own ad-hoc counters
(``EdgeCache`` hit/miss/eviction counts, the origin's ``download_count``
ledger, ``FederationMember.steals``, the transport's per-message-type
frame accounting, the ticket queue's EWMA client rates).  These
collectors absorb them into one registry at snapshot time — the legacy
counters stay the source of truth (cheap, lock-local, always on), and
the registry is a *view* refreshed by calling a collector.  That keeps
the differential test trivial: registry value == legacy counter, always.

Cumulative legacy counts land via :meth:`Counter.set_total` (idempotent
re-collection — calling a collector twice doesn't double-count);
point-in-time values land in gauges.

Entry point::

    reg = MetricsRegistry()
    collect_fabric(reg, distributor=fed, transport=server)
    print(reg.snapshot())
"""
from __future__ import annotations

from typing import Optional

from repro.obs.metrics import MetricsRegistry

__all__ = ["collect_origin", "collect_edge", "collect_queue",
           "collect_federation", "collect_transport", "collect_fleet",
           "collect_fabric"]


def collect_origin(reg: MetricsRegistry, origin) -> None:
    """Absorb an ``HttpServerBase`` origin's download/revalidation/delta
    ledgers (keyed by asset key)."""
    dl = reg.counter("origin.downloads_total",
                     "Full payload transfers served by the origin",
                     labels=("key",))
    rv = reg.counter("origin.revalidations_total",
                     "Conditional fetches answered not-modified",
                     labels=("key",))
    de = reg.counter("origin.deltas_total",
                     "Changed-leaves delta payloads served (protocol v2)",
                     labels=("key",))
    for key, n in origin.download_count.items():
        dl.set_total(n, key=key)
    for key, n in origin.revalidation_count.items():
        rv.set_total(n, key=key)
    for key, n in origin.delta_count.items():
        de.set_total(n, key=key)


def collect_edge(reg: MetricsRegistry, edge) -> None:
    """Absorb one :class:`~repro.core.federation.EdgeCache`'s ``stats()``
    (labelled by the edge's name, so a federation's edges coexist)."""
    s = edge.stats()
    cache = s["name"]
    for field, help_ in (("requests", "Client-facing requests at the edge"),
                         ("hits", "Edge cache hits"),
                         ("misses", "Edge cache misses"),
                         ("evictions", "Edge cache LRU evictions"),
                         ("invalidations", "Origin-pushed invalidations"),
                         ("revalidations",
                          "Conditional origin fetches answered 304"),
                         ("deltas", "Delta payloads passed through")):
        reg.counter(f"cache.{field}_total", help_, labels=("cache",)
                    ).set_total(s[field], cache=cache)
    reg.gauge("cache.hit_ratio", "Edge hits / requests",
              labels=("cache",)).set(s["hit_rate"], cache=cache)


def collect_queue(reg: MetricsRegistry, queue) -> None:
    """Absorb a ticket queue's ``snapshot()``: lifecycle counters plus
    per-client EWMA throughput gauges."""
    snap = queue.snapshot()
    reg.gauge("queue.tickets_count",
              "Tickets currently tracked").set(snap["tickets"])
    reg.gauge("queue.waiting_count",
              "Tickets never yet leased").set(snap["waiting"])
    reg.gauge("queue.inflight_count",
              "Tickets leased and incomplete").set(snap["in_flight"])
    reg.counter("queue.executed_total",
                "Tickets completed").set_total(snap["executed"])
    reg.counter("queue.errors_total",
                "Client error reports").set_total(snap["errors"])
    reg.counter("queue.redistributions_total",
                "Ticket re-leases past the first").set_total(
                    snap["redistributions"])
    reg.counter("queue.releases_total",
                "Lease releases (watchdog + voluntary)").set_total(
                    snap["lease_releases"])
    reg.counter("queue.duplicate_results_total",
                "Duplicate submits dropped by first-result-wins"
                ).set_total(snap.get("duplicates", 0))
    rate = reg.gauge("queue.client_rate",
                     "Per-client EWMA tickets/second", labels=("client",))
    for client, cs in snap["clients"].items():
        # rate is None until the EWMA has its first observation
        rate.set(cs["rate"] or 0.0, client=client)


def collect_federation(reg: MetricsRegistry, fed) -> None:
    """Absorb a :class:`~repro.core.federation.FederatedDistributor`:
    per-member steals + liveness, migrations, and every edge cache."""
    steals = reg.counter("federation.steals_total",
                         "Lease grants that reached outside home shards",
                         labels=("member",))
    alive = reg.gauge("federation.alive_count", "Members currently alive")
    reg.counter("federation.migrations_total",
                "Home-shard migrations applied").set_total(fed.migrations)
    for m in fed.members:
        steals.set_total(m.steals, member=m.index)
        collect_edge(reg, m.edge)
    alive.set(len(fed.alive_members()))


def collect_transport(reg: MetricsRegistry, server) -> None:
    """Absorb a :class:`~repro.core.transport.TransportServer`'s
    ``stats()``: totals plus the per-message-type breakdown."""
    s = server.stats()
    reg.gauge("transport.connections_count",
              "Live client connections").set(s["connections"])
    reg.counter("transport.errors_total",
                "Protocol errors raised").set_total(s["protocol_errors"])
    reg.counter("transport.busy_refusals_total",
                "Hellos refused at admission (busy frames sent)"
                ).set_total(s.get("busy_refusals", 0))
    reg.counter("transport.heartbeats_total",
                "Heartbeat frames answered").set_total(
                    s.get("heartbeats", 0))
    reg.counter("transport.evictions_total",
                "Connections evicted for heartbeat silence").set_total(
                    s.get("evictions", 0))
    reg.counter("transport.evicted_leases_total",
                "Leases force-released by eviction").set_total(
                    s.get("evicted_leases", 0))
    reg.counter("transport.telemetry_frames_total",
                "Telemetry batches accepted into the fleet plane"
                ).set_total(s.get("telemetry_accepted", 0))
    reg.counter("transport.telemetry_drops_total",
                "Telemetry batches dropped (malformed, no fleet "
                "aggregator, or v1 sender)").set_total(
                    s.get("telemetry_dropped", 0))
    frames = reg.counter("transport.frames_total",
                         "Wire frames (chunk frames included)",
                         labels=("direction", "type"))
    nbytes = reg.counter("transport.bytes_total", "Wire payload bytes",
                         labels=("direction", "type"))
    chunks = reg.counter("transport.chunks_total",
                         "Binary chunk frames (protocol v2)",
                         labels=("direction",))
    chunks.set_total(s["chunks_in"], direction="in")
    chunks.set_total(s["chunks_out"], direction="out")
    by = s["by_type"]
    for direction in ("in", "out"):
        for kind, n in by[f"frames_{direction}"].items():
            frames.set_total(n, direction=direction, type=kind)
        for kind, n in by[f"bytes_{direction}"].items():
            nbytes.set_total(n, direction=direction, type=kind)


def collect_fleet(reg: MetricsRegistry, fleet) -> None:
    """Absorb a :class:`~repro.obs.fleet.FleetAggregator`'s ``stats()``:
    population, ingested batch/span volume, and every drop category
    (labelled by where the data was lost)."""
    s = fleet.stats()
    reg.gauge("fleet.clients_count",
              "Distinct clients with telemetry state").set(s["clients"])
    reg.counter("fleet.batches_total",
                "Telemetry batches ingested").set_total(s["batches_total"])
    reg.counter("fleet.spans_total",
                "Remote trace events received").set_total(s["spans_total"])
    reg.counter("fleet.skew_samples_total",
                "Clock-skew samples from heartbeat echoes").set_total(
                    s["skew_samples"])
    drops = reg.counter(
        "fleet.drops_total",
        "Telemetry discarded, by where it was lost: whole batches, "
        "span-buffer evictions, malformed series rows, the peer's own "
        "report, or the wire parser", labels=("reason",))
    drops.set_total(s["batches_dropped"], reason="batch")
    drops.set_total(s["spans_dropped"], reason="span_buffer")
    drops.set_total(s["series_dropped"], reason="series")
    drops.set_total(s["remote_dropped"], reason="remote")
    drops.set_total(s["parse_dropped"], reason="parse")


def collect_fabric(reg: MetricsRegistry, *, distributor=None,
                   transport=None, fleet=None) -> MetricsRegistry:
    """One-call collection over whatever the caller has: an
    ``AsyncDistributor`` or ``FederatedDistributor`` (origin + queue,
    plus federation surfaces when present), a ``TransportServer``,
    and/or its ``FleetAggregator``.  Returns the registry for
    chaining."""
    if distributor is not None:
        if hasattr(distributor, "download_count"):
            collect_origin(reg, distributor)
        if hasattr(distributor, "queue"):
            collect_queue(reg, distributor.queue)
        if hasattr(distributor, "members"):
            collect_federation(reg, distributor)
    if transport is not None:
        collect_transport(reg, transport)
        if fleet is None:
            fleet = getattr(transport, "fleet", None)
    if fleet is not None:
        collect_fleet(reg, fleet)
    return reg
