"""Declarative SLO monitors over the unified metrics registry.

An :class:`Slo` names a metric in a :class:`~repro.obs.metrics.
MetricsRegistry`, a statistic to extract from it, and a threshold; a
:class:`SloMonitor` evaluates a set of them on demand — the
:class:`~repro.train_fabric.round_engine.FederatedTrainer` runs its
monitor at every round close (results land in ``RoundResult.slos``),
and ``benchmarks/run.py --only obs`` uses one as a CI gate (an
injected regression must trip it; the clean run must not).

Statistics:

* ``value`` — gauge value / counter total (summed across label sets).
* ``total`` — alias of ``value`` for counters (reads as intent).
* ``count`` — a histogram's observation count.
* ``p95`` (any ``p``-prefixed quantile, e.g. ``p50``/``p99``) — the
  upper edge of the first cumulative bucket covering that fraction of
  a histogram's observations.  Bucket-quantiles are conservative
  (they round up to a bucket boundary, and a quantile past the last
  finite bucket reads as inf), which is the right bias for a latency
  gate.

A missing metric evaluates the statistic as 0.0 rather than failing —
an SLO list must be safe to attach before the subsystem it watches has
registered anything.  Breaches emit ``slo.breach`` trace instants
(cat ``warning``) when the monitor holds a tracer, so a flight
recorder can trigger on them.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

from .metrics import Histogram, MetricsRegistry
from .trace import Tracer

__all__ = ["Slo", "SloMonitor", "DEFAULT_ROUND_SLOS"]

_OPS = {
    "<=": lambda v, t: v <= t,
    "<": lambda v, t: v < t,
    ">=": lambda v, t: v >= t,
    ">": lambda v, t: v > t,
    "==": lambda v, t: v == t,
}


@dataclass(frozen=True)
class Slo:
    """One declarative objective: ``stat(metric) op threshold``."""
    name: str           # short id, e.g. "round-p95"
    metric: str         # registry metric name, e.g. "round.duration_seconds"
    op: str             # one of <=, <, >=, >, ==
    threshold: float
    stat: str = "value"  # value | total | count | pNN

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"unknown SLO op {self.op!r}")
        if (self.stat not in ("value", "total", "count")
                and not (self.stat.startswith("p")
                         and self.stat[1:].isdigit())):
            raise ValueError(f"unknown SLO stat {self.stat!r}")


def _histogram_quantile(h: Histogram, q: float) -> float:
    """Upper edge of the first cumulative bucket covering fraction
    ``q`` of observations, summed across label sets.  0.0 when empty.
    A quantile landing in the +inf bucket returns **inf** — "beyond the
    histogram's resolution" must FAIL a ``<= threshold`` latency gate,
    never clamp back under it (clamping to the last finite edge would
    make a gate at that edge untrippable)."""
    counts = [0] * len(h.buckets)
    total = 0
    with h._lock:
        for row in h._hvalues.values():
            for i in range(len(h.buckets)):
                counts[i] += row[i]
            total += row[-2]
    if total == 0:
        return 0.0
    need = q * total
    for b, c in zip(h.buckets, counts):
        if c >= need:
            return b
    return float("inf")     # unreachable: the +inf bucket holds `total`


@dataclass
class SloResult:
    slo: Slo
    value: float
    ok: bool

    def as_dict(self) -> dict:
        return {"name": self.slo.name, "metric": self.slo.metric,
                "stat": self.slo.stat, "op": self.slo.op,
                "threshold": self.slo.threshold,
                "value": self.value, "ok": self.ok}


class SloMonitor:
    """Evaluates a set of :class:`Slo` against one registry."""

    def __init__(self, registry: MetricsRegistry, slos: Sequence[Slo],
                 tracer: Optional[Tracer] = None):
        self.registry = registry
        self.slos = list(slos)
        self.tracer = tracer
        self.breaches_total = 0

    def _stat(self, slo: Slo) -> float:
        m = self.registry.get(slo.metric)
        if m is None:
            return 0.0
        if slo.stat in ("value", "total"):
            if isinstance(m, Histogram):
                return float(m.sum()) if not m.label_names else 0.0
            # counter total / gauge sum, across every label set
            with m._lock:
                return float(sum(m._values.values()))
        if slo.stat == "count":
            if isinstance(m, Histogram) and not m.label_names:
                return float(m.count())
            return 0.0
        # pNN quantile
        if not isinstance(m, Histogram):
            return 0.0
        return float(_histogram_quantile(m, int(slo.stat[1:]) / 100.0))

    def evaluate(self, *, ts: Optional[float] = None) -> List[SloResult]:
        """Evaluate every SLO now; breaches emit ``slo.breach``
        instants on the monitor's tracer (track ``slo``)."""
        out: List[SloResult] = []
        for slo in self.slos:
            v = self._stat(slo)
            ok = _OPS[slo.op](v, slo.threshold)
            out.append(SloResult(slo, v, ok))
            if not ok:
                self.breaches_total += 1
                if self.tracer is not None:
                    self.tracer.instant(
                        "slo.breach", track="slo", cat="warning", ts=ts,
                        args={"slo": slo.name, "metric": slo.metric,
                              "stat": slo.stat, "value": v,
                              "op": slo.op,
                              "threshold": slo.threshold})
        return out

    def ok(self, *, ts: Optional[float] = None) -> bool:
        return all(r.ok for r in self.evaluate(ts=ts))


#: The fabric's stock round-health objectives (ISSUE 10): latency p95,
#: zero stale weight serves, zero lost/duplicated tickets, bounded
#: eviction and busy-refusal pressure.  Callers clone-and-tune.
DEFAULT_ROUND_SLOS = (
    Slo("round-latency-p95", "round.duration_seconds", "<=", 60.0,
        stat="p95"),
    Slo("zero-stale-serves", "round.stale_executions_total", "==", 0.0,
        stat="total"),
    Slo("zero-lost-tickets", "round.lost_tickets_total", "==", 0.0,
        stat="total"),
    Slo("zero-duplicate-results", "queue.duplicate_results_total", "==",
        0.0, stat="total"),
    Slo("eviction-rate", "transport.evictions_total", "<=", 100.0,
        stat="total"),
    Slo("busy-refusal-rate", "transport.busy_refusals_total", "<=",
        1000.0, stat="total"),
)
