"""The server-side round update as a first-class abstraction.

After a federated round's barrier closes, the server turns the arrived
per-member gradients into the next round's weights:

    clip each member's gradient  →  work-weighted mean  →  optimizer

:class:`ServerStep` names that hot path so
:class:`~repro.train_fabric.round_engine.FederatedTrainingLoop` can
delegate to interchangeable implementations:

  * :class:`TreeServerStep` — the reference: one fused ``tree_map``
    weighted mean (the old ``weighted_grad_mean`` rule, f32 accumulate)
    followed by the pure-pytree optimizer, the whole step under one
    ``jax.jit``.  Works with any :class:`~repro.optim.Optimizer`.
  * :class:`FusedServerStep` — the paper's modified-AdaGrad hot path as
    ONE kernel launch: every leaf is flattened into a single f32 buffer
    and ``repro.kernels.server_step`` performs clip-weighted mean +
    accumulator + update in one pass (Pallas on TPU, the jit-fused
    oracle off-TPU, the Pallas interpreter for the bit-equivalence
    tests).  With a ``mesh``, the buffer's rows are sharded across the
    data axis via ``shard_map``/``with_sharding_constraint``.

Both paths consume identical per-member coefficients from
:func:`member_coeffs` (clip scale × normalised work weight, computed
once per round on the unflattened trees), so the two implementations
are bit-equivalent by construction — asserted across dtypes in
``tests/test_train_fabric.py``.  One caveat: XLA scalarises leaves of
1-2 elements with FMA contraction the kernels don't replay, so the
bit-for-bit guarantee starts at 3-element leaves (smaller leaves still
agree to within one f32 ulp).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.optim import Optimizer
from repro.optim.adagrad_math import adagrad_leaf_update

__all__ = ["ServerStep", "TreeServerStep", "FusedServerStep",
           "member_coeffs", "member_grad_norms", "param_count"]


def param_count(params) -> int:
    """Total scalar parameters in a pytree."""
    return int(sum(l.size for l in jax.tree_util.tree_leaves(params)))


def member_grad_norms(grads: Sequence) -> jnp.ndarray:
    """(M,) f32 global L2 norm of each member's gradient pytree.

    Per-leaf squared sums are accumulated left-to-right in flatten
    order — ONE canonical reduction order shared by every ServerStep
    implementation, so clip coefficients can never differ between the
    reference and the fused path.
    """
    norms = []
    for g in grads:
        s = None
        for leaf in jax.tree_util.tree_leaves(g):
            q = jnp.sum(jnp.square(jnp.asarray(leaf).astype(jnp.float32)))
            s = q if s is None else s + q
        norms.append(jnp.sqrt(s))
    return jnp.stack(norms)


@functools.lru_cache(maxsize=None)
def _coeffs_jit(clip_norm: Optional[float]):
    @jax.jit
    def f(grads_tuple, works):
        w = works / jnp.sum(works)
        if clip_norm is not None:
            norms = member_grad_norms(grads_tuple)
            w = w * jnp.minimum(1.0, clip_norm / jnp.maximum(norms, 1e-12))
        return w
    return f


def member_coeffs(grads: Sequence, works: Sequence[float],
                  clip_norm: Optional[float] = None) -> jnp.ndarray:
    """(M,) f32 per-member coefficient: normalised work weight times the
    member's clip scale ``min(1, clip_norm / ‖g_m‖₂)``.  The weighted
    mean of clipped gradients is then simply ``Σ_m coeff_m · g_m``.

    Every ServerStep implementation calls this — the SAME cached
    compiled function — and feeds the resulting concrete array to its
    own step, so the coefficients are bitwise identical across
    implementations no matter how each one's jit fuses its math."""
    return _coeffs_jit(clip_norm)(
        tuple(grads), jnp.asarray(list(works), jnp.float32))


class ServerStep:
    """Interface: ``step(grads, works, params, opt_state)`` →
    ``(new_params, new_opt_state)``, where ``grads`` is the round's list
    of arrived per-member gradient pytrees and ``works`` their work
    weights (same order)."""

    name = "abstract"

    def step(self, grads: Sequence, works: Sequence[float], params,
             opt_state):
        raise NotImplementedError


class TreeServerStep(ServerStep):
    """Reference implementation: clip → fused ``tree_map`` weighted mean
    → ``opt.update``, jitted end to end.  The weighted mean accumulates
    in f32 left-to-right over members (each leaf reduced in one pass, no
    per-member scaled tree copies) — the same operation order the fused
    kernel replays, which is what makes bit-equivalence testable."""

    name = "tree_map"

    def __init__(self, opt: Optimizer, *, clip_norm: Optional[float] = None):
        self.opt = opt
        self.clip_norm = clip_norm

        def impl(grads_tuple, coeffs, params, opt_state):
            def fuse(*leaves):
                acc = coeffs[0] * leaves[0].astype(jnp.float32)
                for m in range(1, len(leaves)):
                    acc = acc + coeffs[m] * leaves[m].astype(jnp.float32)
                return acc

            gmean = jax.tree_util.tree_map(fuse, *grads_tuple)
            return self.opt.update(gmean, opt_state, params)

        self._jit = jax.jit(impl)

    def step(self, grads, works, params, opt_state):
        coeffs = member_coeffs(grads, works, self.clip_norm)
        return self._jit(tuple(grads), coeffs, params, opt_state)


class FusedServerStep(ServerStep):
    """The modified-AdaGrad server step as one fused kernel pass.

    Two instantiations of the same fusion, picked by ``mode``:

      * ``"pallas"`` / ``"interpret"`` — every leaf is flattened and
        concatenated into a single f32 buffer (per-leaf dtypes restored
        on the way out), the M member gradients stacked on a leading
        axis, and ``server_step_update`` performs clip-weighted mean +
        accumulator + parameter update in ONE kernel launch; with a
        multi-device ``mesh`` the flat rows are ``shard_map``-partitioned
        across ``data_axis``.
      * ``"xla"`` (the off-TPU default) — the identical math expressed
        leafwise under one ``jax.jit``: XLA fuses the whole step into
        one elementwise program per leaf with NO flatten/concat copies
        (on CPU those copies cost more than the unfused passes they
        replace).  Same elementwise op order as the flat kernel, so all
        three modes produce bit-identical results.

    Only the paper's optimizer is fused; constructing this against a
    non-adagrad optimizer raises.
    """

    name = "fused"

    def __init__(self, opt: Optimizer, *, lr: float, beta: float = 1.0,
                 weight_decay: float = 0.0,
                 clip_norm: Optional[float] = None,
                 mode: Optional[str] = None, mesh=None,
                 data_axis: str = "data"):
        if opt.name != "adagrad":
            raise ValueError(
                f"FusedServerStep fuses the paper's modified AdaGrad; "
                f"got optimizer {opt.name!r} (use TreeServerStep)")
        from repro.kernels.server_step.ops import (resolve_mode,
                                                   server_step_update)
        self.opt = opt
        self.lr, self.beta, self.weight_decay = lr, beta, weight_decay
        self.clip_norm = clip_norm
        self.mode = resolve_mode(mode)
        self.mesh = mesh
        self.data_axis = data_axis

        def leafwise(grads_tuple, coeffs, params, acc):
            def one(p, a, *gs):
                g = coeffs[0] * gs[0].astype(jnp.float32)
                for m in range(1, len(gs)):
                    g = g + coeffs[m] * gs[m].astype(jnp.float32)
                return adagrad_leaf_update(
                    p, g, a, lr=self.lr, beta=self.beta,
                    weight_decay=self.weight_decay)

            out = jax.tree_util.tree_map(one, params, acc, *grads_tuple)
            pick = lambda i: jax.tree_util.tree_map(
                lambda o: o[i], out,
                is_leaf=lambda x: isinstance(x, tuple))
            return pick(0), pick(1)

        def flat(grads_tuple, coeffs, params, acc):
            leaves_p, tdef = jax.tree_util.tree_flatten(params)
            leaves_a = tdef.flatten_up_to(acc)
            flat32 = lambda ls: jnp.concatenate(
                [jnp.asarray(l).astype(jnp.float32).reshape(-1)
                 for l in ls])
            pf = flat32(leaves_p)
            af = flat32(leaves_a)
            gf = jnp.stack([flat32(tdef.flatten_up_to(g))
                            for g in grads_tuple])
            po, ao = server_step_update(
                pf, gf, af, coeffs, lr=self.lr, beta=self.beta,
                weight_decay=self.weight_decay, mode=self.mode,
                mesh=self.mesh, data_axis=self.data_axis)
            new_p, new_a, off = [], [], 0
            for leaf in leaves_p:
                sz = leaf.size
                new_p.append(po[off:off + sz].reshape(leaf.shape)
                             .astype(leaf.dtype))
                new_a.append(ao[off:off + sz].reshape(leaf.shape))
                off += sz
            return (jax.tree_util.tree_unflatten(tdef, new_p),
                    jax.tree_util.tree_unflatten(tdef, new_a))

        # leafwise only without a mesh: the sharded paths (GSPMD / the
        # shard_map'd kernel) need the flat row-partitioned buffer
        self._jit = jax.jit(leafwise if self.mode == "xla"
                            and mesh is None else flat)

    def step(self, grads, works, params, opt_state):
        coeffs = member_coeffs(grads, works, self.clip_norm)
        new_params, new_acc = self._jit(tuple(grads), coeffs, params,
                                        opt_state["acc"])
        return new_params, {"acc": new_acc}
