"""Training fabric: federation-scale §4.1 training as a first-class,
fault-tolerant workload.

Joins the ML stack (``repro.models`` / ``repro.optim`` /
``repro.checkpoint``) to the Sashimi fabric (``repro.core``): a round
engine with per-member shard affinity and versioned per-round weights
(:class:`FederatedTrainer`), straggler-aware K-of-N barriers, shard
rebalancing driven by the members' steal counters (:class:`Rebalancer`),
and resumable round-boundary checkpoints in the paper's JSON+base64
model-file format.  See ``docs/ARCHITECTURE.md`` §Training fabric and
``benchmarks/federated_training.py``.
"""
from repro.train_fabric.checkpointing import (CHECKPOINT_FORMAT,
                                              checkpoint_path,
                                              latest_checkpoint,
                                              load_round_checkpoint,
                                              save_round_checkpoint,
                                              state_from_tree, state_to_tree)
from repro.train_fabric.rebalancer import Migration, Rebalancer
from repro.train_fabric.round_engine import (STRAGGLER_POLICIES,
                                             EmptyRoundError,
                                             FederatedTrainer,
                                             FederatedTrainingLoop,
                                             RoundResult,
                                             affinity_placement,
                                             resolve_barrier_k)
from repro.train_fabric.server_step import (FusedServerStep, ServerStep,
                                            TreeServerStep, member_coeffs,
                                            member_grad_norms, param_count)

__all__ = [
    "CHECKPOINT_FORMAT", "EmptyRoundError", "FederatedTrainer",
    "FederatedTrainingLoop", "FusedServerStep", "Migration", "Rebalancer",
    "RoundResult", "STRAGGLER_POLICIES", "ServerStep", "TreeServerStep",
    "affinity_placement", "checkpoint_path", "latest_checkpoint",
    "load_round_checkpoint", "member_coeffs", "member_grad_norms",
    "param_count", "resolve_barrier_k", "save_round_checkpoint",
    "state_from_tree", "state_to_tree",
]
