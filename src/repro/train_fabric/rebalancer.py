"""Shard rebalancing: move a hot task's shard between federation members
when load skews.

The signal was already there — each :class:`~repro.core.federation.
FederationMember` counts ``steals`` (lease grants that had to reach
outside its home shards).  A member that steals round after round is
telling us its home set is chronically dry while some other member's
home shards carry the backlog; every one of those steals pays the
full-fabric merge (all shard locks peeked) instead of the home fast
path.  The :class:`Rebalancer` turns the counter into action: when a
member's steal *delta* over an observation window crosses the threshold,
the donor member whose home shards hold the most waiting tickets gives
its busiest shard to the thief (``FederatedDistributor.migrate_shard``).

Two extra rules keep it stable and fault-aware:

  * **cool-down** — at most one migration per ``cooldown`` observation
    windows, so a transient imbalance can't make shards ping-pong;
  * **failover** — a dead member's home shards are orphaned (nobody
    serves them from the fast path; every grant against them is a
    steal), so they are migrated to survivors first, round-robin,
    regardless of counters.

The trainer calls :meth:`Rebalancer.observe_round` at round boundaries;
any long-running producer can do the same on its own cadence.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


@dataclass
class Migration:
    """One home-shard move, for consoles and tests."""

    shard_index: int
    from_member: int
    to_member: int
    reason: str                  # "steals" | "failover"


class Rebalancer:
    """Watch per-member steal counters; migrate home shards to the
    members that keep having to steal (and off dead members)."""

    def __init__(self, federation, *, steal_threshold: int = 4,
                 cooldown: int = 2, metrics=None):
        self.fed = federation
        self.steal_threshold = steal_threshold
        self.cooldown = cooldown
        self._last_steals = {m.index: m.steals for m in federation.members}
        self._since_migration = cooldown       # first window may migrate
        self.history: list[Migration] = []
        self.metrics = metrics
        if metrics is not None:
            self._m_migrations = metrics.counter(
                "rebalancer.migrations_total",
                "Home-shard migrations performed by the rebalancer",
                labels=("reason",))

    # -- helpers --------------------------------------------------------------

    def _waiting_by_member(self) -> dict[int, int]:
        """Waiting-ticket backlog summed over each member's home shards."""
        return {m.index: sum(sh.snapshot()["waiting"]
                             for sh in m.home_shards)
                for m in self.fed.members}

    def _busiest_home_shard(self, member) -> Optional[int]:
        """The member's home shard with the most waiting tickets."""
        best: tuple[int, Optional[int]] = (-1, None)
        for sh in member.home_shards:
            w = sh.snapshot()["waiting"]
            idx = next(j for j, q in enumerate(self.fed.queue.shards)
                       if q is sh)
            if w > best[0]:
                best = (w, idx)
        return best[1]

    def _migrate(self, shard_index: int, donor: int, to_member: int,
                 reason: str) -> Optional[Migration]:
        if not self.fed.migrate_shard(shard_index, to_member):
            return None
        mig = Migration(shard_index, donor, to_member, reason)
        self.history.append(mig)
        if self.metrics is not None:
            self._m_migrations.inc(reason=reason)
        return mig

    # -- the per-round hook ----------------------------------------------------

    def observe_round(self) -> list[Migration]:
        """One observation window: fail over dead members' shards, then
        (at most once per cool-down) move the busiest backlogged shard to
        the member with the largest steal delta.  Returns the migrations
        performed this window (usually empty)."""
        out: list[Migration] = []
        alive = self.fed.alive_members()
        if not alive:
            return out

        # failover first: orphaned home shards to survivors, round-robin
        rr = 0
        for m in self.fed.members:
            if m.alive:
                continue
            for sh in list(m.home_shards):
                idx = next(j for j, q in enumerate(self.fed.queue.shards)
                           if q is sh)
                target = alive[rr % len(alive)].index
                mig = self._migrate(idx, m.index, target, "failover")
                if mig is not None:
                    out.append(mig)
                    rr += 1

        # steal-driven migration, throttled by the cool-down
        deltas = {}
        for m in self.fed.members:
            deltas[m.index] = m.steals - self._last_steals.get(m.index, 0)
            self._last_steals[m.index] = m.steals
        self._since_migration += 1
        if self._since_migration <= self.cooldown:
            return out
        thief_idx = max((i for i in deltas if self.fed.members[i].alive),
                        key=lambda i: deltas[i], default=None)
        if thief_idx is None or deltas[thief_idx] < self.steal_threshold:
            return out
        waiting = self._waiting_by_member()
        donor_idx = max((i for i in waiting if i != thief_idx
                         and self.fed.members[i].alive
                         and len(self.fed.members[i].home_shards) > 1),
                        key=lambda i: waiting[i], default=None)
        if donor_idx is None or waiting[donor_idx] == 0:
            return out
        shard_idx = self._busiest_home_shard(self.fed.members[donor_idx])
        if shard_idx is None:
            return out
        mig = self._migrate(shard_idx, donor_idx, thief_idx, "steals")
        if mig is not None:
            out.append(mig)
            self._since_migration = 0
        return out


__all__ = ["Migration", "Rebalancer"]
