"""Resumable training rounds: full ``TrainState`` + trainer metadata in
the paper's JSON+base64 model-file format.

The paper exchanges model files as "a platform independent string format
... without rounding errors"; :mod:`repro.checkpoint.serialization`
already gives us the bit-exact pytree codec.  This module adds the
**round checkpoint** envelope on top: the complete
:class:`~repro.core.split_parallel.TrainState` (params, head and
stale-head slots, both optimizer states, feature-replay buffers, step
counter — every leaf, bf16 included), the round index it was taken at,
and a free-form ``extra`` dict for trainer configuration, all in one
JSON document.

Checkpoints are written **atomically** (temp file + ``os.replace``) at
round boundaries, so a kill mid-write can never leave a torn file: a
resumed run either sees round *t*'s complete checkpoint or round
*t−1*'s.  ``load_round_checkpoint`` + the trainer's ``start_round``
reproduce the unkilled loss trajectory exactly — the codec is
bit-preserving and the round engine is deterministic given the same
shard plan.
"""
from __future__ import annotations

import os
from dataclasses import fields
from typing import Any, Optional

from repro.checkpoint.serialization import tree_from_json, tree_to_json
from repro.core.split_parallel import TrainState

#: Envelope tag; bump on layout changes so a resume can fail loudly
#: instead of mis-reading an old file.
CHECKPOINT_FORMAT = "sashimi-train-ckpt-v1"


def state_to_tree(state: TrainState) -> dict:
    """The ``TrainState`` dataclass as a plain field-name → subtree dict
    (the JSON codec speaks dict/list/tuple/scalar/array, not registered
    dataclasses)."""
    return {f.name: getattr(state, f.name) for f in fields(TrainState)}


def state_from_tree(tree: dict) -> TrainState:
    """Inverse of :func:`state_to_tree`."""
    return TrainState(**{f.name: tree[f.name] for f in fields(TrainState)})


def save_round_checkpoint(path: str, state: TrainState, *,
                          round_index: int,
                          extra: Optional[dict] = None) -> str:
    """Write a round-boundary checkpoint atomically; returns ``path``.

    ``round_index`` is the number of rounds COMPLETED — a resume
    continues from round ``round_index`` (zero-based), and its first
    gradient step sees exactly the params this state carries."""
    doc = {"format": CHECKPOINT_FORMAT,
           "round": int(round_index),
           "extra": dict(extra or {}),
           "state": state_to_tree(state)}
    payload = tree_to_json(doc)
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        f.write(payload)
    os.replace(tmp, path)
    return path


def load_round_checkpoint(path: str) -> tuple[TrainState, int, dict]:
    """Read a round checkpoint; returns ``(state, round_index, extra)``.
    Raises ``ValueError`` on an unknown envelope format."""
    with open(path) as f:
        doc = tree_from_json(f.read())
    if doc.get("format") != CHECKPOINT_FORMAT:
        raise ValueError(
            f"not a {CHECKPOINT_FORMAT} checkpoint: {doc.get('format')!r}")
    return state_from_tree(doc["state"]), int(doc["round"]), doc["extra"]


def latest_checkpoint(directory: str,
                      prefix: str = "round") -> Optional[str]:
    """The highest-round ``<prefix>_<n>.json`` checkpoint in
    ``directory`` (None when there is none) — the resume entry point."""
    best: tuple[int, Optional[str]] = (-1, None)
    if not os.path.isdir(directory):
        return None
    for name in os.listdir(directory):
        if not (name.startswith(f"{prefix}_") and name.endswith(".json")):
            continue
        try:
            n = int(name[len(prefix) + 1:-len(".json")])
        except ValueError:
            continue
        if n > best[0]:
            best = (n, os.path.join(directory, name))
    return best[1]


def checkpoint_path(directory: str, round_index: int,
                    prefix: str = "round") -> str:
    """Canonical per-round checkpoint filename."""
    return os.path.join(directory, f"{prefix}_{round_index}.json")


__all__ = ["CHECKPOINT_FORMAT", "checkpoint_path", "latest_checkpoint",
           "load_round_checkpoint", "save_round_checkpoint",
           "state_from_tree", "state_to_tree"]
