"""The federation-scale round engine for §4.1 training.

``SplitConcurrentDispatcher`` (PR 1) drives one training step's backbone
shards through ONE ``AsyncDistributor`` and waits for every result.
This module generalises that into a **training fabric** workload over
the whole stack — sharded store, federation members, edge caches,
cross-host transport:

  * :class:`FederatedTrainer` — the round engine.  Each round's shards
    are enqueued with **per-member shard affinity** (spread across the
    alive members' home shards via ``add_work(shard=...)``, so each
    member serves its slice from its own locks), per-round weights are
    published through the PR 3 versioned-statics path BEFORE the tickets
    pin their coherence version (a client can never compute round *t*
    against round *t−1* weights, no matter how its cache is warmed), and
    the round closes through a **straggler-aware K-of-N barrier**.
  * :class:`FederatedTrainingLoop` — round-based data-parallel SGD on
    top of the engine: publish weights → fan gradient shards → work-
    weighted aggregate → server-side optimizer step, with full
    ``TrainState`` checkpoints at round boundaries (resumable — see
    ``checkpointing.py``).

Straggler policies (paper §4: heterogeneous devices — one slow browser
must not stall the fleet):

  * ``"wait"``     — classic full barrier: the round closes only when
                     all N shard gradients arrive.
  * ``"reticket"`` — when K of N have arrived, the laggards' leases are
                     force-released (VCT reset), so idle fast clients
                     redo them immediately; the round still closes with
                     all N gradients — **exact** math, bounded tail.
  * ``"fold"``     — when K of N have arrived, the laggard tickets are
                     cancelled and the round closes with the K arrived
                     gradients; the work-weighted ``aggregate`` then
                     normalises over the arrived work only (approximate
                     math, hard latency bound).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field, replace
from typing import Any, Optional

import jax.numpy as jnp

from repro.core.split_parallel import (RoundDriverLifetime, TrainState,
                                       adaptive_shard_sizes)
from repro.core.tickets import CANCELLED
from repro.train_fabric.checkpointing import (checkpoint_path,
                                              save_round_checkpoint)
from repro.train_fabric.server_step import (ServerStep, TreeServerStep,
                                            param_count)

STRAGGLER_POLICIES = ("wait", "reticket", "fold")


class EmptyRoundError(RuntimeError):
    """A round closed with ZERO arrived gradients (every shard folded or
    timed out), so there is nothing to aggregate: applying an optimizer
    step here would silently train on garbage (a 0/0 weighted mean).
    Carries the offending :class:`RoundResult` so callers can inspect
    which shards straggled and decide whether to retry the round or
    abort; the loop leaves its state untouched (same ``round_index``,
    same params), so a retry is just calling ``run_round`` again."""

    def __init__(self, round_index: int, result: "RoundResult"):
        super().__init__(
            f"training round {round_index} closed with 0 of "
            f"{len(result.ticket_ids)} shard gradients arrived "
            f"({len(result.stragglers)} straggler(s) folded) — nothing "
            f"to aggregate")
        self.round_index = round_index
        self.result = result


def resolve_barrier_k(n: int, barrier_k) -> int:
    """Concrete K for an N-shard round: ``None`` → N (full barrier), a
    float in (0, 1] → ``ceil(frac * N)``, an int → clamped to [1, N]."""
    if barrier_k is None:
        return n
    if isinstance(barrier_k, float):
        if not 0.0 < barrier_k <= 1.0:
            raise ValueError(f"fractional barrier_k must be in (0, 1], "
                             f"got {barrier_k}")
        return max(1, min(n, math.ceil(barrier_k * n)))
    return max(1, min(n, int(barrier_k)))


def affinity_placement(distributor, n: int
                       ) -> Optional[dict[int, list[int]]]:
    """{queue-shard index: [round-shard positions]} spreading an N-shard
    round across the alive members' home shards (None when the
    distributor has no federation surface — plain single ``add_work``).
    Standalone so planners (benchmark sims, dashboards) can use it
    without constructing a trainer and taking client-lifetime
    ownership."""
    if not hasattr(distributor, "alive_members"):
        return None
    homes = [(m.index, distributor.home_shard_indices(m.index))
             for m in distributor.alive_members()]
    homes = [(i, hs) for i, hs in homes if hs]
    if not homes:
        return None
    groups: dict[int, list[int]] = {}
    for pos in range(n):
        _, hs = homes[pos % len(homes)]
        shard = hs[(pos // len(homes)) % len(hs)]
        groups.setdefault(shard, []).append(pos)
    return groups


@dataclass
class RoundResult:
    """One closed training round."""

    index: int                      # round number (zero-based)
    results: list                   # per-shard results; None where folded
    ticket_ids: list
    arrived: list                   # shard positions that arrived
    stragglers: list = field(default_factory=list)   # positions folded
    reticketed: int = 0             # laggard tickets force-released
    work_arrived: float = 0.0
    work_total: float = 0.0
    duration: float = 0.0           # on the queue's (injectable) clock
    barrier_wait: float = 0.0       # clock time between K-of-N and close
    migrations: int = 0             # rebalancer moves at this boundary
    metrics: Optional[dict] = None  # registry snapshot, when trainer has one
    slos: Optional[list] = None     # SLO evaluations at round close, when
    #                                 the trainer holds an SloMonitor
    publish_deltas: dict = field(default_factory=dict)
    # per published static: the origin registry's delta view at publish
    # time ({"version", "leaves", "changed", "window"}) — ``changed``
    # counts the leaf arrays a v2 client actually downloads this round
    # (the wire-protocol delta payload); ``leaves`` is what a v1 client
    # or cold cache pulls.  Empty when the distributor predates the v2
    # delta registry.

    @property
    def complete(self) -> bool:
        """True when every shard's gradient arrived (nothing folded)."""
        return not self.stragglers

    @property
    def slo_ok(self) -> bool:
        """True when no SLO breached at round close (vacuously true
        when the trainer evaluates none)."""
        return all(r["ok"] for r in self.slos) if self.slos else True


class FederatedTrainer(RoundDriverLifetime):
    """Round engine over any distributor duck-typing the v2 surface
    (``AsyncDistributor``, ``FederatedDistributor`` — in-process clients
    or remote ones behind a ``TransportServer`` alike).

    Owns the client lifetime explicitly (``RoundDriverLifetime``):
    constructing the trainer flips the distributor to ``keep_alive``
    (clients must survive drained queues between rounds) and
    :meth:`aclose` — or the async context manager — restores the
    caller's original mode, so a discarded trainer can't leave the
    distributor in a changed state."""

    def __init__(self, distributor, *, task_name: str = "backbone_shard",
                 barrier_k=None, straggler_policy: str = "wait",
                 timeout: float = 60.0, stall_after: Optional[float] = None,
                 rebalancer=None, metrics=None, slos=None):
        if straggler_policy not in STRAGGLER_POLICIES:
            raise KeyError(f"straggler_policy must be one of "
                           f"{STRAGGLER_POLICIES}, got {straggler_policy!r}")
        self._own_clients(distributor)
        self.task_name = task_name
        self.barrier_k = barrier_k
        self.straggler_policy = straggler_policy
        self.timeout = timeout
        # a round STALLS when no new shard arrives for ``stall_after``
        # clock seconds while it is still open — the symptom of a churned
        # fleet whose stranded leases are not coming back.  Stalls are
        # counted (and traced) without aborting the round: eviction or
        # the watchdog may still rescue it before ``timeout``.  The chaos
        # harness asserts this counter stays 0 under 20%/round churn.
        self.stall_after = stall_after
        self.rebalancer = rebalancer
        self.rounds = 0
        self.stalls = 0
        self.reticketed_total = 0
        self.folded_total = 0
        self.tracer = getattr(distributor, "tracer", None)
        self.metrics = metrics
        if metrics is not None:
            self._m_duration = metrics.histogram(
                "round.duration_seconds",
                "Virtual-clock duration of each closed training round")
            self._m_barrier = metrics.histogram(
                "round.barrier_wait_seconds",
                "Clock time spent waiting between K-of-N and round close")
            self._m_reticketed = metrics.counter(
                "round.reticketed_total",
                "Laggard leases force-released by the reticket policy")
            self._m_folded = metrics.counter(
                "round.folded_total",
                "Straggler shards folded (cancelled) at round close")
            self._m_timeouts = metrics.counter(
                "round.timeouts_total", "Training rounds abandoned on timeout")
            self._m_stalls = metrics.counter(
                "round.stalls_total",
                "Open rounds that made no progress for stall_after seconds")
            self._m_lost = metrics.counter(
                "round.lost_tickets_total",
                "Shard tickets abandoned un-arrived at a round timeout")
        # declarative round-health objectives (repro.obs.slo), evaluated
        # at every round close against the trainer's registry; results
        # land in RoundResult.slos and breaches emit slo.breach instants
        self.slo_monitor = None
        if slos:
            if metrics is None:
                raise ValueError("slos= requires metrics= (the monitor "
                                 "evaluates against the registry)")
            from repro.obs.slo import SloMonitor
            self.slo_monitor = SloMonitor(metrics, slos, tracer=self.tracer)

    # -- shard planning --------------------------------------------------------

    def _live_rates(self) -> dict:
        """Measured per-client rates, minus clients known to be gone
        (dead members' clients, finished in-process clients) — their
        EWMA entries outlive them in ``queue.stats``, and a phantom
        client must not be apportioned a shard nobody will execute.
        Remote clients can't be enumerated and stay in (their rates age
        out of relevance only by not being refreshed)."""
        if not hasattr(self.dist, "client_rates"):
            return {}
        rates = {c: r for c, r in self.dist.client_rates().items() if r}
        gone: set = set()
        for m in getattr(self.dist, "members", [self.dist]):
            gone.update(c.profile.name for c in getattr(m, "clients", ())
                        if c.done or not getattr(m, "alive", True))
        return {c: r for c, r in rates.items() if c not in gone}

    def plan_shards(self, global_batch: int, *, default_shards: int = 4,
                    min_shard: int = 1) -> list[int]:
        """Row counts per shard for the next round, sized to **measured**
        per-client EWMA throughput (``client_rates``) so every client's
        slice takes about the same wall time — the barrier closes as one.
        Before any measurement (or without rates) the batch splits into
        ``default_shards`` near-equal slices."""
        rates = self._live_rates()
        if not rates:
            k = min(default_shards, global_batch)
            base, rem = divmod(global_batch, k)
            return [base + (1 if i < rem else 0) for i in range(k)]
        sizes = adaptive_shard_sizes(rates, global_batch,
                                     min_shard=min_shard)
        return [s for s in sizes.values() if s > 0]

    # -- affinity placement ----------------------------------------------------

    def placement(self, n: int) -> Optional[dict[int, list[int]]]:
        """Per-member affinity map for an N-shard round (see
        :func:`affinity_placement`)."""
        return affinity_placement(self.dist, n)

    # -- the round -------------------------------------------------------------

    def _reticket_stragglers(self, laggard_tids) -> int:
        """Force-release every outstanding lease holding a laggard ticket
        (VCT reset → immediately eligible), so idle fast clients redo the
        stragglers' work; the slow client's own late submit is folded by
        the queue's first-result-wins rule."""
        lagset = set(laggard_tids)
        released = 0
        for batch in self.dist.queue.outstanding_leases():
            if lagset & set(batch.ticket_ids):
                released += self.dist.queue.release(batch.lease_id,
                                                    client_failed=False)
        if released:
            self._notify()
        return released

    async def run_round(self, shard_args, *, shard_work=None,
                        statics=None, timeout: Optional[float] = None
                        ) -> RoundResult:
        """Execute one training round through the fabric.

        ``statics`` (e.g. this round's weights) are re-registered on the
        origin BEFORE the tickets are enqueued, so the tickets pin the
        new coherence version and every client revalidates before
        executing.  Re-registering through the v2 delta registry stamps
        each leaf array with the version it last changed, so remote v2
        clients revalidating against a warm cache download only the
        changed leaves (``RoundResult.publish_deltas`` records the
        per-key delta view).  Returns a :class:`RoundResult` with
        per-shard results ordered like ``shard_args`` (None where the
        barrier folded a straggler)."""
        if self._closed:
            raise RuntimeError("trainer is closed")
        n = len(shard_args)
        if shard_work is None:
            shard_work = [1.0] * n
        publish_deltas: dict = {}
        if statics:
            stats_fn = getattr(self.dist, "static_delta_stats", None)
            for key, value in statics.items():
                self.dist.add_static(key, value)
                if stats_fn is not None:
                    publish_deltas[key] = stats_fn(key)
        t0 = self.dist.queue.clock()
        groups = self.placement(n)
        if groups is None:
            tids = list(self.dist.add_work(self.task_name, list(shard_args),
                                           work=list(shard_work)))
        else:
            tids: list = [None] * n
            for shard, positions in groups.items():
                got = self.dist.add_work(
                    self.task_name, [shard_args[p] for p in positions],
                    work=[shard_work[p] for p in positions], shard=shard)
                for p, tid in zip(positions, got):
                    tids[p] = tid
        k = resolve_barrier_k(n, self.barrier_k)
        timeout = self.timeout if timeout is None else timeout
        deadline = t0 + timeout
        wall_deadline = time.monotonic() + max(timeout, 60.0)
        reticketed = 0
        did_reticket = False
        folded: list[int] = []
        tr = self.tracer
        round_span = None
        span_status = "ok"
        if tr is not None:
            round_span = tr.begin(
                "round", track="trainer", cat="round", lane=True, ts=t0,
                args={"round": self.rounds, "shards": n, "barrier_k": k,
                      "policy": self.straggler_policy})
        barrier_open: Optional[float] = None   # clock when K-of-N reached
        progress_count = -1                # arrivals at last progress mark
        progress_at = t0
        stalled = False                    # at most one stall per round
        try:
            while True:
                # capture the wake epoch before probing: a submit can only
                # land at an await point, so a notification can't be missed
                wake = self.dist._wake_event()
                done = self.dist.queue.completed_results(tids)
                if len(done) > progress_count:
                    progress_count = len(done)
                    progress_at = self.dist.queue.clock()
                elif (self.stall_after is not None and not stalled
                        and self.dist.queue.clock() - progress_at
                        > self.stall_after):
                    stalled = True
                    self.stalls += 1
                    if self.metrics is not None:
                        self._m_stalls.inc()
                    if tr is not None:
                        tr.instant("round.stall", track="trainer",
                                   cat="warning",
                                   ts=self.dist.queue.clock(),
                                   args={"round": self.rounds,
                                         "arrived": len(done), "n": n,
                                         "stalled_for": self.stall_after})
                if len(done) >= k and barrier_open is None:
                    barrier_open = self.dist.queue.clock()
                    if tr is not None:
                        tr.instant("round.barrier_open", track="trainer",
                                   cat="round", ts=barrier_open,
                                   args={"round": self.rounds,
                                         "arrived": len(done), "k": k})
                if len(done) >= n:
                    break
                if len(done) >= k and self.straggler_policy != "wait":
                    laggards = [tid for tid in tids if tid not in done]
                    if self.straggler_policy == "fold":
                        self.dist.queue.cancel(laggards)
                        self._notify()
                        done = self.dist.queue.completed_results(tids)
                        if tr is not None:
                            tr.instant(
                                "round.fold", track="trainer", cat="round",
                                ts=self.dist.queue.clock(),
                                args={"round": self.rounds,
                                      "folded": len(laggards)})
                        break
                    if not did_reticket:      # once per round: no thrash
                        did_reticket = True
                        reticketed = self._reticket_stragglers(laggards)
                        if tr is not None:
                            tr.instant(
                                "round.reticket", track="trainer",
                                cat="round", ts=self.dist.queue.clock(),
                                args={"round": self.rounds,
                                      "laggards": len(laggards),
                                      "released": reticketed})
                if (self.dist.queue.clock() > deadline
                        or time.monotonic() > wall_deadline):
                    # abandon the round cleanly: cancel the stragglers and
                    # prune everything so the queue doesn't keep zombie
                    # tickets leasable (and all_done() poisoned) after the
                    # caller handles the timeout
                    span_status = "timeout"
                    if self.metrics is not None:
                        self._m_timeouts.inc()
                        self._m_lost.inc(n - len(done))
                    if tr is not None:
                        tr.instant("round.timeout", track="trainer",
                                   cat="round", ts=self.dist.queue.clock(),
                                   args={"round": self.rounds,
                                         "arrived": len(done), "n": n})
                    self.dist.queue.cancel(
                        [tid for tid in tids if tid not in done])
                    self._notify()
                    self.dist.queue.prune(tids)
                    raise TimeoutError(
                        f"training round {self.rounds} unfinished: "
                        f"{self.dist.console()}")
                await self.dist._wait_on(wake, 0.05)
        finally:
            if tr is not None:
                tr.end(round_span, ts=self.dist.queue.clock(),
                       args={"status": span_status})
        # forget the finished round so queue scans stay O(one round)
        self.dist.queue.prune(tids)
        results, arrived, stragglers = [], [], []
        for pos, tid in enumerate(tids):
            r = done.get(tid)
            if r is CANCELLED or tid not in done:
                results.append(None)
                stragglers.append(pos)
            else:
                results.append(r)
                arrived.append(pos)
        migrations = 0
        if self.rebalancer is not None:
            migrations = len(self.rebalancer.observe_round())
        t_close = self.dist.queue.clock()
        barrier_wait = (t_close - barrier_open
                        if barrier_open is not None else 0.0)
        out = RoundResult(
            index=self.rounds, results=results, ticket_ids=tids,
            arrived=arrived, stragglers=stragglers, reticketed=reticketed,
            work_arrived=sum(shard_work[p] for p in arrived),
            work_total=float(sum(shard_work)),
            duration=t_close - t0, barrier_wait=barrier_wait,
            migrations=migrations, publish_deltas=publish_deltas)
        self.rounds += 1
        self.reticketed_total += reticketed
        self.folded_total += len(stragglers)
        if self.metrics is not None:
            self._m_duration.observe(out.duration)
            self._m_barrier.observe(barrier_wait)
            if reticketed:
                self._m_reticketed.inc(reticketed)
            if stragglers:
                self._m_folded.inc(len(stragglers))
            if self.slo_monitor is not None:
                out.slos = [r.as_dict() for r in
                            self.slo_monitor.evaluate(ts=t_close)]
            out.metrics = self.metrics.snapshot()
        return out


class FederatedTrainingLoop:
    """Round-based data-parallel SGD over a :class:`FederatedTrainer`.

    Server side (this object): holds the full
    :class:`~repro.core.split_parallel.TrainState`, publishes the current
    params each round as the versioned ``weights_key`` static (tagged
    with the round number; over the v2 wire protocol a warm remote
    client then downloads only the param leaves that changed since its
    cached round — per-round weight deltas), aggregates the arrived
    shard gradients with
    the work-weighted mean, applies the optimizer, and checkpoints at
    round boundaries.  Client side: the task registered under the
    trainer's ``task_name`` receives ``static[weights_key] = {"round": t,
    "params": ...}`` and must return ``{grad_key: grad_pytree,
    loss_key: float, "round": t_seen}`` per shard — the echoed round tag
    lets the loop count stale-weight executions (zero by construction;
    asserted in the benchmark)."""

    def __init__(self, trainer: FederatedTrainer, opt, state: TrainState, *,
                 weights_key: str = "weights", grad_key: str = "grad",
                 loss_key: str = "loss", round_index: int = 0,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 1,
                 extra: Optional[dict] = None,
                 server_step: Optional[ServerStep] = None):
        self.trainer = trainer
        self.opt = opt
        self.state = state
        self.weights_key = weights_key
        self.grad_key = grad_key
        self.loss_key = loss_key
        self.round_index = round_index
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self.extra = dict(extra or {})
        self.losses: list[float] = []
        self.stale_executions = 0
        self.server_step = (server_step if server_step is not None
                            else TreeServerStep(opt))
        self._m_step_s = self._m_params = self._m_stale = None
        if trainer.metrics is not None:
            self._m_step_s = trainer.metrics.histogram(
                "round.server_step_seconds",
                "Wall time of the server-side aggregate+update step")
            self._m_params = trainer.metrics.gauge(
                "round.model_params_count",
                "Scalar parameters in the model being trained")
            self._m_params.set(param_count(state.params))
            self._m_stale = trainer.metrics.counter(
                "round.stale_executions_total",
                "Arrived gradients computed against a previous round's "
                "weights (zero by construction; SLO-gated)")

    async def run_round(self, shard_args, shard_work) -> RoundResult:
        """One SGD round: publish → fan out → aggregate → update →
        checkpoint.  Records the round's work-weighted training loss."""
        t = self.round_index
        res = await self.trainer.run_round(
            shard_args, shard_work=shard_work,
            statics={self.weights_key: {"round": t,
                                        "params": self.state.params}})
        got = [res.results[p] for p in res.arrived]
        if not got:
            tr = self.trainer.tracer
            if tr is not None:
                tr.instant("round.empty_fold", track="trainer", cat="round",
                           ts=self.trainer.dist.queue.clock(),
                           args={"round": t,
                                 "stragglers": len(res.stragglers)})
            raise EmptyRoundError(t, res)
        for g in got:
            if isinstance(g, dict) and g.get("round", t) != t:
                self.stale_executions += 1
                if self._m_stale is not None:
                    self._m_stale.inc()
        works = [shard_work[p] for p in res.arrived]
        t_step = time.perf_counter()
        new_params, new_opt = self.server_step.step(
            [g[self.grad_key] for g in got], works,
            self.state.params, self.state.opt_state)
        if self._m_step_s is not None:
            self._m_step_s.observe(time.perf_counter() - t_step)
        self.state = replace(
            self.state, params=new_params, opt_state=new_opt,
            step=jnp.asarray(self.state.step) + 1)
        loss = float(sum(g[self.loss_key] * w for g, w in zip(got, works))
                     / sum(works))
        self.losses.append(loss)
        self.round_index = t + 1
        if (self.checkpoint_dir is not None and self.checkpoint_every
                and self.round_index % self.checkpoint_every == 0):
            self.checkpoint()
        return res

    def checkpoint(self) -> str:
        """Write the round-boundary checkpoint (atomic; resumable with
        :func:`~repro.train_fabric.checkpointing.load_round_checkpoint`)."""
        extra = {"task_name": self.trainer.task_name,
                 "straggler_policy": self.trainer.straggler_policy,
                 "losses": list(self.losses), **self.extra}
        return save_round_checkpoint(
            checkpoint_path(self.checkpoint_dir, self.round_index),
            self.state, round_index=self.round_index, extra=extra)


__all__ = ["EmptyRoundError", "FederatedTrainer", "FederatedTrainingLoop",
           "RoundResult", "STRAGGLER_POLICIES", "affinity_placement",
           "resolve_barrier_k"]
