"""Checkpointing.

Two formats:
  * the paper's model-file format — JSON with **base64-encoded parameters**
    ("although the model file is a platform independent string format, it can
    be exchanged among machines without rounding errors") — bit-exact
    round-trip, used for cross-host exchange;
  * a fast ``.npz`` path for large checkpoints.
"""
from __future__ import annotations

import base64
import json
from typing import Any

import jax
import numpy as np


def _encode_leaf(x) -> dict:
    a = np.asarray(x)
    return {
        "__tensor__": True,
        "dtype": str(a.dtype),
        "shape": list(a.shape),
        "data": base64.b64encode(a.tobytes()).decode("ascii"),
    }


def _decode_leaf(d: dict):
    a = np.frombuffer(base64.b64decode(d["data"]),
                      dtype=np.dtype(d["dtype"]))
    return a.reshape(d["shape"]).copy()


def tree_to_json(tree) -> str:
    """Serialise a pytree of arrays to the paper's JSON+base64 format."""

    def conv(x):
        if isinstance(x, dict):
            return {"__dict__": {k: conv(v) for k, v in x.items()}}
        if isinstance(x, (list, tuple)):
            tag = "__list__" if isinstance(x, list) else "__tuple__"
            return {tag: [conv(v) for v in x]}
        if isinstance(x, (int, float, str, bool)) or x is None:
            return {"__scalar__": x}
        return _encode_leaf(x)

    return json.dumps(conv(tree))


def tree_from_json(s: str):
    def conv(d):
        if "__dict__" in d:
            return {k: conv(v) for k, v in d["__dict__"].items()}
        if "__list__" in d:
            return [conv(v) for v in d["__list__"]]
        if "__tuple__" in d:
            return tuple(conv(v) for v in d["__tuple__"])
        if "__scalar__" in d:
            return d["__scalar__"]
        return _decode_leaf(d)

    return conv(json.loads(s))


def save_json_model(path: str, tree) -> None:
    tree = jax.tree_util.tree_map(np.asarray, tree)
    with open(path, "w") as f:
        f.write(tree_to_json(tree))


def load_json_model(path: str):
    with open(path) as f:
        return tree_from_json(f.read())


# --- npz fast path ---------------------------------------------------------


def _flatten_paths(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten_paths(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        tag = "T" if isinstance(tree, tuple) else "L"
        for i, v in enumerate(tree):
            out.update(_flatten_paths(v, f"{prefix}__{tag}{i}/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def save_npz(path: str, tree) -> None:
    np.savez(path, **_flatten_paths(tree))


def load_npz(path: str):
    flat = dict(np.load(path))

    def insert(root, keys, val):
        k = keys[0]
        if len(keys) == 1:
            root[k] = val
            return
        root = root.setdefault(k, {})
        insert(root, keys[1:], val)

    nested: dict = {}
    for k, v in flat.items():
        insert(nested, k.split("/"), v)

    def fix(node):
        if isinstance(node, dict):
            keys = list(node.keys())
            if keys and all(k.startswith("__T") or k.startswith("__L")
                            for k in keys):
                seq = [fix(node[k]) for k in sorted(
                    keys, key=lambda s: int(s[3:]))]
                return tuple(seq) if keys[0].startswith("__T") else seq
            return {k: fix(v) for k, v in node.items()}
        return node

    return fix(nested)
