from repro.checkpoint.serialization import (
    load_json_model,
    load_npz,
    save_json_model,
    save_npz,
    tree_from_json,
    tree_to_json,
)

__all__ = [
    "load_json_model",
    "load_npz",
    "save_json_model",
    "save_npz",
    "tree_from_json",
    "tree_to_json",
]
