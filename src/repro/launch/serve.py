"""Serving driver: batched prefill + decode with the KV-cache/state path.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch_config, get_smoke_config
from repro.models.model import build_model


def generate(api, params, prompts, *, gen: int, extra_inputs=None):
    """Greedy decode ``gen`` tokens after batched prefill.

    prompts: (B, S) int32.  Returns (B, gen) int32.
    """
    cfg = api.cfg
    b, s = prompts.shape
    batch = {"tokens": prompts}
    if extra_inputs:
        batch.update(extra_inputs)
    total = s + gen
    logits, cache = jax.jit(
        lambda p, bt: api.prefill(p, bt, cache_len=total))(params, batch)

    jstep = jax.jit(api.decode_step)
    out = []
    tok = jnp.argmax(logits[:, :, :cfg.vocab_size], axis=-1).astype(jnp.int32)
    for i in range(gen):
        out.append(tok[:, 0])
        logits, cache = jstep(params, cache, tok, jnp.int32(s + i))
        tok = jnp.argmax(logits[:, :, :cfg.vocab_size],
                         axis=-1).astype(jnp.int32)
    return jnp.stack(out, axis=1)


def main():
    """CLI entry: serve a model (prefill+decode loop) from a config id."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_arch_config(args.arch))
    api = build_model(cfg, compute_dtype=jnp.float32, remat=False)
    from repro.sharding.spec import values_tree
    params = values_tree(api.init(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(0)
    s_text = args.prompt_len - (cfg.num_patches if cfg.family == "vlm" else 0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, s_text)), jnp.int32)
    extra = {}
    if cfg.family == "vlm":
        extra["patches"] = jnp.asarray(
            rng.normal(0, 0.02, (args.batch, cfg.num_patches, cfg.d_model)),
            jnp.float32)
    if cfg.family == "encdec":
        extra["frames"] = jnp.asarray(
            rng.normal(0, 0.02,
                       (args.batch, cfg.encoder_seq_len, cfg.d_model)),
            jnp.float32)
    t0 = time.time()
    toks = generate(api, params, prompts, gen=args.gen, extra_inputs=extra)
    dt = time.time() - t0
    print(f"generated {toks.shape} tokens in {dt:.2f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s)")
    print("sample:", np.asarray(toks[0][:16]))


if __name__ == "__main__":
    main()
