"""Roofline-term extraction from compiled XLA artifacts.

``cost_analysis`` supplies HLO FLOPs and bytes; collective traffic is NOT in
cost_analysis, so we parse the (post-SPMD-partitioning) HLO text and sum the
result-shape bytes of every collective op, bucketed by kind.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (report vs chips*link_bw)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"[\s(]")


def shape_bytes(type_str: str) -> int:
    """Sum the byte size of every typed shape in an HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    """Collective-op traffic parsed out of HLO text, bucketed by kind."""

    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        """All collective result bytes across kinds."""
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Scan (post-SPMD) HLO text and sum result bytes per collective kind
    (cost_analysis does not report collective traffic)."""
    stats = CollectiveStats()
    for m in _OP_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        kind = kind.replace("-start", "")
        b = shape_bytes(type_str)
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + b
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class Roofline:
    """Roofline terms for one compiled program on a ``chips``-wide fleet;
    ``t_*`` are per-step lower-bound times against v5e peak rates."""

    flops: float                 # whole-program HLO FLOPs (all chips)
    hbm_bytes: float             # whole-program bytes accessed (all chips)
    collective_bytes: float      # whole-program collective result bytes
    chips: int
    model_flops: float = 0.0     # 6·N·D analytic useful FLOPs

    @property
    def t_compute(self) -> float:
        """Seconds if compute-bound (flops / fleet peak FLOP/s)."""
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        """Seconds if HBM-bound (bytes / fleet HBM bandwidth)."""
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        """Seconds if interconnect-bound (collective bytes / ICI bw)."""
        return self.collective_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        """Which roofline term bounds the step: compute/memory/collective."""
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """Analytic model FLOPs over HLO FLOPs (padding/rematerialisation
        overhead shows up as a ratio below 1)."""
        return self.model_flops / self.flops if self.flops else 0.0

    def as_dict(self) -> dict:
        """Flatten to the JSONL record emitted by the dry-run."""
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes, "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_flops_ratio,
        }


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a dict on recent jax but a
    one-element list of dicts on 0.4.x; normalise to a dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def roofline_from_compiled(compiled, chips: int, *,
                           model_flops: float = 0.0) -> Roofline:
    """Build a :class:`Roofline` from a jax ``Compiled`` object."""
    cost = cost_analysis_dict(compiled)
    # XLA reports per-partition numbers for SPMD modules; scale to the fleet.
    flops = float(cost.get("flops", 0.0)) * chips
    byts = float(cost.get("bytes accessed", 0.0)) * chips
    stats = parse_collectives(compiled.as_text())
    return Roofline(flops=flops, hbm_bytes=byts,
                    collective_bytes=float(stats.total_bytes) * chips,
                    chips=chips, model_flops=model_flops)
