"""Multi-pod dry-run driver: see the usage block below (module docstring
kept minimal because the XLA device-count flag must be set before any
other import)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: jax locks the device
# count at first initialisation, and the production-mesh dry-run needs 512
# placeholder devices on this CPU-only host.

# Multi-pod dry-run: lower + compile every (architecture x input-shape)
# combination against the production meshes, prove memory/sharding coherence,
# and emit the roofline terms consumed by EXPERIMENTS.md §Dry-run/§Roofline.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
#       --out results/dryrun.jsonl

import argparse
import dataclasses
import gc
import json
import time
import traceback

import jax

from repro.configs.base import (ARCH_IDS, INPUT_SHAPES, RunConfig,
                                get_arch_config)
from repro.launch.hlo_analysis import (Roofline, cost_analysis_dict,
                                       parse_collectives,
                                       roofline_from_compiled)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step
from repro.models import flags


def model_flops_for(cfg, shape) -> float:
    """Analytic useful FLOPs (6ND train / 2ND inference) for a shape."""
    from repro.models.model import count_params_analytic

    n = count_params_analytic(cfg, active_only=True)
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def _accounting_depths(cfg):
    if cfg.family == "hybrid":
        p = cfg.attn_layer_period
        return p, 2 * p
    return 2, 4


def _reduced_depth(cfg, depth: int):
    kw = {"num_layers": depth}
    if cfg.family == "encdec":
        kw["encoder_layers"] = depth
    return dataclasses.replace(cfg, **kw)


def accounting_costs(cfg, run, shape, mesh) -> dict:
    """XLA's HLO cost analysis counts a while-loop body ONCE regardless of
    trip count (verified empirically; see EXPERIMENTS.md §Dry-run), so
    scanned-layer models under-report FLOPs/bytes.  We therefore compile
    reduced-depth UNROLLED variants at two depths and extrapolate the
    per-layer slope to the full depth.  Memory analysis still comes from
    the full-depth scanned compile (loop buffers are reused, so that one
    is correct as-is)."""
    d1, d2 = _accounting_depths(cfg)
    samples = []
    for d in (d1, d2):
        bundle = build_step(_reduced_depth(cfg, d), run, shape, mesh)
        with flags.unrolled_for_accounting():
            compiled = bundle.lower().compile()
        cost = cost_analysis_dict(compiled)
        coll = parse_collectives(compiled.as_text())
        samples.append({
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll.total_bytes),
            "coll_by_kind": dict(coll.bytes_by_kind),
        })
        del compiled, bundle
        gc.collect()
    L = cfg.num_layers

    def extrap(key):
        v1, v2 = samples[0][key], samples[1][key]
        slope = (v2 - v1) / (d2 - d1)
        return max(v1 + slope * (L - d1), 0.0)

    kinds = set(samples[0]["coll_by_kind"]) | set(samples[1]["coll_by_kind"])
    coll_by_kind = {}
    for k in kinds:
        v1 = samples[0]["coll_by_kind"].get(k, 0)
        v2 = samples[1]["coll_by_kind"].get(k, 0)
        coll_by_kind[k] = int(max(v1 + (v2 - v1) / (d2 - d1) * (L - d1), 0))
    return {
        "flops_per_device": extrap("flops"),
        "bytes_per_device": extrap("bytes"),
        "collective_bytes_per_device": extrap("coll"),
        "collectives_by_kind": coll_by_kind,
        "accounting_depths": [d1, d2],
    }


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            strategy: str | None = None, verbose: bool = True,
            accounting: bool = True) -> dict:
    """Lower+compile one (arch, shape) combo on the production mesh and
    return its memory/roofline record."""
    shape = INPUT_SHAPES[shape_name]
    cfg = get_arch_config(arch)
    strategy = strategy or ("split_concurrent" if shape.kind == "train"
                            else "fsdp_tp")
    mesh = make_production_mesh(multi_pod=multi_pod)
    from repro.launch.steps import resolve_decode_layout
    layout = (resolve_decode_layout(cfg, mesh, "auto")
              if shape.kind == "decode" else "batch_sharded")
    run = RunConfig(arch=arch, shape=shape_name, strategy=strategy,
                    param_dtype="float32" if shape.kind == "train"
                    else "bfloat16", decode_layout=layout,
                    multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()
    bundle = build_step(cfg, run, shape, mesh)
    with mesh:
        lowered = bundle.lower()
        compiled = lowered.compile()
    t1 = time.time()
    mem = compiled.memory_analysis()
    stats = parse_collectives(compiled.as_text())
    if accounting:
        acct = accounting_costs(cfg, run, shape, mesh)
        roof = Roofline(
            flops=acct["flops_per_device"] * chips,
            hbm_bytes=acct["bytes_per_device"] * chips,
            collective_bytes=acct["collective_bytes_per_device"] * chips,
            chips=chips,
            model_flops=model_flops_for(bundle.cfg, shape))
        stats.bytes_by_kind = acct["collectives_by_kind"]
    else:
        roof = roofline_from_compiled(
            compiled, chips, model_flops=model_flops_for(bundle.cfg, shape))
    rec = {
        "arch": arch, "shape": shape_name, "strategy": strategy,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "chips": chips,
        "compile_s": round(t1 - t0, 1),
        "arg_bytes_per_device": mem.argument_size_in_bytes,
        "out_bytes_per_device": mem.output_size_in_bytes,
        "temp_bytes_per_device": mem.temp_size_in_bytes,
        "peak_bytes_per_device": (mem.argument_size_in_bytes
                                  + mem.temp_size_in_bytes),
        "collectives": {k: int(v) for k, v in stats.bytes_by_kind.items()},
        "collective_counts": dict(stats.count_by_kind),
        **roof.as_dict(),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} ({rec['mesh']}, {strategy}): "
              f"OK compile={rec['compile_s']}s "
              f"args/dev={rec['arg_bytes_per_device']/2**30:.2f}GiB "
              f"temp/dev={rec['temp_bytes_per_device']/2**30:.2f}GiB "
              f"dominant={rec['dominant']} "
              f"t=({roof.t_compute:.4f},{roof.t_memory:.4f},"
              f"{roof.t_collective:.4f})s", flush=True)
    del compiled, lowered, bundle
    gc.collect()
    return rec


def main() -> None:
    """CLI: ``--arch/--shape`` for one combo or ``--all`` for the sweep."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--strategy", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="write JSONL records here")
    args = ap.parse_args()

    combos = []
    if args.all:
        combos = [(a, s) for a in ARCH_IDS for s in INPUT_SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    records, failures = [], []
    for arch, shape in combos:
        try:
            records.append(run_one(arch, shape, multi_pod=args.multi_pod,
                                   strategy=args.strategy))
        except Exception as e:  # a failure here is a sharding bug
            failures.append((arch, shape, repr(e)))
            print(f"[dryrun] {arch} x {shape} FAILED: {e}", flush=True)
            traceback.print_exc()
        if args.out:
            with open(args.out, "w") as f:
                for r in records:
                    f.write(json.dumps(r) + "\n")
    print(f"[dryrun] {len(records)} OK, {len(failures)} failed")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
