"""Training driver: real end-to-end training on whatever devices exist.

On this CPU host it trains reduced configs (the same code path that targets
the production mesh); on a TPU fleet the identical script drives the
16x16(x2) meshes via --mesh.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --steps 50 --strategy split_concurrent
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import INPUT_SHAPES, InputShape, RunConfig, \
    get_arch_config, get_smoke_config
from repro.core.split_parallel import init_prev_features, make_train_step
from repro.data import TicketDataLoader, make_lm_batch
from repro.data.synthetic import InlineWorker
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import arch_for_run, make_rules
from repro.models.model import build_model
from repro.optim import get_optimizer
from repro.sharding.spec import ShardCtx, use_shard_ctx


def train_loop(cfg, run: RunConfig, *, steps: int, batch: int, seq: int,
               mesh=None, log_every: int = 10, checkpoint_path=None):
    """Train ``cfg`` for ``steps`` on synthetic LM batches with the
    strategy named in ``run``; returns the per-step metric history."""
    compute_dtype = jnp.dtype(run.compute_dtype)
    shape = InputShape("custom", seq, batch, "train")
    cfg = arch_for_run(cfg, shape, run.strategy)
    api = build_model(cfg, compute_dtype=compute_dtype, remat=run.remat)
    opt = get_optimizer(run.optimizer, run.learning_rate,
                        adagrad_beta=run.adagrad_beta,
                        weight_decay=run.weight_decay)
    init_state, step_fn = make_train_step(
        api, opt, strategy=run.strategy,
        head_sync_period=run.head_sync_period)

    rng = np.random.default_rng(run.seed)
    loader = TicketDataLoader(
        lambda step, i: make_lm_batch(rng, batch // run.microbatch_per_ticket
                                      if run.microbatch_per_ticket > 1
                                      else batch, seq, cfg.vocab_size),
        num_microbatches=1)
    ctx = None
    if mesh is not None:
        rules = make_rules(run.strategy, mesh, shape)
        ctx = ShardCtx(mesh, rules)

    with use_shard_ctx(ctx):
        state = init_state(jax.random.PRNGKey(run.seed))
        first = loader.global_batch(0, [InlineWorker()])
        first = {k: jnp.asarray(v) for k, v in first.items()}
        if run.strategy in ("split_concurrent", "split_server_sharded"):
            state = init_prev_features(state, api, first,
                                       dtype=compute_dtype)
        jstep = jax.jit(step_fn, donate_argnums=(0,))
        losses = []
        t0 = time.time()
        for i in range(steps):
            b = first if i == 0 else {
                k: jnp.asarray(v) for k, v in loader.global_batch(
                    i, [InlineWorker()]).items()}
            state, metrics = jstep(state, b)
            loss = float(metrics["loss"])
            losses.append(loss)
            if i % log_every == 0 or i == steps - 1:
                dt = time.time() - t0
                print(f"step {i:5d} loss {loss:.4f} "
                      f"({dt/(i+1):.3f}s/step)", flush=True)
    if checkpoint_path:
        from repro.checkpoint import save_npz
        from repro.core.split_parallel import merge_params
        save_npz(checkpoint_path, merge_params(
            jax.tree_util.tree_map(np.asarray, state.params),
            jax.tree_util.tree_map(np.asarray, state.head)))
        print(f"checkpoint -> {checkpoint_path}")
    return losses, state


def main():
    """CLI entry: train an arch config with a chosen strategy/optimizer."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--strategy", default="split_concurrent")
    ap.add_argument("--optimizer", default="adagrad")
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--adagrad-beta", type=float, default=1.0)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--compute-dtype", default="float32")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_arch_config(args.arch))
    run = RunConfig(arch=args.arch, strategy=args.strategy,
                    optimizer=args.optimizer, learning_rate=args.lr,
                    adagrad_beta=args.adagrad_beta,
                    compute_dtype=args.compute_dtype)
    mesh = None
    if args.data_par * args.model_par > 1:
        mesh = make_local_mesh(args.data_par, args.model_par)
    losses, _ = train_loop(cfg, run, steps=args.steps, batch=args.batch,
                           seq=args.seq, mesh=mesh,
                           checkpoint_path=args.checkpoint)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
