"""Step builders: turn (arch config, run config, mesh) into jit-able step
functions with fully-specified in/out shardings for training, prefill and
decode — used by the real launcher and by the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape, RunConfig
from repro.core.split_parallel import (TrainState, init_prev_features,
                                       make_train_step, split_params)
from repro.models.model import build_model
from repro.optim import get_optimizer
from repro.sharding.rules import rules_for_strategy
from repro.sharding.spec import (ShardCtx, axes_tree, spec_tree, to_pspec,
                                 use_shard_ctx, values_tree)

LONG_CONTEXT_WINDOW = 8192  # sliding window used by full-attention archs
                            # for the long_500k shape


# ---------------------------------------------------------------------------
# Shape-aware rule adjustment
# ---------------------------------------------------------------------------


def resolve_decode_layout(cfg: ArchConfig, mesh, layout: str) -> str:
    """Resolve "auto" ONCE against the full-size config (reduced-depth
    accounting compiles must inherit the same concrete layout)."""
    if layout != "auto":
        return layout
    per_shard = cfg.param_count() * 2 / mesh.shape["model"]
    return "replicated_batch" if per_shard > 2 * 2**30 else "batch_sharded"


def make_rules(strategy: str, mesh, shape: InputShape,
               global_batch: int | None = None,
               decode_layout: str = "batch_sharded",
               cfg: ArchConfig | None = None) -> dict:
    """Strategy rules specialised to the input shape.

    * decode: query heads are replicated and the KV cache is sharded along
      its sequence dim over 'model' (plus 'data' too when the batch is too
      small to occupy the data axis) — distributed flash-decode layout.
      ``decode_layout="replicated_batch"`` additionally replicates the
      batch over the data axes so contraction-dim-sharded (FSDP) weights
      stay RESIDENT — GSPMD partial-sums the tiny per-step activations
      instead of all-gathering the weights (measured −92.6% collective on
      jamba-398B decode_32k; §Perf).  "auto" picks it when the bf16 weight
      bytes per model shard exceed 2 GiB.
    * any shape: drop 'batch' sharding when the global batch doesn't divide
      the data axes.
    """
    rules = dict(rules_for_strategy(strategy, mesh.axis_names))
    b = global_batch or shape.global_batch
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    data_size = 1
    for a in data_axes:
        data_size *= mesh.shape[a]
    batch_shardable = b % data_size == 0
    if not batch_shardable:
        rules["batch"] = None
    if shape.kind == "decode":
        layout = decode_layout
        if layout == "auto":
            layout = "batch_sharded"
            if cfg is not None:
                per_shard = cfg.param_count() * 2 / mesh.shape["model"]
                if per_shard > 2 * 2**30:
                    layout = "replicated_batch"
        rules["heads"] = None
        if layout == "replicated_batch":
            rules["batch"] = None
            batch_shardable = False
        rules["kv_seq"] = ("data", "model") if not batch_shardable \
            else "model"
        rules["kv_seq"] = tuple(a for a in (rules["kv_seq"]
                                if isinstance(rules["kv_seq"], tuple)
                                else (rules["kv_seq"],))
                                if a in mesh.axis_names)
        if len(rules["kv_seq"]) == 1:
            rules["kv_seq"] = rules["kv_seq"][0]
    return rules


def arch_for_run(cfg: ArchConfig, shape: InputShape,
                 strategy: str) -> ArchConfig:
    """Apply run-level config surgery: untie heads for split strategies,
    sliding window for long-context decode on full-attention archs."""
    if strategy.startswith("split") and cfg.tie_embeddings:
        cfg = dataclasses.replace(cfg, tie_embeddings=False)
    if (shape.name == "long_500k" and not cfg.supports_long_context
            and not cfg.sliding_window):
        cfg = cfg.with_sliding_window(LONG_CONTEXT_WINDOW)
    return cfg


# ---------------------------------------------------------------------------
# Axes trees for states / batches / caches
# ---------------------------------------------------------------------------


def batch_axes(batch_spec: dict) -> dict:
    """Logical axes for a batch tree: leading dim "batch", rest unsharded."""
    out = {}
    for k, v in batch_spec.items():
        out[k] = ("batch",) + (None,) * (len(v.shape) - 1)
    return out


_CACHE_AXES_BY_KEY = {
    "k": ("layers", "batch", "kv_seq", "kv_heads", None),
    "v": ("layers", "batch", "kv_seq", "kv_heads", None),
    "pos": ("layers", "kv_seq"),
    "cross_k": ("layers", "batch", None, "kv_heads", None),
    "cross_v": ("layers", "batch", None, "kv_heads", None),
    "ssm": ("layers", None, "batch", "mamba", None),
    "conv": ("layers", None, "batch", None, "mamba"),
    "wkv": ("layers", "batch", "rwkv_head", None, None),
    "shift_tm": ("layers", "batch", None),
    "shift_cm": ("layers", "batch", None),
}


def cache_axes(cache_sds: dict) -> dict:
    """Logical axes for every decode-cache entry present in the tree."""
    return {k: _CACHE_AXES_BY_KEY[k] for k in cache_sds}


def _mirror(axes, like):
    """Build an axes tree for an optimizer-state subtree mirroring params."""
    return jax.tree_util.tree_map(lambda _: axes_copy(_), like)


def opt_state_axes(opt_name: str, params_axes):
    """Optimizer-state axes tree mirroring the params' axes."""
    if opt_name == "adagrad":
        return {"acc": params_axes}
    if opt_name == "adamw":
        return {"m": params_axes, "v": params_axes, "t": ()}
    if opt_name == "sgd":
        return {}
    raise KeyError(opt_name)


def train_state_axes(api, opt_name: str, strategy: str,
                     batch_spec: dict) -> TrainState:
    """Logical-axes TrainState matching what ``init_state`` will build."""
    p_axes = axes_tree(jax.eval_shape(
        lambda: api.init(jax.random.PRNGKey(0))))
    if strategy in ("dp_full", "fsdp_tp"):
        return TrainState(
            params=p_axes, head={}, head_stale={},
            opt_state=opt_state_axes(opt_name, p_axes), head_opt_state={},
            prev_features=(), prev_labels=(), prev_mask=(), step=())
    backbone_axes, head_axes = split_params(p_axes)
    concurrent = strategy in ("split_concurrent", "split_server_sharded")
    feats = ("batch", None, None) if concurrent else ()
    lbl = ("batch", None) if concurrent else ()
    return TrainState(
        params=backbone_axes, head=head_axes, head_stale=head_axes,
        opt_state=opt_state_axes(opt_name, backbone_axes),
        head_opt_state=opt_state_axes(opt_name, head_axes),
        prev_features=feats, prev_labels=lbl, prev_mask=lbl, step=())


# ---------------------------------------------------------------------------
# Bundles
# ---------------------------------------------------------------------------


@dataclass
class StepBundle:
    """Everything needed to lower/compile/run one step kind."""

    fn: Callable                 # jit-able python callable
    args_sds: tuple              # ShapeDtypeStruct pytree of example args
    in_shardings: tuple
    rules: dict
    mesh: Any
    api: Any
    cfg: ArchConfig

    def lower(self, donate: bool = True):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         donate_argnums=(0,) if donate else ())
        with use_shard_ctx(ShardCtx(self.mesh, self.rules)):
            return jitted.lower(*self.args_sds)


def _cast_float_sds(tree, dtype):
    def one(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(x.shape, dtype)
        return x
    return jax.tree_util.tree_map(one, tree)


def _shardings(tree_axes, rules, mesh, tree_sds=None):
    """Axes tree (+ optional SDS tree for divisibility checks) -> shardings."""
    if tree_sds is None:
        return jax.tree_util.tree_map(
            lambda ax: NamedSharding(mesh, to_pspec(ax, rules)), tree_axes,
            is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree_util.tree_map(
        lambda ax, sds: NamedSharding(
            mesh, to_pspec(ax, rules, mesh=mesh,
                           shape=getattr(sds, "shape", ()))),
        tree_axes, tree_sds, is_leaf=lambda x: isinstance(x, tuple))


def build_train_step(cfg: ArchConfig, run: RunConfig, shape: InputShape,
                     mesh, *, global_batch: int | None = None) -> StepBundle:
    """Assemble the jit-ready training step (fn, arg shapes, shardings)."""
    cfg = arch_for_run(cfg, shape, run.strategy)
    compute_dtype = jnp.dtype(run.compute_dtype)
    api = build_model(cfg, compute_dtype=compute_dtype, remat=run.remat,
                      loss_chunks=run.loss_chunks)
    opt = get_optimizer(run.optimizer, run.learning_rate,
                        adagrad_beta=run.adagrad_beta,
                        weight_decay=run.weight_decay)
    init_state, step_fn = make_train_step(
        api, opt, strategy=run.strategy,
        head_sync_period=run.head_sync_period, grad_accum=run.grad_accum)

    batch_sds = api.batch_spec(shape, global_batch=global_batch)
    rules = make_rules(run.strategy, mesh, shape, global_batch)

    def init_all():
        state = init_state(jax.random.PRNGKey(run.seed))
        if run.strategy in ("split_concurrent", "split_server_sharded"):
            state = init_prev_features(state, api, batch_sds,
                                       dtype=compute_dtype)
        return state

    state_sds = jax.eval_shape(init_all)
    if run.param_dtype != "float32":
        state_sds = _cast_float_sds(state_sds, jnp.dtype(run.param_dtype))
    st_axes = train_state_axes(api, run.optimizer, run.strategy, batch_sds)
    in_shardings = (_shardings(st_axes, rules, mesh, state_sds),
                    _shardings(batch_axes(batch_sds), rules, mesh, batch_sds))
    return StepBundle(step_fn, (state_sds, batch_sds), in_shardings, rules,
                      mesh, api, cfg)


def build_prefill_step(cfg: ArchConfig, run: RunConfig, shape: InputShape,
                       mesh, *, global_batch: int | None = None) -> StepBundle:
    """Assemble the jit-ready prefill step (params, batch) -> cache."""
    cfg = arch_for_run(cfg, shape, run.strategy)
    compute_dtype = jnp.dtype(run.compute_dtype)
    api = build_model(cfg, compute_dtype=compute_dtype, remat=False)
    batch_sds = api.batch_spec(shape, global_batch=global_batch)
    rules = make_rules(run.strategy, mesh, shape, global_batch)
    params_sds = _cast_float_sds(
        jax.eval_shape(lambda: values_tree(api.init(jax.random.PRNGKey(0)))),
        compute_dtype)
    p_axes = axes_tree(jax.eval_shape(
        lambda: api.init(jax.random.PRNGKey(0))))
    in_shardings = (_shardings(p_axes, rules, mesh, params_sds),
                    _shardings(batch_axes(batch_sds), rules, mesh, batch_sds))

    def fn(params, batch):
        return api.prefill(params, batch)

    return StepBundle(fn, (params_sds, batch_sds), in_shardings, rules,
                      mesh, api, cfg)


def build_decode_step(cfg: ArchConfig, run: RunConfig, shape: InputShape,
                      mesh, *, global_batch: int | None = None) -> StepBundle:
    """Assemble the jit-ready single-token decode step."""
    cfg = arch_for_run(cfg, shape, run.strategy)
    compute_dtype = jnp.dtype(run.compute_dtype)
    api = build_model(cfg, compute_dtype=compute_dtype, remat=False)
    b = global_batch or shape.global_batch
    rules = make_rules(run.strategy, mesh, shape, global_batch,
                       decode_layout=run.decode_layout, cfg=cfg)

    cache_sds = jax.eval_shape(
        lambda: api.init_cache(b, shape.seq_len))
    params_sds = _cast_float_sds(
        jax.eval_shape(lambda: values_tree(api.init(jax.random.PRNGKey(0)))),
        compute_dtype)
    p_axes = axes_tree(jax.eval_shape(
        lambda: api.init(jax.random.PRNGKey(0))))
    token_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    index_sds = jax.ShapeDtypeStruct((), jnp.int32)
    in_shardings = (
        _shardings(p_axes, rules, mesh, params_sds),
        _shardings(cache_axes(cache_sds), rules, mesh, cache_sds),
        NamedSharding(mesh, to_pspec(("batch", None), rules)),
        NamedSharding(mesh, P()),
    )

    def fn(params, cache, token, index):
        return api.decode_step(params, cache, token, index)

    return StepBundle(fn, (params_sds, cache_sds, token_sds, index_sds),
                      in_shardings, rules, mesh, api, cfg)


def build_step(cfg: ArchConfig, run: RunConfig, shape: InputShape, mesh,
               **kw) -> StepBundle:
    """Dispatch to the train/prefill/decode builder by ``shape.kind``."""
    if shape.kind == "train":
        return build_train_step(cfg, run, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, run, shape, mesh, **kw)
    if shape.kind == "decode":
        return build_decode_step(cfg, run, shape, mesh, **kw)
    raise ValueError(shape.kind)


def axes_copy(x):
    return x
