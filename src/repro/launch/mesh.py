"""Production meshes.  Defined as FUNCTIONS so importing this module never
touches jax device state (the dry-run sets the 512-placeholder-device flag
before any jax initialisation)."""
from __future__ import annotations

import jax


def _mk(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; older jax defaults to Auto.
    axis_type = getattr(jax.sharding, "AxisType", None)
    kwargs = ({"axis_types": (axis_type.Auto,) * len(axes)}
              if axis_type is not None else {})
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per TPU v5e pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (real or forced) local devices exist."""
    return _mk((data, model), ("data", "model"))
