"""The paper's modified-AdaGrad update as ONE pure per-leaf function.

    acc' = acc + g²;   θ' = θ − α · g / sqrt(β + acc')

Both the pure-pytree optimizer (``repro.optim.optimizers.adagrad``) and
the Pallas kernel oracle (``repro.kernels.adagrad.ref``) import this —
the kernel reference and the optimizer are the same math by
construction and cannot drift.  The fused server-step kernel
(``repro.kernels.server_step``) mirrors the identical operation order so
its interpret-mode output is bit-equal to this function applied after
the work-weighted gradient mean.

All arithmetic is float32 regardless of the parameter dtype (the
accumulator is always f32 state); the returned parameter is cast back
to the input parameter's dtype as the final operation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adagrad_leaf_update(p, g, acc, *, lr: float, beta: float = 1.0,
                        weight_decay: float = 0.0):
    """One leaf's modified-AdaGrad step: ``(p, g, acc) -> (p', acc')``.

    ``p``/``g`` may be any float dtype; ``acc`` must be f32.  The exact
    f32 operation order here is the contract the fused kernels are
    bit-equal to — change it only together with them.
    """
    gf = g.astype(jnp.float32)
    if weight_decay:
        gf = gf + weight_decay * p.astype(jnp.float32)
    a = acc + jnp.square(gf)
    step = lr * gf * jax.lax.rsqrt(beta + a)
    return (p.astype(jnp.float32) - step).astype(p.dtype), a


__all__ = ["adagrad_leaf_update"]
