from repro.optim.optimizers import (
    Optimizer,
    adagrad,
    adamw,
    get_optimizer,
    sgd,
)

__all__ = ["Optimizer", "adagrad", "adamw", "get_optimizer", "sgd"]
