from repro.optim.adagrad_math import adagrad_leaf_update
from repro.optim.optimizers import (
    Optimizer,
    adagrad,
    adamw,
    get_optimizer,
    sgd,
)

__all__ = ["Optimizer", "adagrad", "adagrad_leaf_update", "adamw",
           "get_optimizer", "sgd"]
