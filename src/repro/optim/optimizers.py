"""Pure-pytree optimizers.

The default is the paper's **modified AdaGrad** (Sukiyaki §3.1):

    θ_{t} = θ_{t-1} − α · g_t / sqrt(β + Σ_{u<=t} g_u²)

— plain AdaGrad with the stabilising constant β *inside* the square root so
early steps (tiny accumulated squared gradient) don't explode.  The fused
TPU update kernel lives in ``repro/kernels/adagrad``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim.adagrad_math import adagrad_leaf_update


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]  # (grads, state, params) -> (params, state)
    name: str = ""


def _tmap(f, *trees, **kw):
    return jax.tree_util.tree_map(f, *trees, **kw)


def adagrad(lr: float, beta: float = 1.0, weight_decay: float = 0.0,
            use_kernel: bool = False) -> Optimizer:
    """The paper's modified AdaGrad.  ``beta`` is the paper's β."""

    def init(params):
        return {"acc": _tmap(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params):
        if use_kernel:
            from repro.kernels.adagrad.ops import adagrad_update as fused

            new_p, new_acc = [], []
            flat_p, tdef = jax.tree_util.tree_flatten(params)
            flat_g = tdef.flatten_up_to(grads)
            flat_a = tdef.flatten_up_to(state["acc"])
            for p, g, a in zip(flat_p, flat_g, flat_a):
                np_, na = fused(p, g, a, lr=lr, beta=beta,
                                weight_decay=weight_decay)
                new_p.append(np_)
                new_acc.append(na)
            return (jax.tree_util.tree_unflatten(tdef, new_p),
                    {"acc": jax.tree_util.tree_unflatten(tdef, new_acc)})

        def one(p, g, a):
            return adagrad_leaf_update(p, g, a, lr=lr, beta=beta,
                                       weight_decay=weight_decay)

        out = _tmap(one, params, grads, state["acc"])
        new_params = _tmap(lambda o: o[0], out,
                           is_leaf=lambda x: isinstance(x, tuple))
        new_acc = _tmap(lambda o: o[1], out,
                        is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"acc": new_acc}

    return Optimizer(init, update, "adagrad")


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": _tmap(z, params), "v": _tmap(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        t = state["t"] + 1
        bc1 = 1.0 - b1 ** t.astype(jnp.float32)
        bc2 = 1.0 - b2 ** t.astype(jnp.float32)

        def one(p, g, m, v):
            gf = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * jnp.square(gf)
            step = lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                step = step + lr * weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step).astype(p.dtype), m, v

        out = _tmap(one, params, grads, state["m"], state["v"])
        pick = lambda i: _tmap(lambda o: o[i], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"m": pick(1), "v": pick(2), "t": t}

    return Optimizer(init, update, "adamw")


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum:
            return {"mom": _tmap(lambda p: jnp.zeros_like(p, jnp.float32),
                                 params)}
        return {}

    def update(grads, state, params):
        if momentum:
            def one(p, g, m):
                m = momentum * m + g.astype(jnp.float32)
                return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m
            out = _tmap(one, params, grads, state["mom"])
            pick = lambda i: _tmap(lambda o: o[i], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
            return pick(0), {"mom": pick(1)}
        new_p = _tmap(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_p, state

    return Optimizer(init, update, "sgd")


def get_optimizer(name: str, lr: float, *, adagrad_beta: float = 1.0,
                  weight_decay: float = 0.0, **kw) -> Optimizer:
    if name == "adagrad":
        return adagrad(lr, beta=adagrad_beta, weight_decay=weight_decay, **kw)
    if name == "adamw":
        return adamw(lr, weight_decay=weight_decay, **kw)
    if name == "sgd":
        return sgd(lr, **kw)
    raise KeyError(f"unknown optimizer {name!r}")
