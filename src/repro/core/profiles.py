"""Device profiles for browser-scale populations (paper §4 heterogeneity).

The paper's fleet is whatever browsers happen to open the page: a GPU
workstation (the Sukiyaki WebCL path, ~30x its own CPU fallback), office
desktops, laptops on Wi-Fi, phones on mobile networks — and every one of
them can close the tab mid-lease.  The browser-DL measurement study
(*Moving Deep Learning into Web Browser*, PAPERS.md) puts hard numbers
on this: device capability spreads exceed 30x and network latencies are
heavy-tailed, so a realistic churn simulation cannot draw clients from a
uniform distribution.

This module is the single source of those draws.  A :class:`DeviceTier`
describes one device class (relative speed, latency scale, per-round
tab-close hazard, population weight); :func:`draw_fleet` samples a
population of :class:`DeviceDraw`\\ s from the tier mix with a seeded
RNG, so a 10k-client chaos run is exactly reproducible from its seed.
Draws convert to the scheduler's :class:`~repro.core.distributor
.ClientProfile` via :meth:`DeviceDraw.client_profile` — the virtual-clock
sim (``benchmarks/churn_scale.py``) and the socket-level chaos harness
(``tests/chaos.py``) both consume the same distributions.

Latency is **Pareto** (heavy-tailed: most draws near the scale, rare
draws many multiples out — the study's long-tail mobile links), speed is
log-uniform within a tier's spread, and tab-close is a per-round hazard
(memoryless: a tab is as likely to close in round 40 as round 1).
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.core.distributor import ClientProfile

__all__ = ["DeviceTier", "DeviceDraw", "DEFAULT_TIERS", "draw_fleet",
           "fleet_summary", "scale_hazard"]


@dataclass(frozen=True)
class DeviceTier:
    """One device class in the population mix.

    ``speed`` is the tier's median throughput in work-units/s on the
    fabric's normalized scale (the GPU tier sits ~30x the CPU tiers —
    the paper's Sukiyaki gap); a draw lands log-uniformly in
    ``[speed / spread, speed * spread]``.  ``latency_s`` is the Pareto
    scale of the per-lease network latency; ``latency_alpha`` its tail
    index (smaller = heavier tail).  ``tab_close_hazard`` is the
    probability the tab closes during any one round.  ``weight`` is the
    tier's share of the population."""

    name: str
    speed: float
    spread: float = 2.0
    latency_s: float = 0.02
    latency_alpha: float = 2.5
    tab_close_hazard: float = 0.1
    weight: float = 1.0


#: The default population mix.  Speeds put the GPU tier 30x the desktop
#: CPU tier; hazards average out near the ROADMAP's 20%/round churn when
#: mixed by weight (mobile tabs close far more often than workstations).
DEFAULT_TIERS: Dict[str, DeviceTier] = {
    "gpu_desktop": DeviceTier("gpu_desktop", speed=300.0, spread=1.5,
                              latency_s=0.01, latency_alpha=3.0,
                              tab_close_hazard=0.05, weight=0.1),
    "cpu_desktop": DeviceTier("cpu_desktop", speed=10.0, spread=2.0,
                              latency_s=0.02, latency_alpha=2.5,
                              tab_close_hazard=0.1, weight=0.4),
    "laptop": DeviceTier("laptop", speed=6.0, spread=2.5,
                         latency_s=0.04, latency_alpha=2.0,
                         tab_close_hazard=0.25, weight=0.3),
    "mobile": DeviceTier("mobile", speed=2.0, spread=3.0,
                         latency_s=0.08, latency_alpha=1.6,
                         tab_close_hazard=0.45, weight=0.2),
}


@dataclass(frozen=True)
class DeviceDraw:
    """One sampled device: a concrete (speed, latency, hazard) triple
    plus the tier it came from."""

    name: str
    tier: str
    speed: float
    latency: float
    tab_close_hazard: float

    def client_profile(self, **overrides) -> ClientProfile:
        """The scheduler-facing view of this device (``die_after`` /
        ``fail_prob`` and friends may be layered on by the caller)."""
        kw = dict(name=self.name, speed=self.speed, latency=self.latency)
        kw.update(overrides)
        return ClientProfile(**kw)


def _pareto(rng: random.Random, scale: float, alpha: float) -> float:
    """One Pareto(Lomax-shifted) draw: ``scale`` at the head, tail index
    ``alpha``.  Mean exists only for alpha > 1; the mobile tier's 1.6
    keeps rare multi-second stalls in the population on purpose."""
    u = 1.0 - rng.random()                 # (0, 1]
    return scale * u ** (-1.0 / alpha)


def draw_fleet(n: int, *, seed: int = 0,
               tiers: Optional[Sequence[DeviceTier]] = None
               ) -> List[DeviceDraw]:
    """Sample a reproducible ``n``-device population from the tier mix.

    Deterministic in ``(n, seed, tiers)``: the chaos harness and the
    virtual-clock benchmark re-create identical fleets from one seed, so
    a churn failure replays exactly."""
    if tiers is None:
        tiers = list(DEFAULT_TIERS.values())
    if not tiers:
        raise ValueError("tier mix is empty")
    rng = random.Random(seed)
    weights = [max(t.weight, 0.0) for t in tiers]
    out: List[DeviceDraw] = []
    for i in range(n):
        tier = rng.choices(tiers, weights=weights)[0]
        # log-uniform speed inside the tier's spread
        lo, hi = tier.speed / tier.spread, tier.speed * tier.spread
        speed = lo * (hi / lo) ** rng.random()
        latency = _pareto(rng, tier.latency_s, tier.latency_alpha)
        out.append(DeviceDraw(name=f"{tier.name}-{i}", tier=tier.name,
                              speed=speed, latency=latency,
                              tab_close_hazard=tier.tab_close_hazard))
    return out


def scale_hazard(fleet: Sequence[DeviceDraw], target: float
                 ) -> List[DeviceDraw]:
    """Rescale every device's tab-close hazard so the population mean
    hits ``target`` (e.g. the ROADMAP's 20%/round churn), preserving the
    relative tier shape (mobile still churns more than workstations).
    Hazards are clamped to [0, 1]."""
    if not fleet:
        return []
    mean = sum(d.tab_close_hazard for d in fleet) / len(fleet)
    if mean <= 0.0:
        factor = 0.0
    else:
        factor = target / mean
    return [DeviceDraw(name=d.name, tier=d.tier, speed=d.speed,
                       latency=d.latency,
                       tab_close_hazard=min(1.0, max(
                           0.0, d.tab_close_hazard * factor)))
            for d in fleet]


def fleet_summary(fleet: Sequence[DeviceDraw]) -> dict:
    """JSON-safe population description for ``BENCH_churn.json``: tier
    counts, the realised speed spread, latency tail, and mean hazard."""
    if not fleet:
        return {"devices": 0, "tiers": {}}
    by_tier: Dict[str, int] = {}
    for d in fleet:
        by_tier[d.tier] = by_tier.get(d.tier, 0) + 1
    speeds = sorted(d.speed for d in fleet)
    lats = sorted(d.latency for d in fleet)

    def pct(xs, q):
        return xs[min(len(xs) - 1, int(q * len(xs)))]

    return {
        "devices": len(fleet),
        "tiers": by_tier,
        "speed_spread": speeds[-1] / max(speeds[0], 1e-9),
        "speed_p50": pct(speeds, 0.5),
        "latency_p50_s": pct(lats, 0.5),
        "latency_p99_s": pct(lats, 0.99),
        "mean_tab_close_hazard": (sum(d.tab_close_hazard for d in fleet)
                                  / len(fleet)),
    }
