"""The paper's distributed deep-learning algorithm (§4.1) as first-class
training strategies, plus the baselines it is compared against.

  * ``dp_full``          — MLitB (Meeds et al. 2014): synchronous data
                           parallelism; every parameter's gradient crosses
                           the data axis every step.
  * ``split_sequential`` — He et al. 2015: backbone data-parallel, head
                           ("FC") on the server, *synchronous*: head trains
                           on current features, backbone waits.
  * ``split_concurrent`` — the paper: backbone data-parallel on "clients",
                           head trained on the "server" **concurrently** —
                           the head updates from the *previous* step's
                           features while the backbone's backward pass uses
                           a *stale* head copy refreshed every K steps.  The
                           two computations are data-independent inside one
                           step, so XLA overlaps them, and the head gradient
                           never crosses the data axis (head params are
                           server-sharded; only features move).
  * ``fsdp_tp``          — modern baseline mapping (no split), used for
                           beyond-paper comparisons.

All strategies share the same pure-pytree optimizer interface and the same
model API; they differ in the step function and the sharding-rule table
(``repro/sharding/rules.py``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.model import ModelApi, lm_loss
from repro.optim import Optimizer

HEAD_KEYS = ("head",)  # server-owned subtree(s) of the param pytree


def split_params(params: dict):
    """Partition a param pytree into (backbone, head) by top-level key."""
    backbone = {k: v for k, v in params.items() if k not in HEAD_KEYS}
    head = {k: v for k, v in params.items() if k in HEAD_KEYS}
    return backbone, head


def merge_params(backbone: dict, head: dict) -> dict:
    """Inverse of :func:`split_params`."""
    return {**backbone, **head}


@jax.tree_util.register_dataclass
@dataclass
class TrainState:
    """Pytree train state shared by every strategy; split_concurrent uses
    the head/stale-head/prev-feature slots, the others leave them empty."""

    params: Any                 # backbone params (clients)
    head: Any                   # server head params
    head_stale: Any             # client-side stale head copy (split_concurrent)
    opt_state: Any              # backbone optimizer state
    head_opt_state: Any         # head optimizer state
    prev_features: Any          # features from step t-1 (split_concurrent)
    prev_labels: Any
    prev_mask: Any
    step: Any                   # scalar int32


def _text_logits(api: ModelApi, logits):
    if api.cfg.family == "vlm":
        return logits[:, api.cfg.num_patches:]
    return logits


def _head_loss(api: ModelApi, head_params, full_params_wo_head, feats,
               labels, mask):
    """Server-side loss: head logits from (stop-gradient) features."""
    params = merge_params(full_params_wo_head, head_params)
    logits = _text_logits(api, api.head_logits(params, feats))
    return lm_loss(logits, labels, mask)


def _split_micro(batch, k: int):
    """(B, ...) arrays -> (k, B/k, ...) microbatch stacks."""
    return jax.tree_util.tree_map(
        lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)


def _accum(grad_fn, batch, k: int):
    """Gradient accumulation: scan ``grad_fn`` over k microbatches, mean the
    outputs.  Peak activation memory drops ~k-fold (only one microbatch's
    forward/backward is live at a time)."""
    from repro.models import flags

    micro = _split_micro(batch, k)

    def body(acc, mb):
        out = grad_fn(mb)
        return jax.tree_util.tree_map(jnp.add, acc, out), None

    zeros = jax.tree_util.tree_map(
        lambda sd: jnp.zeros(sd.shape, sd.dtype),
        jax.eval_shape(grad_fn, jax.tree_util.tree_map(lambda x: x[0],
                                                       micro)))
    tot, _ = jax.lax.scan(body, zeros, micro, **flags.scan_kwargs())
    return jax.tree_util.tree_map(lambda x: x / k, tot)


def make_train_step(api: ModelApi, opt: Optimizer, *, strategy: str,
                    head_sync_period: int = 4,
                    grad_accum: int = 1) -> tuple[Callable, Callable]:
    """Returns (init_state, step_fn).

    ``step_fn(state, batch) -> (state, metrics)`` is jit-friendly; batch is
    {"tokens","labels","mask"[,"patches","frames"]}.  ``grad_accum`` > 1
    splits the global batch into microbatches and accumulates gradients
    (identical math for mean losses; ~k-fold lower activation memory).
    """
    cfg = api.cfg

    if strategy in ("dp_full", "fsdp_tp"):

        def init_state(rng):
            from repro.sharding.spec import values_tree
            params = values_tree(api.init(rng))
            return TrainState(params=params, head={}, head_stale={},
                              opt_state=opt.init(params), head_opt_state={},
                              prev_features=(), prev_labels=(),
                              prev_mask=(), step=jnp.zeros((), jnp.int32))

        def step_fn(state: TrainState, batch):
            def grad_fn(mb):
                def loss_fn(params):
                    loss, metrics = api.train_loss(params, mb)
                    return loss, metrics
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(state.params)
                return {"loss": loss, "metrics": metrics, "grads": grads}

            out = (_accum(grad_fn, batch, grad_accum) if grad_accum > 1
                   else grad_fn(batch))
            new_params, new_opt = opt.update(out["grads"], state.opt_state,
                                             state.params)
            return TrainState(new_params, {}, {}, new_opt, {}, (), (), (),
                              state.step + 1), \
                {**out["metrics"], "total": out["loss"]}

        return init_state, step_fn

    if strategy not in ("split_sequential", "split_concurrent",
                        "split_server_sharded"):
        raise KeyError(strategy)

    def init_state(rng):
        from repro.sharding.spec import values_tree
        params = values_tree(api.init(rng))
        backbone, head = split_params(params)
        if not jax.tree_util.tree_leaves(head):
            raise ValueError(
                f"{cfg.name}: split strategies need an untied head; "
                "build the model with tie_embeddings=False "
                "(configs are auto-untied by the launcher for split runs)")
        # head_stale must be a distinct buffer (donation aliases otherwise)
        stale = jax.tree_util.tree_map(lambda x: x.copy(), head)
        return TrainState(
            params=backbone, head=head, head_stale=stale,
            opt_state=opt.init(backbone), head_opt_state=opt.init(head),
            prev_features=(), prev_labels=(), prev_mask=(),
            step=jnp.zeros((), jnp.int32))

    if strategy == "split_sequential":
        # He et al.: exact gradients, hard dependency between server and
        # clients (head grads from *current* features; backbone backward
        # through the *current* head).
        def step_fn(state: TrainState, batch):
            def loss_fn(backbone, head):
                loss, metrics = api.train_loss(
                    merge_params(backbone, head), batch)
                return loss, metrics

            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, argnums=(0, 1), has_aux=True)(
                    state.params, state.head)
            g_backbone, g_head = grads
            new_backbone, new_opt = opt.update(g_backbone, state.opt_state,
                                               state.params)
            new_head, new_hopt = opt.update(g_head, state.head_opt_state,
                                            state.head)
            return TrainState(new_backbone, new_head, new_head, new_opt,
                              new_hopt, (), (), (), state.step + 1), \
                {**metrics, "total": loss}

        return init_state, step_fn

    # --- split_concurrent: the paper's algorithm -----------------------------

    def step_fn(state: TrainState, batch):
        # ---- clients: backbone fwd/bwd through the STALE head ------------
        def grad_fn(mb):
            def client_loss(backbone):
                params = merge_params(backbone, state.head_stale)
                logits, aux, feats = api.forward_features(params, mb)
                loss = lm_loss(_text_logits(api, logits), mb["labels"],
                               mb["mask"])
                metrics = {"loss": loss, "aux": aux}
                # features are what the server trains on NEXT step
                return loss + aux, (metrics, jax.lax.stop_gradient(feats))
            (loss, (metrics, feats)), g = jax.value_and_grad(
                client_loss, has_aux=True)(state.params)
            return {"loss": loss, "metrics": metrics, "grads": g,
                    "feats": feats}

        if grad_accum > 1:
            # microbatched: mean grads; feature replay keeps the per-token
            # layout by re-assembling microbatch features along batch
            micro = _split_micro(batch, grad_accum)

            def body(acc, mb):
                out = grad_fn(mb)
                acc = jax.tree_util.tree_map(
                    jnp.add, acc,
                    {"loss": out["loss"], "metrics": out["metrics"],
                     "grads": out["grads"]})
                return acc, out["feats"]

            zeros = jax.tree_util.tree_map(
                lambda sd: jnp.zeros(sd.shape, sd.dtype),
                jax.eval_shape(
                    lambda mb: {k: grad_fn(mb)[k]
                                for k in ("loss", "metrics", "grads")},
                    jax.tree_util.tree_map(lambda x: x[0], micro)))
            from repro.models import flags as _flags
            tot, feats_stack = jax.lax.scan(body, zeros, micro,
                                            **_flags.scan_kwargs())
            out = jax.tree_util.tree_map(lambda x: x / grad_accum, tot)
            loss, metrics = out["loss"], out["metrics"]
            feats = feats_stack.reshape(
                (-1,) + feats_stack.shape[2:])
        else:
            o = grad_fn(batch)
            loss, metrics, feats = o["loss"], o["metrics"], o["feats"]
            out = o
        new_backbone, new_opt = opt.update(out["grads"], state.opt_state,
                                           state.params)

        # ---- server: head trains on PREVIOUS step's features -------------
        have_prev = state.step > 0

        def head_grads():
            g = jax.grad(_head_loss, argnums=1)(
                api, state.head, state.params, state.prev_features,
                state.prev_labels, state.prev_mask)
            return g

        def zero_head_grads():
            return jax.tree_util.tree_map(jnp.zeros_like, state.head)

        g_head = jax.tree_util.tree_map(
            lambda a, b: jnp.where(have_prev, a, b),
            head_grads(), zero_head_grads())
        new_head, new_hopt = opt.update(g_head, state.head_opt_state,
                                        state.head)

        # ---- stale-head refresh every K steps ------------------------------
        do_sync = (state.step + 1) % head_sync_period == 0
        new_stale = jax.tree_util.tree_map(
            lambda fresh, stale: jnp.where(do_sync, fresh, stale),
            new_head, state.head_stale)

        return TrainState(
            new_backbone, new_head, new_stale, new_opt, new_hopt,
            feats, batch["labels"], batch["mask"], state.step + 1), \
            {**metrics, "total": loss}

    return init_state, step_fn


# ---------------------------------------------------------------------------
# Distributor v2 wiring: §4.1 split training over the ticket scheduler
# ---------------------------------------------------------------------------


def adaptive_shard_sizes(rates: dict, global_batch: int, *,
                         min_shard: int = 1) -> dict:
    """Split ``global_batch`` rows across clients proportional to measured
    throughput (EWMA work-units/s from ``TicketQueue.stats``).

    Clients with ``None`` rate (never observed) share the mean of the known
    rates so newcomers aren't starved.  Integer apportionment uses the
    largest-remainder method; every client gets at least ``min_shard`` rows
    (dropping to 0 would stop us ever re-measuring a slow client).

    >>> adaptive_shard_sizes({"fast": 30.0, "slow": 10.0}, 8)
    {'fast': 6, 'slow': 2}
    """
    if not rates:
        return {}
    known = [r for r in rates.values() if r]
    fallback = (sum(known) / len(known)) if known else 1.0
    eff = {c: (r if r else fallback) for c, r in rates.items()}
    total = sum(eff.values())
    raw = {c: global_batch * r / total for c, r in eff.items()}
    # largest-remainder apportionment (sums to global_batch exactly)
    sizes = {c: int(raw[c]) for c in raw}
    by_remainder = sorted(raw, key=lambda c: raw[c] - int(raw[c]),
                          reverse=True)
    i = 0
    while sum(sizes.values()) < global_batch:
        sizes[by_remainder[i % len(by_remainder)]] += 1
        i += 1
    # enforce the floor only when it's satisfiable (global_batch may be
    # smaller than len(rates) * min_shard), stealing from the largest
    if min_shard * len(sizes) <= global_batch:
        for c in sizes:
            while sizes[c] < min_shard:
                donor = max(sizes, key=lambda d: (sizes[d], eff[d]))
                if sizes[donor] <= min_shard:
                    break
                sizes[donor] -= 1
                sizes[c] += 1
    return sizes


def weighted_grad_mean(shard_grads, shard_sizes) -> Any:
    """Work-weighted mean of per-shard gradient pytrees — the exact
    combination rule for unevenly sized data-parallel shards.

    One fused ``tree_map`` over ALL shard trees at once: each leaf is
    reduced in a single pass, so no per-shard scaled pytree copies are
    materialised on the per-step hot path (the old implementation built
    O(n_shards) intermediate trees per call)."""
    total = float(sum(shard_sizes))
    weights = [w / total for w in shard_sizes]

    def fuse(*leaves):
        acc = leaves[0] * weights[0]
        for g, w in zip(leaves[1:], weights[1:]):
            acc = acc + g * w
        return acc

    return jax.tree_util.tree_map(fuse, *shard_grads)


class RoundDriverLifetime:
    """Explicit client-lifetime ownership shared by the round drivers
    (``SplitConcurrentDispatcher``, ``train_fabric.FederatedTrainer``).

    A round driver needs the distributor's clients to survive drained
    queues between rounds, so constructing one flips ``keep_alive`` on —
    but the caller's original mode must come back when the driver is
    done, or a discarded driver leaves the distributor permanently
    changed.  :meth:`aclose` (or the async context manager) restores it;
    one implementation here so the restore/notify semantics can't
    diverge between drivers."""

    def _own_clients(self, distributor):
        self.dist = distributor
        self._prev_keep_alive = distributor.keep_alive
        distributor.keep_alive = True
        self._closed = False

    async def __aenter__(self):
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    def _notify(self):
        """Wake the whole fabric (federation) or this distributor's
        parked waiters."""
        notify = getattr(self.dist, "_notify_all", None)
        (notify or self.dist._notify_waiters)()

    async def aclose(self, *, shutdown: bool = False):
        """End this driver's ownership of the client lifetime: restore
        the distributor's original ``keep_alive`` (parked clients wake,
        re-check the now-restored terminal condition, and exit once the
        queue drains), optionally shutting the distributor down outright.
        Idempotent."""
        if self._closed:
            return
        self._closed = True
        self.dist.keep_alive = self._prev_keep_alive
        if shutdown:
            await self.dist.shutdown()
        else:
            self._notify()


class SplitConcurrentDispatcher(RoundDriverLifetime):
    """Bridge from §4.1 split training to the Distributor v2 scheduler.

    Each training step, the backbone's data-parallel shards become a batch
    of tickets on an :class:`repro.core.distributor.AsyncDistributor`; the
    simulated browser clients lease them (adaptively sized batches), run
    the shard work function, and the dispatcher aggregates the results —
    a work-weighted mean, which is exactly the gradient combination rule
    for unevenly sized data-parallel shards.

    The server-side head update (which never crosses the data axis — see
    ``split_concurrent`` above) proceeds concurrently on the caller's
    thread, so the ticket round only covers backbone traffic.
    """

    def __init__(self, distributor, task_name: str = "backbone_shard"):
        self._own_clients(distributor)
        self.task_name = task_name
        self.rounds = 0

    async def run_round(self, shard_args, *, shard_work=None,
                        statics=None, timeout: float = 60.0) -> list:
        """Execute one step's shards through the scheduler.

        ``shard_args`` is a list of per-shard work-function arguments;
        ``shard_work[i]`` (default 1.0 each) meters each shard's size so
        the EWMA stays calibrated when shards are uneven.  Returns results
        ordered like ``shard_args``.

        ``statics`` ({key: value}, e.g. this step's stale-head weights) is
        re-registered on the origin registry BEFORE the round's tickets
        are enqueued.  Re-registering bumps each asset's version, the
        tickets pin the new coherence version, and every client
        revalidates before executing — so per-round weight refresh is
        correct by construction: a client can never run round t's shard
        against round t-1's weights, no matter how its cache is warmed."""
        if statics:
            for key, value in statics.items():
                self.dist.add_static(key, value)
        if shard_work is None:
            shard_work = [1.0] * len(shard_args)
        tids = self.dist.add_work(self.task_name, shard_args,
                                  work=list(shard_work))
        deadline = self.dist.queue.clock() + timeout
        while True:
            # capture the wake epoch before checking: a submit can only
            # land at an await point, so this can't miss a notification
            wake = self.dist._wake_event()
            out = self.dist.queue.results_for(tids)
            if out is not None:
                break
            if self.dist.queue.clock() > deadline:
                raise TimeoutError(
                    f"split round unfinished: {self.dist.console()}")
            await self.dist._wait_on(wake, 0.05)
        # forget the finished round so queue scans/memory stay O(one round)
        # over a long training run, not O(all history)
        self.dist.queue.prune(tids)
        self.rounds += 1
        return out

    @staticmethod
    def aggregate(shard_grads, shard_sizes) -> Any:
        """Work-weighted mean of per-shard gradient pytrees (one fused
        ``tree_map`` — see :func:`weighted_grad_mean`)."""
        return weighted_grad_mean(shard_grads, shard_sizes)


def init_prev_features(state: TrainState, api: ModelApi, batch,
                       dtype=jnp.bfloat16) -> TrainState:
    """Materialise zero placeholders for the feature-replay slots (shapes
    depend on the batch, so this runs once before jit)."""
    cfg = api.cfg
    b, s = batch["tokens"].shape
    if cfg.family == "vlm":
        s = s + cfg.num_patches
    feats = jnp.zeros((b, s, cfg.d_model), dtype)
    from dataclasses import replace
    return replace(state, prev_features=feats,
                   prev_labels=jnp.zeros_like(batch["labels"]),
                   prev_mask=jnp.zeros_like(batch["mask"]))
