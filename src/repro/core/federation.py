"""Multi-distributor federation over the sharded ticket store.

The paper runs ONE TicketDistributor; follow-up work from the same group
(Hidaka et al., arXiv:1702.01846; DistML.js, arXiv:2407.01023) scales the
server side with multiple coordinating hosts and a dedicated asset-serving
tier.  This module is that fabric for our reproduction:

  * :class:`FederationMember` — an ``AsyncDistributor`` that shares one
    :class:`~repro.core.shards.ShardedTicketQueue` with its peers.  Each
    member owns a set of **home shards** it serves by preference (so the
    common case touches only its own locks) and **steals** from the rest of
    the fabric the moment its home shards run dry — idle capacity anywhere
    drains backlog everywhere.  Every member's watchdog patrols the
    *shared* store, so when a member dies mid-lease its stranded tickets
    are released by a survivor's watchdog and stolen within seconds.
  * :class:`EdgeCache` — a read-through cache node in front of the origin
    ``HttpServerBase``.  Clients fetch task code and static assets from
    their member's edge; only misses reach the origin, whose existing
    ``download_count`` ledger therefore measures exactly the miss traffic
    (hit rate = 1 - origin fetches / edge requests).
  * :class:`FederatedDistributor` — the façade: origin HTTP store +
    sharded queue + N members + per-member edges, with least-loaded client
    routing, member kill/failover for fault-injection, and a merged
    console.

``benchmarks/federation_throughput.py`` measures the payoff under a
bimodal client mix and member failure; ``docs/ARCHITECTURE.md``
§Federation fabric has the shard → member → origin diagram.
"""
from __future__ import annotations

import asyncio
import collections
import threading
import time
from typing import Any, Callable, Optional

from repro.core.distributor import (AdaptiveSizer, AsyncDistributor,
                                    Fetched, HttpServerBase, LRUCache,
                                    TaskDef, build_delta_fetched)
from repro.core.shards import ShardedTicketQueue


class EdgeCache:
    """Read-through cache tier for task code and static assets.

    Sits between a member's browser clients and the origin
    ``HttpServerBase``.  Serves from an LRU store; misses fall through to
    the origin (bumping its ``download_count`` ledger, which thereby
    counts *origin egress*, i.e. cache misses).  The edge keeps its own
    ``download_count`` of client-facing requests plus a
    ``revalidation_count`` of conditional requests it answered
    "not modified", so hit rates and revalidation traffic are directly
    measurable from the ledgers.

    The edge is **coherent**: it subscribes to the origin's invalidation
    feed, so re-registering a task or static drops exactly that key from
    the edge store (next request re-warms read-through) — no full
    ``clear()``.  Entries are stored with their origin version, and
    client-side conditional fetches (``if_version``) are answered locally
    when current.

    All cache + counter mutations are guarded by one lock: v1 thread
    clients routed through an edge would otherwise corrupt the LRU's
    OrderedDict.  The lock is NOT held across origin round-trips, so an
    invalidation can race an in-flight miss fill; a per-key **version
    floor** (the invalidation's tombstone) makes that safe — a fill
    below the floor is never cached and never answered "not modified",
    so a raced fill costs one extra origin round-trip instead of
    freezing stale data in."""

    def __init__(self, origin: HttpServerBase, name: str = "edge0",
                 capacity: int = 64, subscribe: bool = True):
        self.origin = origin
        self.name = name
        self.cache = LRUCache(capacity)   # key -> (value, version, dstate)
        self.download_count: collections.Counter = collections.Counter()
        self.revalidation_count: collections.Counter = collections.Counter()
        #: client-facing partial transfers (protocol v2 deltas served
        #: locally from the cached leaf-stamp snapshot)
        self.delta_count: collections.Counter = collections.Counter()
        self.invalidations = 0
        self._floor: dict[str, int] = {}  # key -> minimum current version
        self._lock = threading.Lock()
        # subscribe=False opts out of coherence (benchmark baseline for
        # the pre-invalidation behaviour); production edges stay coherent
        if subscribe and hasattr(origin, "subscribe_invalidation"):
            origin.subscribe_invalidation(self.invalidate)

    def invalidate(self, cache_key: str, version: int):
        """Origin push: a key was re-published at ``version`` — drop our
        copy (if any) and raise the key's floor, so a concurrent miss
        fill carrying the OLD version can't be cached or served as
        current after this returns."""
        with self._lock:
            self._floor[cache_key] = max(self._floor.get(cache_key, 0),
                                         version)
            if self.cache.pop(cache_key) is not None:
                self.invalidations += 1

    def _read_through(self, cache_key: str, ledger_key: str,
                      fetch, if_version: Optional[int], *,
                      delta: bool = False,
                      delta_state_fetch=None) -> Fetched:
        """Shared fetch path: LRU probe under the lock, origin fetch
        outside it, conditional short-circuit when the client's version
        matches our entry AND the entry is at or above the invalidation
        floor (i.e. provably current).

        Statics additionally read the origin's leaf-stamp snapshot on a
        miss fill (``delta_state_fetch``), kept only when its version
        matches the payload fetched (a mismatch means the fill raced a
        re-publish).  With it cached, a v2 client's ``delta=True``
        conditional fetch is answered locally with just the changed
        leaves — same :func:`build_delta_fetched` decision as the origin,
        and only when the entry is provably current (a sub-floor entry
        already forces the client to refetch a full payload)."""
        with self._lock:
            self.download_count[ledger_key] += 1
            entry = self.cache.get(cache_key)
            if (entry is not None
                    and entry[1] < self._floor.get(cache_key, 0)):
                self.cache.pop(cache_key)   # a raced fill slipped in
                entry = None
        if entry is None:
            got = fetch()                      # origin round-trip, unlocked
            dstate = None
            if delta_state_fetch is not None:
                snap = delta_state_fetch()     # second trip, still unlocked
                if snap is not None and snap[0] == got.version:
                    dstate = snap[1]
            entry = (got.value, got.version, dstate)
            with self._lock:
                if got.version >= self._floor.get(cache_key, 0):
                    self.cache.put(cache_key, entry)
        value, version = entry[0], entry[1]
        dstate = entry[2] if len(entry) > 2 else None
        with self._lock:
            current = version >= self._floor.get(cache_key, 0)
            if if_version is not None and if_version == version and current:
                self.revalidation_count[ledger_key] += 1
                return Fetched(None, version, not_modified=True)
            if delta and current and dstate is not None:
                got_d = build_delta_fetched(dstate, version, if_version)
                if got_d is not None:
                    self.delta_count[ledger_key] += 1
                    return got_d
        # current=False tells the client this payload raced an
        # invalidation — serve it, but don't let it validate a pin
        return Fetched(value, version, current=current)

    def fetch_task_versioned(self, name: str,
                             if_version: Optional[int] = None) -> Fetched:
        """Serve task code, read-through to the origin on a miss."""
        key = f"task:{name}"
        return self._read_through(
            key, key, lambda: self.origin.fetch_task_versioned(name),
            if_version)

    def serve_static_versioned(self, key: str,
                               if_version: Optional[int] = None, *,
                               delta: bool = False) -> Fetched:
        """Serve a static asset, read-through to the origin on a miss.
        ``delta=True`` (protocol v2) serves changed-leaves deltas from the
        cached leaf-stamp snapshot when the client's base is in window."""
        # "static:" namespace so an asset literally named "task:<x>" can't
        # collide with task <x>'s code (same split BrowserNodeBase uses)
        delta_state_fetch = getattr(self.origin, "static_delta_state", None)
        return self._read_through(
            f"static:{key}", key,
            lambda: self.origin.serve_static_versioned(key), if_version,
            delta=delta,
            delta_state_fetch=(None if delta_state_fetch is None
                               else (lambda: delta_state_fetch(key))))

    def fetch_task(self, name: str) -> TaskDef:
        """Unconditional task fetch (v1 compat surface)."""
        return self.fetch_task_versioned(name).value

    def serve_static(self, key: str):
        """Unconditional static fetch (v1 compat surface)."""
        return self.serve_static_versioned(key).value

    def clear(self):
        """Drop the edge's store (node restart); next requests re-warm
        from the origin."""
        with self._lock:
            self.cache.clear()

    def stats(self) -> dict:
        """Requests/hits/misses/hit-rate counters for the console."""
        with self._lock:
            requests = sum(self.download_count.values())
            return {
                "name": self.name,
                "requests": requests,
                "hits": self.cache.hits,
                "misses": self.cache.misses,
                "evictions": self.cache.evictions,
                "invalidations": self.invalidations,
                "revalidations": sum(self.revalidation_count.values()),
                "deltas": sum(self.delta_count.values()),
                "hit_rate": (self.cache.hits / requests) if requests else 0.0,
            }


def grant_has_foreign_tickets(batch, home_shards) -> bool:
    """True when a lease grant contains tickets from shards outside
    ``home_shards`` — the definition of a steal.  A fabric-wide retry
    whose grant turns out to be purely home tickets (a home cool-down
    expired between the two lease calls) is NOT a steal, just home work
    arriving late.  Shared by :class:`FederationMember` and the
    federation benchmark so the two counters can't diverge."""
    home = {id(sh) for sh in home_shards}
    return any(id(sh) not in home for sh in (batch.shards or ()))


class FederationMember(AsyncDistributor):
    """One distributor in the federation: home-shard affinity, work
    stealing, and edge-cached asset serving.

    The member leases from its ``home_shards`` first (touching only those
    shards' locks — the common, contention-free case).  When home is dry
    it re-merges across the WHOLE fabric, stealing whatever ticket is
    globally next by VCT; ``steals`` counts those rescues."""

    def __init__(self, federation: "FederatedDistributor", index: int,
                 home_shards, edge: EdgeCache, **kw):
        super().__init__(queue=federation.queue, **kw)
        self.federation = federation
        self.index = index
        self.home_shards = list(home_shards)
        self.edge = edge
        self.alive = True
        self.steals = 0

    def _queue_lease(self, client_name: str, n: int):
        """Home shards first; steal across the fabric when home is dry."""
        batch = None
        if self.home_shards:
            batch = self.queue.lease(client_name, n,
                                     shards=self.home_shards)
        if batch is None and len(self.home_shards) < self.queue.n_shards:
            batch = self.queue.lease(client_name, n)
            if batch is not None and grant_has_foreign_tickets(
                    batch, self.home_shards):
                self.steals += 1
                if self.tracer is not None:
                    self.tracer.instant(
                        "federation.steal", track=f"member{self.index}",
                        cat="federation", ts=self.queue.clock(),
                        args={"member": self.index, "lease": batch.lease_id,
                              "client": client_name})
        return batch

    def task_version(self, name: str) -> int:
        """Coherence versions live in the ORIGIN registry (the façade) —
        a member enqueueing work directly still pins correctly."""
        return self.federation.task_version(name)

    # clients of this member fetch assets through its edge, not the origin
    def fetch_task_versioned(self, name: str, if_version=None):
        """Serve task code from this member's edge (conditional fetch:
        ``if_version`` matching costs a counter bump, not a payload)."""
        return self.edge.fetch_task_versioned(name, if_version)

    def serve_static_versioned(self, key: str, if_version=None, *,
                               delta: bool = False):
        """Serve a static asset from this member's edge (conditional;
        ``delta=True`` ships changed leaves only, protocol v2)."""
        return self.edge.serve_static_versioned(key, if_version, delta=delta)

    def fetch_task(self, name: str) -> TaskDef:
        """Unconditional task fetch through the edge (v1 compat)."""
        return self.edge.fetch_task(name)

    def serve_static(self, key: str):
        """Unconditional static fetch through the edge (v1 compat)."""
        return self.edge.serve_static(key)

    def _notify_waiters(self):
        """A submit/release/add anywhere may unblock a peer's parked
        clients (stealing) — broadcast through the federation."""
        self.federation._notify_all()


class FederatedDistributor(HttpServerBase):
    """N federated distributors + sharded queue + edge tier, one façade.

    Duck-type compatible with ``AsyncDistributor`` where it matters
    (``add_work`` / ``spawn_clients`` / ``run_until_done`` / ``shutdown``
    / ``console`` / ``queue``), so ``SplitConcurrentDispatcher`` and the
    examples can swap it in.  Itself the *origin* HTTP store: tasks and
    static assets registered here are served to clients through each
    member's :class:`EdgeCache`.
    """

    def __init__(self, n_members: int = 2, *, n_shards: Optional[int] = None,
                 timeout: float = 300.0, redistribute_min: float = 10.0,
                 clock: Callable[[], float] = time.monotonic,
                 sizer=None, grace: float = 3.0,
                 watchdog_interval: float = 0.05,
                 edge_capacity: int = 64,
                 keep_alive: bool = False,
                 project_name: str = "federation",
                 tracer=None):
        super().__init__()
        if n_members < 1:
            raise ValueError(f"n_members must be >= 1, got {n_members}")
        if n_shards is not None and n_shards < n_members:
            # fewer shards than members would leave some members with no
            # home shards — every one of their leases would count as a
            # "steal" and home affinity would silently vanish
            raise ValueError(
                f"n_shards ({n_shards}) must be >= n_members ({n_members})")
        self.project_name = project_name
        self.queue = ShardedTicketQueue(
            n_shards if n_shards is not None else max(n_members, 2),
            timeout=timeout, redistribute_min=redistribute_min, clock=clock,
            tracer=tracer)
        # members inherit the tracer through the shared queue (see
        # AsyncDistributor.__init__); the façade keeps it for its own
        # run_until_done stall events and federation-level instants
        self.tracer = tracer
        self.last_stall_report: Optional[dict] = None
        sizer = sizer if sizer is not None else AdaptiveSizer()
        self.members: list[FederationMember] = []
        for i in range(n_members):
            home = [self.queue.shards[j]
                    for j in range(self.queue.n_shards)
                    if j % n_members == i]
            edge = EdgeCache(self, name=f"edge{i}", capacity=edge_capacity)
            self.members.append(FederationMember(
                self, i, home, edge,
                timeout=timeout, redistribute_min=redistribute_min,
                clock=clock, sizer=sizer, grace=grace,
                watchdog_interval=watchdog_interval,
                keep_alive=keep_alive,
                project_name=f"{project_name}/member{i}"))
        self.migrations = 0           # home-shard moves (rebalancer)
        self._wake: Optional[asyncio.Event] = None

    # -- keep_alive fans out (SplitConcurrentDispatcher sets it) -------------

    @property
    def keep_alive(self) -> bool:
        """True when every member survives drained rounds (the
        ``SplitConcurrentDispatcher`` mode); setting it fans out."""
        return all(m.keep_alive for m in self.members)

    @keep_alive.setter
    def keep_alive(self, value: bool):
        for m in self.members:
            m.keep_alive = value

    # -- wake-event fabric ----------------------------------------------------

    _wait_on = staticmethod(AsyncDistributor._wait_on)

    def _wake_event(self) -> asyncio.Event:
        if self._wake is None:
            self._wake = asyncio.Event()
        return self._wake

    def _notify_all(self):
        """Wake every member's parked clients and the federation's own
        waiters (run_until_done / dispatcher rounds)."""
        for m in self.members:
            AsyncDistributor._notify_waiters(m)   # base impl, no re-entry
        ev = self._wake
        self._wake = asyncio.Event()
        if ev is not None:
            ev.set()

    # -- producer / client management -----------------------------------------

    def add_work(self, task_name: str, args_list, *,
                 work: float = 1.0,
                 shard: Optional[int] = None) -> list[int]:
        """Enqueue version-pinned tickets on the owning shard (or an
        explicit ``shard`` index — the training fabric's per-member
        affinity placement); wakes the whole fabric."""
        tids = self.queue.add_many(task_name, args_list, work=work,
                                   task_version=self.task_version(task_name),
                                   shard=shard)
        for m in self.members:
            m._work_added = True
        self._notify_all()
        return tids

    def alive_members(self) -> list[FederationMember]:
        """Members still serving clients."""
        return [m for m in self.members if m.alive]

    def transport_endpoints(self) -> list[FederationMember]:
        """Endpoints a ``TransportServer`` binds remote connections to: the
        alive members.  Each remote client is pinned to one member for its
        connection's lifetime, so its leases take the member's home-shard /
        steal path and its asset fetches go through that member's edge —
        exactly like an in-process client of that member."""
        return self.alive_members()

    def spawn_clients(self, profiles, *, member: Optional[int] = None):
        """Attach clients to members.  Default policy is least-loaded:
        each profile goes to the alive member currently serving the fewest
        clients.  ``member=`` pins the whole batch to one member."""
        spawned = []
        for p in profiles:
            if member is not None:
                target = self.members[member]
                if not target.alive:
                    raise RuntimeError(f"member{member} is dead")
            else:
                target = min(
                    self.alive_members(),
                    key=lambda m: (sum(1 for c in m.clients if not c.done),
                                   m.index))
            spawned.extend(target.spawn_clients([p]))
        return spawned

    def home_shard_indices(self, member: int) -> list[int]:
        """Queue-shard indices in ``member``'s home set — the producer-side
        view a trainer needs to place a round's tickets with per-member
        affinity (``add_work(shard=...)``)."""
        owned = {id(sh) for sh in self.members[member].home_shards}
        return [j for j, sh in enumerate(self.queue.shards)
                if id(sh) in owned]

    def migrate_shard(self, shard_index: int, to_member: int) -> bool:
        """Move queue shard ``shard_index`` from its current owner's home
        set to ``to_member``'s — the rebalancing primitive.

        Mid-run safe: home sets are consulted per lease, in-flight leases
        against the old owner drain normally, and the shared store means
        no tickets move — only the *affinity* (which member serves the
        shard from its own locks) changes.  Returns False when the target
        already owns the shard (or no member does); raises on a dead
        target."""
        target = self.members[to_member]
        if not target.alive:
            raise RuntimeError(f"member{to_member} is dead")
        sh = self.queue.shards[shard_index]
        donor = next((m for m in self.members
                      if any(h is sh for h in m.home_shards)), None)
        if donor is None or donor is target:
            return False
        donor.home_shards.remove(sh)
        target.home_shards.append(sh)
        self.migrations += 1
        if self.tracer is not None:
            self.tracer.instant(
                "federation.migrate", track="federation", cat="federation",
                ts=self.queue.clock(),
                args={"shard": shard_index, "from": donor.index,
                      "to": to_member})
        self._notify_all()          # the new owner's idle clients wake up
        return True

    async def evict_client_leases(self, client: str) -> int:
        """Force-release every lease ``client`` holds anywhere in the
        shared store — the federation-wide half of heartbeat eviction
        (the transport's per-connection path covers only one member; a
        client that reconnected across members may have stranded leases
        on several).  Returns the number of tickets released."""
        n = 0
        for batch in self.queue.outstanding_leases():
            if batch.client == client:
                n += self.queue.release(batch.lease_id, client_failed=True)
        if n:
            if self.tracer is not None:
                self.tracer.instant(
                    "federation.evict", track="federation",
                    cat="federation", ts=self.queue.clock(),
                    args={"client": client, "released": n})
            self._notify_all()
        return n

    async def kill_member(self, index: int) -> int:
        """Fault injection: member ``index`` dies — its clients and
        watchdog are cancelled mid-flight, WITHOUT releasing its leases.
        Survivors' watchdogs patrol the shared store, so the dead member's
        stranded tickets come back at ``grace × ETA`` and get stolen.
        Returns how many clients went down with it."""
        m = self.members[index]
        m.alive = False
        n = len(m._client_tasks)
        await m.shutdown()
        if self.tracer is not None:
            self.tracer.instant(
                "federation.kill", track="federation", cat="federation",
                ts=self.queue.clock(), args={"member": index, "clients": n})
        self._notify_all()
        return n

    # drive-until-drained loop (and its stall-report diagnosis) reused
    # verbatim: the façade exposes the same _wake_event/_wait_on/queue/
    # shutdown/client_rates surface the loop needs, and one copy means a
    # fix to its lost-wakeup or silent-expiry handling reaches both classes
    run_until_done = AsyncDistributor.run_until_done
    _stall_report = AsyncDistributor._stall_report

    async def shutdown(self):
        """Shut down every member (dead ones are a no-op)."""
        for m in self.members:
            await m.shutdown()

    # -- introspection ---------------------------------------------------------

    def client_rates(self) -> dict:
        """{client: EWMA work-units/s} across the whole fabric — feed for
        ``split_parallel.adaptive_shard_sizes``."""
        return {name: s.rate for name, s in self.queue.stats.items()}

    def console(self) -> dict:
        """Merged control console: global queue counters plus per-member
        client/steal/edge views."""
        snap = self.queue.snapshot()
        snap["project"] = self.project_name
        snap["migrations"] = self.migrations
        snap["members"] = [
            {"name": f"member{m.index}", "alive": m.alive,
             "steals": m.steals, "home_shards": len(m.home_shards),
             "clients": [{"name": c.profile.name, "executed": c.executed,
                          "errors": c.errors, "alive": not c.done}
                         for c in m.clients],
             "edge": m.edge.stats()}
            for m in self.members]
        return snap
