"""Cross-host transport: the federation's wire protocol over asyncio streams.

The paper's premise is that nodes join "only by accessing a website" —
distribution happens over HTTP/WebSocket, never over in-process method
calls.  Until this module, our federation (``core/federation.py``) still
communicated by direct object references inside one event loop.  Here the
client ⇄ distributor surface becomes a real **message protocol**:

  * **Framing** — length-prefixed JSON: a 4-byte big-endian length header
    followed by one UTF-8 JSON object.  Opaque payloads (task code, static
    assets, ticket args, results) travel as base64 fields inside the JSON
    envelope — this reproduction pickles them, where the paper ships
    JavaScript source; the envelope is identical either way.
  * **Protocol v2** (negotiated in ``hello`` via ``max_proto``; v1 peers
    keep the JSON-only wire unchanged) adds **binary chunk frames**: a
    header frame may announce ``chunks``/``blob_bytes``, followed by that
    many raw-byte frames (length prefix with the top bit set).  Static
    payloads then ride the :mod:`repro.core.wire` binary codec — raw
    array buffers with a compact dtype/shape manifest, zero pickle and
    zero base64 for array data, streamed in bounded chunks so a large
    weight blob never materializes as one frame.  Conditional static
    fetches may ask for a **delta** (``"delta": true``): the registry's
    per-leaf version stamps let it ship only the leaves that changed
    since the client's cached version (full payload past the
    ``DELTA_HISTORY`` staleness horizon), and the client splices them in
    via the same ``merge_versioned_fetch`` helper the in-process path
    uses.
  * **Messages** — ``hello`` answered by ``hello_ok`` or a ``busy``
    refusal (admission control), ``lease_request``/``lease_grant``,
    ``submit``/``submit_ok``, ``release``/``release_ok``,
    ``fetch_task``/``fetch_static`` answered by ``task_data``/
    ``static_data``/``not_modified``, ``heartbeat``/``heartbeat_ok``
    (liveness while holding a lease), ``error_report``/
    ``error_report_ok``, server-pushed ``invalidate``, and ``error``.
    The full spec with frame layout, JSON examples, and the reconnect
    state machine is **docs/PROTOCOL.md** — keep the two in sync.
  * **Browser-scale churn machinery** (see docs/PROTOCOL.md §Admission
    control and §Heartbeat and eviction): the server may cap accepted
    connections per endpoint (``max_conns_per_member``) and refuse the
    overflow at ``hello`` with ``busy`` + a ``retry_after`` hint; a
    connection holding leases that goes silent past
    ``heartbeat_timeout`` is **evicted** — its leases are force-released
    immediately instead of waiting out the watchdog's ``grace x ETA``
    deadline, so 10^4-client fleets with tab-close churn redistribute
    stranded work in one heartbeat interval.
  * :class:`TransportServer` — wraps an ``AsyncDistributor`` or
    ``FederatedDistributor`` behind a loopback (or any TCP) socket.  Each
    connection is bound at ``hello`` time to one endpoint
    (``transport_endpoints()``: the distributor itself, or the
    least-connected alive federation member), so remote clients get the
    same home-shard/steal lease path and edge-cached asset serving as
    in-process clients.  Registry invalidations are pushed to every
    connection as ``invalidate`` frames.
  * :class:`RemoteBrowserClient` — a browser node that speaks ONLY the
    wire protocol: it holds no reference to any distributor object, just a
    host/port.  It keeps the version-aware LRU cache and conditional-fetch
    (ETag analogue) behaviour of the in-process clients, so PR 3's cache
    coherence survives the serialization boundary, and it
    **reconnects with resume**: a dropped connection re-dials, re-submits
    any finished-but-unsubmitted results (duplicates are dropped
    server-side, first result wins), and re-leases — tickets stranded in
    its dead lease come back through the existing watchdog path.

``benchmarks/transport_overhead.py`` measures serialized vs in-process
round throughput and re-runs the PR 3 re-register storm over the wire;
``examples/sashimi_browser_sim.py --transport`` is the runnable demo.
"""
from __future__ import annotations

import asyncio
import base64
import collections
import itertools
import json
import pickle
import random
import struct
import time
import traceback
from typing import Any, Callable, Optional

from repro.core.distributor import (BrowserNodeBase, ClientProfile, Fetched,
                                    TaskDef, merge_unconditional_fetch,
                                    merge_versioned_fetch)
from repro.core.tickets import LeaseBatch
# ProtocolError lives in the leaf module repro.core.wire (the registry's
# codecs raise it too); re-exported here where it historically lived.
from repro.core.wire import (ProtocolError, decode_binary, encode_binary,
                             make_clock_echo, make_telemetry,
                             make_trace_context, parse_clock_echo,
                             parse_retry_after, parse_telemetry,
                             parse_trace_context)

#: Highest protocol version this build speaks.  ``hello`` negotiates: the
#: client sends ``proto`` (its floor, 1 for compatibility) and
#: ``max_proto``; the server answers with the highest version both sides
#: support.  A ``proto`` outside the server's supported range is refused
#: with an ``error`` frame (code ``proto-mismatch``).
PROTOCOL_VERSION = 2

#: Lowest protocol version still served (v1 = JSON-only wire).
MIN_PROTOCOL_VERSION = 1

#: Default ceiling on one frame's body (JSON or binary chunk).  A header
#: announcing more is rejected (code ``frame-too-large``) without
#: allocating the buffer.
MAX_FRAME_BYTES = 64 * 1024 * 1024

#: Top bit of the length prefix marks a **binary chunk frame** (raw
#: bytes, no JSON).  Frame bodies are capped far below 2^31, so the bit
#: is unambiguous.
CHUNK_FLAG = 0x80000000

#: Default ceiling on one chunked message's total binary payload
#: (checked against the header's ``blob_bytes`` BEFORE any chunk is
#: read, code ``blob-too-large``).
MAX_BLOB_BYTES = 1 << 30

#: Ceiling on the chunk count one header may announce.
MAX_BLOB_CHUNKS = 1 << 16

#: Default size a sender slices binary payloads into — large statics
#: stream in bounded frames instead of materializing as one.
DEFAULT_CHUNK_BYTES = 1 << 20

_HEADER = struct.Struct(">I")


# ---------------------------------------------------------------------------
# Framing + payload codec
# ---------------------------------------------------------------------------


def encode_frame(msg: dict) -> bytes:
    """Serialise one message: 4-byte big-endian body length + UTF-8 JSON."""
    body = json.dumps(msg, separators=(",", ":")).encode("utf-8")
    if len(body) > MAX_FRAME_BYTES:
        raise ProtocolError("frame-too-large",
                            f"frame body is {len(body)} bytes "
                            f"(max {MAX_FRAME_BYTES})")
    return _HEADER.pack(len(body)) + body


def encode_chunk(part: bytes) -> bytes:
    """Serialise one binary chunk frame: length prefix with the top bit
    set, then the raw bytes (protocol v2)."""
    if len(part) > MAX_FRAME_BYTES:
        raise ProtocolError("frame-too-large",
                            f"chunk is {len(part)} bytes "
                            f"(max {MAX_FRAME_BYTES})")
    return _HEADER.pack(CHUNK_FLAG | len(part)) + part


def build_blob_frames(msg: dict, buffer: bytes, *,
                      chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                      max_frame_bytes: int = MAX_FRAME_BYTES) -> list[bytes]:
    """Frames for one logical message with a binary payload: the JSON
    header (annotated with ``chunks``/``blob_bytes``) followed by the
    payload sliced into chunk frames of ``chunk_bytes``.  An empty buffer
    yields just the plain header frame.  The sender must write the list
    contiguously (no interleaved pushes) — both sides here do so under
    their write lock / sequential request loop."""
    if not buffer:
        return [encode_frame(msg)]
    size = max(1, min(chunk_bytes, max_frame_bytes))
    n_chunks = -(-len(buffer) // size)
    if n_chunks > MAX_BLOB_CHUNKS:             # huge blob: fewer, larger
        size = -(-len(buffer) // MAX_BLOB_CHUNKS)
        n_chunks = -(-len(buffer) // size)
    frames = [encode_frame({**msg, "chunks": n_chunks,
                            "blob_bytes": len(buffer)})]
    for i in range(0, len(buffer), size):
        frames.append(encode_chunk(buffer[i:i + size]))
    return frames


async def read_frame_ex(reader: asyncio.StreamReader, *,
                        max_bytes: int = MAX_FRAME_BYTES,
                        allow_chunk: bool = False
                        ) -> tuple[Any, int]:
    """Read one frame; returns ``(message, wire_bytes)``.

    ``(None, 0)`` means clean EOF at a frame boundary (peer closed).  A
    JSON frame decodes to a dict; a binary chunk frame (v2, top length
    bit set) returns raw ``bytes`` — but only where the caller expects
    one (``allow_chunk=True``, i.e. inside a chunked message), otherwise
    it is a protocol error (code ``unexpected-chunk``).  Raises
    :class:`ProtocolError` for a truncated frame (EOF mid-frame), an
    oversized length header, a non-JSON body, or a body that is not an
    object with a string ``type`` — the reader never hangs on garbage,
    and never allocates more than ``max_bytes``."""
    try:
        header = await reader.readexactly(_HEADER.size)
    except asyncio.IncompleteReadError as e:
        if not e.partial:
            return None, 0
        raise ProtocolError("truncated-frame", "EOF inside frame header")
    (raw,) = _HEADER.unpack(header)
    is_chunk = bool(raw & CHUNK_FLAG)
    length = raw & (CHUNK_FLAG - 1)
    if length > max_bytes:
        raise ProtocolError("frame-too-large",
                            f"frame announces {length} bytes "
                            f"(max {max_bytes})")
    try:
        body = await reader.readexactly(length)
    except asyncio.IncompleteReadError:
        raise ProtocolError("truncated-frame", "EOF inside frame body")
    if is_chunk:
        if not allow_chunk:
            raise ProtocolError("unexpected-chunk",
                                "binary chunk frame outside a chunked "
                                "message")
        return bytes(body), _HEADER.size + length
    try:
        msg = json.loads(body.decode("utf-8"))
    except (UnicodeDecodeError, ValueError):
        raise ProtocolError("bad-json", "frame body is not valid JSON")
    if not isinstance(msg, dict) or not isinstance(msg.get("type"), str):
        raise ProtocolError(
            "bad-message", "frame must be an object with a string 'type'")
    return msg, _HEADER.size + length


async def read_frame(reader: asyncio.StreamReader, *,
                     max_bytes: int = MAX_FRAME_BYTES) -> Optional[dict]:
    """:func:`read_frame_ex` without the byte count (JSON frames only)."""
    msg, _ = await read_frame_ex(reader, max_bytes=max_bytes)
    return msg


async def read_message(reader: asyncio.StreamReader, *,
                       max_bytes: int = MAX_FRAME_BYTES,
                       max_blob_bytes: int = MAX_BLOB_BYTES,
                       allow_chunks: bool = True
                       ) -> tuple[Optional[dict], int]:
    """Read one **logical** message: a JSON frame, plus — when its header
    announces ``chunks``/``blob_bytes`` (protocol v2) — exactly that many
    binary chunk frames, reassembled into ``msg["_blob"]``.

    The chunk state machine is strict (docs/PROTOCOL.md §Chunked
    messages): the declared total is validated against ``max_blob_bytes``
    *before* the first chunk is read (code ``blob-too-large``), chunk
    count and sizes must match the declaration exactly (code
    ``bad-blob``), a JSON frame where a chunk is due is
    ``chunk-mismatch``, and EOF mid-blob is ``truncated-frame``.  Memory
    is bounded by ``max_blob_bytes`` + one frame."""
    msg, n = await read_frame_ex(reader, max_bytes=max_bytes)
    if msg is None or ("chunks" not in msg and "blob_bytes" not in msg):
        return msg, n
    if not allow_chunks:
        raise ProtocolError("bad-blob",
                            "chunked message on a v1 connection")
    n_chunks = msg.get("chunks")
    total = msg.get("blob_bytes")
    if (not isinstance(n_chunks, int) or isinstance(n_chunks, bool)
            or not isinstance(total, int) or isinstance(total, bool)
            or n_chunks < 1 or n_chunks > MAX_BLOB_CHUNKS or total < 0):
        raise ProtocolError("bad-blob",
                            f"bad chunk declaration: chunks={n_chunks!r} "
                            f"blob_bytes={total!r}")
    if total > max_blob_bytes:
        raise ProtocolError("blob-too-large",
                            f"blob announces {total} bytes "
                            f"(max {max_blob_bytes})")
    parts: list[bytes] = []
    received = 0
    for _ in range(n_chunks):
        chunk, cn = await read_frame_ex(reader, max_bytes=max_bytes,
                                        allow_chunk=True)
        if chunk is None:
            raise ProtocolError("truncated-frame",
                                "EOF inside a chunked message")
        if not isinstance(chunk, bytes):
            raise ProtocolError("chunk-mismatch",
                                "JSON frame arrived where a binary chunk "
                                "was expected")
        received += len(chunk)
        n += cn
        if received > total:
            raise ProtocolError("bad-blob",
                                f"chunks carry more than the declared "
                                f"{total} bytes")
        parts.append(chunk)
    if received != total:
        raise ProtocolError("bad-blob",
                            f"chunks carry {received} bytes, header "
                            f"declared {total}")
    out = dict(msg)
    out["_blob"] = b"".join(parts)
    return out, n


def encode_payload(obj: Any) -> str:
    """Opaque payload codec: pickle + base64.  This reproduction's stand-in
    for the paper's JavaScript-source payloads — the JSON envelope treats
    it as an uninterpreted string either way."""
    return base64.b64encode(pickle.dumps(obj)).decode("ascii")


def decode_payload(s: str) -> Any:
    """Inverse of :func:`encode_payload`."""
    return pickle.loads(base64.b64decode(s.encode("ascii")))


def _fetch_reply(kind: str, seq, got: Fetched) -> dict:
    """Wire reply for a versioned fetch: ``not_modified`` is metadata only,
    otherwise the payload rides in a ``task_data``/``static_data`` frame
    (v1 JSON form: pickled-base64 ``payload``)."""
    if got.not_modified:
        return {"type": "not_modified", "seq": seq, "version": got.version}
    return {"type": kind, "seq": seq, **got.to_wire(encode_payload)}


def _fetch_reply_bin(kind: str, seq, got: Fetched) -> tuple[dict, bytes]:
    """Protocol v2 wire reply for a versioned fetch with a payload: the
    JSON header plus the binary buffer (``encoding: "bin"``); array data
    travels raw, described by the ``manifest``.  A delta reply (changed
    leaves only) additionally carries ``delta_base``."""
    manifest, buffer = encode_binary(got.value)
    header = {"type": kind, "seq": seq, "version": got.version,
              "not_modified": False, "current": got.current,
              "encoding": "bin", "manifest": manifest}
    if got.delta_base is not None:
        header["delta_base"] = got.delta_base
    return header, buffer


def _decode_fetch(reply: dict) -> Fetched:
    """Client-side inverse of :func:`_fetch_reply` /
    :func:`_fetch_reply_bin` (the binary buffer rides in
    ``reply["_blob"]``, attached by :func:`read_message`)."""
    if reply["type"] == "not_modified":
        return Fetched(None, reply["version"], not_modified=True)
    if reply.get("encoding") == "bin":
        value = decode_binary(reply.get("manifest"),
                              reply.get("_blob", b""))
        return Fetched(value, reply["version"],
                       current=reply.get("current", True),
                       delta_base=reply.get("delta_base"))
    return Fetched.from_wire(reply, decode_payload)


# ---------------------------------------------------------------------------
# Server
# ---------------------------------------------------------------------------


class _Connection:
    """Server-side per-connection state: the endpoint the client is bound
    to, its open leases, and a write lock so request replies and pushed
    ``invalidate`` frames never interleave mid-frame."""

    def __init__(self, server: "TransportServer",
                 reader: asyncio.StreamReader, writer: asyncio.StreamWriter):
        self.server = server
        self.reader = reader
        self.writer = writer
        self.endpoint = None               # bound at hello time
        self.client = "?"
        self.leases: dict[int, LeaseBatch] = {}
        self.ready = False                 # hello completed
        self.proto = MIN_PROTOCOL_VERSION  # negotiated at hello time
        # liveness mark on the server's (injectable) wall clock: stamped
        # at hello and refreshed by EVERY inbound frame — a heartbeat is
        # just the cheapest frame a busy client can send
        self.last_seen = server._clock()
        self.evicted = False               # eviction happened exactly once
        self._wlock = asyncio.Lock()

    async def send(self, msg: dict):
        """Write one frame under the connection's write lock."""
        frame = encode_frame(msg)
        async with self._wlock:
            self.writer.write(frame)
            await self.writer.drain()
        self.server.frames_out += 1
        self.server.bytes_out += len(frame)
        self.server._count_out(msg.get("type", "?"), 1, len(frame))

    async def send_blob(self, msg: dict, buffer: bytes):
        """Write one chunked message (header + binary chunk frames) under
        the write lock, so a pushed ``invalidate`` can never interleave
        mid-blob."""
        frames = build_blob_frames(msg, buffer,
                                   chunk_bytes=self.server.chunk_bytes,
                                   max_frame_bytes=self.server
                                   .max_frame_bytes)
        async with self._wlock:
            for frame in frames:
                self.writer.write(frame)
            await self.writer.drain()
        self.server.frames_out += len(frames)
        self.server.chunks_out += len(frames) - 1
        self.server.bytes_out += sum(len(f) for f in frames)
        self.server._count_out(msg.get("type", "?"), len(frames),
                               sum(len(f) for f in frames))

    async def send_error(self, seq, err: ProtocolError):
        """Best-effort ``error`` frame (swallowed if the peer is gone)."""
        try:
            await self.send({"type": "error", "seq": seq,
                             "code": err.code, "message": err.message})
        except (ConnectionError, RuntimeError):
            pass                           # peer already gone

    def close(self):
        """Drop the underlying transport (idempotent)."""
        try:
            self.writer.close()
        except RuntimeError:
            pass


class TransportServer:
    """Serve a distributor's client surface over length-prefixed JSON.

    Wraps an ``AsyncDistributor`` **or** a ``FederatedDistributor``: each
    incoming connection is bound to one of ``transport_endpoints()`` (the
    least-connected alive member in a federation) for its lifetime, and
    every request on it — leases, submits, releases, versioned fetches —
    goes through that endpoint exactly as an in-process client's calls
    would.  Registry invalidations are fanned out to every live connection
    as ``invalidate`` pushes.

    Lifecycle: ``await start()`` binds the socket (default loopback,
    ephemeral port — ``address`` holds the result) and arms the
    endpoints' watchdogs; ``await stop()`` closes every connection.

    **Admission control** (``max_conns_per_member``): with the cap set,
    a ``hello`` that would push every endpoint past its cap is refused
    with a ``busy`` frame carrying a ``retry_after`` hint, and the
    connection is closed — backpressure happens at the door, before the
    connection consumes a handler task or a lease.  Unset (the default),
    admission is unlimited, as before.

    **Heartbeat/eviction** (``heartbeat_timeout``): with the timeout
    set, a sweeper evicts any connection that holds open leases but has
    been silent (no frame of any kind) longer than the timeout — its
    leases are force-released (``client_failed=True``) *immediately*,
    instead of waiting out the watchdog's ``grace x ETA`` deadline, and
    the socket is closed.  Clients signal liveness mid-execution with
    ``heartbeat`` frames.  Idle connections (no open leases — e.g.
    parked in ``lease_request``) are never evicted: they hold no work,
    and a parked request cannot frame heartbeats anyway.  Unset (the
    default), dead connections fall back to the watchdog path alone,
    exactly the pre-eviction behaviour.
    """

    def __init__(self, distributor, *, host: str = "127.0.0.1",
                 port: int = 0, max_frame_bytes: int = MAX_FRAME_BYTES,
                 max_proto: int = PROTOCOL_VERSION,
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 max_blob_bytes: int = MAX_BLOB_BYTES,
                 max_conns_per_member: Optional[int] = None,
                 retry_after: float = 0.5,
                 heartbeat_timeout: Optional[float] = None,
                 eviction_interval: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic,
                 tracer=None, fleet=None):
        self.distributor = distributor
        # default to the distributor's tracer, so wiring one tracer into
        # the fabric lights up the transport lanes with no extra plumbing
        self.tracer = (tracer if tracer is not None
                       else getattr(distributor, "tracer", None))
        #: optional repro.obs.FleetAggregator — the sink for clients'
        #: ``telemetry`` frames and heartbeat clock echoes.  Unset, the
        #: server drops telemetry (counted) and its heartbeat replies
        #: stay byte-identical to pre-fleet builds.
        self.fleet = fleet
        self._wire_spans: dict[int, int] = {}     # lease_id -> span id
        self.host = host
        self.port = port
        self.max_frame_bytes = max_frame_bytes
        #: highest protocol version this server negotiates; set to 1 to
        #: behave exactly like a pre-v2 (JSON-only) server
        self.max_proto = max_proto
        self.chunk_bytes = chunk_bytes
        self.max_blob_bytes = max_blob_bytes
        #: accepted-connection cap per endpoint (None = unlimited)
        self.max_conns_per_member = max_conns_per_member
        #: seconds hinted in a ``busy`` refusal's ``retry_after``
        self.retry_after = retry_after
        #: silence (on ``clock``) after which a lease-holding connection
        #: is evicted; None disables eviction entirely
        self.heartbeat_timeout = heartbeat_timeout
        # sweep cadence: a fraction of the timeout, so detection latency
        # is at most ~1.25x the timeout itself
        self.eviction_interval = (
            eviction_interval if eviction_interval is not None
            else (heartbeat_timeout / 4.0
                  if heartbeat_timeout is not None else 1.0))
        self._clock = clock                # liveness clock (injectable)
        self.address: Optional[tuple[str, int]] = None
        self.frames_in = 0
        self.frames_out = 0
        self.bytes_in = 0
        self.bytes_out = 0
        self.chunks_in = 0
        self.chunks_out = 0
        self.protocol_errors = 0
        self.busy_refusals = 0             # hellos refused at the door
        self.heartbeats = 0                # heartbeat frames answered
        self.evictions = 0                 # connections evicted
        self.evicted_leases = 0            # leases force-released by those
        self.telemetry_accepted = 0        # telemetry batches into fleet
        self.telemetry_dropped = 0         # telemetry batches discarded
        # per-message-type wire accounting (frames include chunk frames;
        # feeds the obs MetricsRegistry via repro.obs.collect)
        self.msg_frames_in: collections.Counter = collections.Counter()
        self.msg_frames_out: collections.Counter = collections.Counter()
        self.msg_bytes_in: collections.Counter = collections.Counter()
        self.msg_bytes_out: collections.Counter = collections.Counter()
        self._server: Optional[asyncio.AbstractServer] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._conns: set[_Connection] = set()
        self._handler_tasks: set[asyncio.Task] = set()
        self._eviction_task: Optional[asyncio.Task] = None
        self._subscribed = False

    # -- lifecycle ------------------------------------------------------------

    async def start(self) -> tuple[str, int]:
        """Bind the listening socket; returns ``(host, port)``.  Arms the
        endpoint watchdogs and subscribes to the registry's invalidation
        feed (pushed to clients as ``invalidate`` frames)."""
        self._loop = asyncio.get_running_loop()
        for ep in self.distributor.transport_endpoints():
            ep.ensure_watchdog()
        if not self._subscribed and hasattr(self.distributor,
                                            "subscribe_invalidation"):
            self.distributor.subscribe_invalidation(self._on_invalidate)
            self._subscribed = True
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.address = self._server.sockets[0].getsockname()[:2]
        if (self.heartbeat_timeout is not None
                and self._eviction_task is None):
            self._eviction_task = self._loop.create_task(
                self._eviction_loop())
        return self.address

    async def stop(self):
        """Close the listener and every live connection, and wait for the
        per-connection handler tasks to unwind."""
        if self._eviction_task is not None:
            self._eviction_task.cancel()
            try:
                await self._eviction_task
            except asyncio.CancelledError:
                pass
            self._eviction_task = None
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        for conn in list(self._conns):
            conn.close()
        tasks = list(self._handler_tasks)
        for t in tasks:
            t.cancel()
        if tasks:
            await asyncio.gather(*tasks, return_exceptions=True)
        self._conns.clear()
        self._handler_tasks.clear()
        if self.tracer is not None:
            # leases granted but never submitted back (client died, lease
            # watchdog-released): close their wire spans so a stopped
            # server always leaves a balanced trace
            for lid in list(self._wire_spans):
                self.tracer.end(self._wire_spans.pop(lid, None),
                                args={"status": "orphaned"})

    def drop_connections(self) -> int:
        """Hard-close every live connection WITHOUT stopping the listener —
        fault injection for reconnect tests (the wire analogue of
        ``kill_member``).  Open leases stay with the watchdog."""
        n = 0
        for conn in list(self._conns):
            conn.close()
            n += 1
        return n

    def drop_member_connections(self, index: int) -> int:
        """Hard-close every connection bound to federation member
        ``index`` — the transport half of ``kill_member``.  A remote
        client whose member dies would otherwise keep talking to a
        scheduler with no watchdog; dropping the connection makes it
        reconnect-with-resume, and ``_pick_endpoint`` (alive members only)
        lands it on a survivor.  Returns how many connections dropped."""
        n = 0
        for conn in list(self._conns):
            if getattr(conn.endpoint, "index", None) == index:
                conn.close()
                n += 1
        return n

    # -- heartbeat / eviction -------------------------------------------------

    async def _eviction_loop(self):
        """Sweep for lease-holding connections silent past the heartbeat
        timeout, forcing their leases back into circulation immediately.
        Runs only when ``heartbeat_timeout`` is set (armed by start())."""
        while True:
            await asyncio.sleep(self.eviction_interval)
            now = self._clock()
            for conn in list(self._conns):
                if (conn.ready and conn.leases and not conn.evicted
                        and now - conn.last_seen > self.heartbeat_timeout):
                    await self._evict(conn, reason="silent")

    async def _evict(self, conn: _Connection, *, reason: str) -> int:
        """Evict one connection: drain its lease bookkeeping FIRST (so a
        submit frame racing this eviction takes the late-submit path,
        where the queue's first-result-wins rule drops duplicates — a
        ticket can never double-complete), force-release every drained
        lease, close its wire spans, then close the socket.  Returns the
        number of leases force-released.  Idempotent per connection."""
        if conn.evicted:
            return 0
        conn.evicted = True
        self.evictions += 1
        batches = list(conn.leases.values())
        conn.leases.clear()
        released = 0
        for batch in batches:
            if self.tracer is not None:
                self.tracer.end(
                    self._wire_spans.pop(batch.lease_id, None),
                    ts=conn.endpoint.queue.clock(),
                    args={"status": "evicted", "reason": reason})
            released += await conn.endpoint.release_lease(
                batch, client_failed=True)
        self.evicted_leases += len(batches)
        if self.tracer is not None:
            self.tracer.instant(
                "transport.evict", track="wire", cat="wire",
                ts=conn.endpoint.queue.clock(),
                args={"client": conn.client, "reason": reason,
                      "leases": len(batches), "released": released})
        conn.close()
        return released

    async def evict_client(self, client: str, *,
                           reason: str = "forced") -> int:
        """Evict every ready connection announcing ``client`` in its
        hello — the server-side tab-close lever (chaos harness, admin
        tooling).  Unlike the silent-sweep path this also evicts
        connections holding no leases (they are just closed).  Returns
        the total leases force-released."""
        released = 0
        for conn in list(self._conns):
            if conn.ready and conn.client == client:
                released += await self._evict(conn, reason=reason)
        return released

    def _count_out(self, kind: str, frames: int, nbytes: int):
        self.msg_frames_out[kind] += frames
        self.msg_bytes_out[kind] += nbytes

    def _count_in(self, kind: str, frames: int, nbytes: int):
        self.msg_frames_in[kind] += frames
        self.msg_bytes_in[kind] += nbytes

    def stats(self) -> dict:
        """Console counters: live connections, wire traffic totals, and
        the per-message-type frame/byte breakdown."""
        return {"connections": len(self._conns),
                "frames_in": self.frames_in, "frames_out": self.frames_out,
                "bytes_in": self.bytes_in, "bytes_out": self.bytes_out,
                "chunks_in": self.chunks_in, "chunks_out": self.chunks_out,
                "protocol_errors": self.protocol_errors,
                "busy_refusals": self.busy_refusals,
                "heartbeats": self.heartbeats,
                "evictions": self.evictions,
                "evicted_leases": self.evicted_leases,
                "telemetry_accepted": self.telemetry_accepted,
                "telemetry_dropped": self.telemetry_dropped,
                "by_type": {
                    "frames_in": dict(self.msg_frames_in),
                    "frames_out": dict(self.msg_frames_out),
                    "bytes_in": dict(self.msg_bytes_in),
                    "bytes_out": dict(self.msg_bytes_out)}}

    # -- invalidation push ----------------------------------------------------

    def _on_invalidate(self, key: str, version: int):
        # sync registry callback (may fire from a non-loop thread); hop to
        # the server loop, where per-connection write locks serialise the
        # push against in-flight replies
        loop = self._loop
        if loop is None or loop.is_closed():
            return
        loop.call_soon_threadsafe(self._broadcast_invalidate, key, version)

    def _broadcast_invalidate(self, key: str, version: int):
        msg = {"type": "invalidate", "key": key, "version": version}
        for conn in list(self._conns):
            if conn.ready:
                task = asyncio.ensure_future(conn.send(msg))
                task.add_done_callback(lambda t: t.exception())

    # -- connection handling --------------------------------------------------

    def _pick_endpoint(self, conns: set[_Connection]):
        """Least-connected alive endpoint (ties break toward the lowest
        member index), so remote clients spread across a federation the
        way ``spawn_clients`` spreads in-process ones."""
        endpoints = self.distributor.transport_endpoints()
        if not endpoints:
            raise ProtocolError("no-endpoint", "no alive endpoint to serve")
        load = collections.Counter(
            id(c.endpoint) for c in conns if c.endpoint is not None)
        return min(endpoints,
                   key=lambda e: (load.get(id(e), 0),
                                  getattr(e, "index", 0)))

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter):
        conn = _Connection(self, reader, writer)
        self._conns.add(conn)
        self._handler_tasks.add(asyncio.current_task())
        try:
            await self._serve(conn)
        except (ConnectionError, asyncio.IncompleteReadError):
            pass                           # peer vanished mid-exchange
        finally:
            self._conns.discard(conn)
            self._handler_tasks.discard(asyncio.current_task())
            conn.close()
            if (self.heartbeat_timeout is not None and conn.leases
                    and not conn.evicted):
                # eviction mode: a DETECTED death (EOF/reset) is treated
                # like heartbeat silence — the leases come back now, not
                # at the watchdog's grace x ETA.  Without eviction mode
                # the watchdog stays the single recovery path (legacy).
                await self._evict(conn, reason="disconnect")

    async def _serve(self, conn: _Connection):
        # -- handshake: first frame must be a protocol-compatible hello --
        try:
            msg, n = await read_frame_ex(conn.reader,
                                         max_bytes=self.max_frame_bytes)
        except ProtocolError as e:
            self.protocol_errors += 1
            await conn.send_error(None, e)
            return
        if msg is None:
            return
        self.frames_in += 1
        self.bytes_in += n
        self._count_in(msg.get("type", "?"), 1, n)
        seq = msg.get("seq")
        if msg["type"] != "hello":
            self.protocol_errors += 1
            await conn.send_error(seq, ProtocolError(
                "bad-handshake", "first frame must be 'hello'"))
            return
        # negotiation: ``proto`` is the client's floor (1 for old
        # clients), ``max_proto`` its ceiling (defaults to the floor, so
        # a plain v1 hello negotiates v1); the connection speaks the
        # highest version inside both sides' ranges
        proto = msg.get("proto")
        if (not isinstance(proto, int) or isinstance(proto, bool)
                or not (MIN_PROTOCOL_VERSION <= proto <= self.max_proto)):
            self.protocol_errors += 1
            await conn.send_error(seq, ProtocolError(
                "proto-mismatch",
                f"server speaks protos {MIN_PROTOCOL_VERSION}.."
                f"{self.max_proto}, client sent {proto!r}"))
            return
        client_max = msg.get("max_proto", proto)
        if not isinstance(client_max, int) or isinstance(client_max, bool):
            client_max = proto
        conn.proto = min(self.max_proto, max(proto, client_max))
        conn.client = str(msg.get("client", "remote"))
        try:
            conn.endpoint = self._pick_endpoint(self._conns)
        except ProtocolError as e:
            # e.g. every federation member is dead: refuse the hello with
            # an error frame instead of a silent close
            self.protocol_errors += 1
            await conn.send_error(seq, e)
            return
        if self.max_conns_per_member is not None:
            # admission control: _pick_endpoint chose the least-loaded
            # endpoint, so if even that one is at its cap the fabric is
            # full — refuse with ``busy`` (retryable backpressure, not an
            # error) and close.  Only ready connections count: a flood of
            # half-open hellos must not starve out accepted clients.
            load = sum(1 for c in self._conns
                       if c is not conn and c.ready
                       and c.endpoint is conn.endpoint)
            if load >= self.max_conns_per_member:
                self.busy_refusals += 1
                if self.tracer is not None:
                    self.tracer.instant(
                        "transport.busy", track="wire", cat="wire",
                        ts=conn.endpoint.queue.clock(),
                        args={"client": str(msg.get("client", "remote")),
                              "retry_after": self.retry_after})
                conn.endpoint = None
                await conn.send({"type": "busy", "seq": seq,
                                 "retry_after": self.retry_after})
                return
        conn.endpoint.ensure_watchdog()    # re-arm after a drained round
        conn.ready = True
        conn.last_seen = self._clock()
        await conn.send({"type": "hello_ok", "seq": seq,
                         "proto": conn.proto,
                         "project": conn.endpoint.project_name,
                         "member": getattr(conn.endpoint, "index", None)})
        # -- request loop: sequential request/response per connection ----
        while True:
            try:
                msg, n = await read_message(
                    conn.reader, max_bytes=self.max_frame_bytes,
                    max_blob_bytes=self.max_blob_bytes,
                    allow_chunks=conn.proto >= 2)
            except ProtocolError as e:
                # reject loudly, then close: after a framing error the
                # stream position is unrecoverable
                self.protocol_errors += 1
                await conn.send_error(None, e)
                return
            if msg is None:
                return                     # clean close
            conn.last_seen = self._clock() # any frame proves liveness
            self.frames_in += 1 + msg.get("chunks", 0)
            self.chunks_in += msg.get("chunks", 0)
            self.bytes_in += n
            self._count_in(msg.get("type", "?"), 1 + msg.get("chunks", 0), n)
            await self._dispatch(conn, msg)

    async def _dispatch(self, conn: _Connection, msg: dict):
        seq = msg.get("seq")
        kind = msg["type"]
        try:
            if kind == "lease_request":
                await self._handle_lease(conn, seq)
            elif kind == "submit":
                if msg.get("encoding") == "bin":
                    # v2: one binary blob for the whole result dict —
                    # gradient arrays go up raw, no pickle+base64
                    decoded = decode_binary(msg.get("manifest"),
                                            msg.get("_blob", b""))
                    if not isinstance(decoded, dict):
                        raise ProtocolError(
                            "bad-manifest",
                            "binary submit must decode to a dict")
                    results = {int(tid): r for tid, r in decoded.items()}
                else:
                    results = {int(tid): decode_payload(payload)
                               for tid, payload in msg["results"].items()}
                batch = conn.leases.pop(msg["lease_id"], None)
                if batch is not None:
                    accepted = await conn.endpoint.submit_batch(batch,
                                                                results)
                else:
                    # resume after reconnect: the lease lives on another
                    # (dead) connection or was watchdog-released; the
                    # queue accepts late results and drops duplicates
                    accepted = conn.endpoint.queue.submit_batch(
                        msg["lease_id"], results, conn.client)
                    conn.endpoint._notify_waiters()
                if self.tracer is not None:
                    # the span covers grant -> submit; the client's echoed
                    # trace context (its measured execute time) lands in
                    # the span args so the wire/compute split is visible
                    echo = parse_trace_context(msg.get("trace")) or {}
                    self.tracer.end(
                        self._wire_spans.pop(msg["lease_id"], None),
                        ts=conn.endpoint.queue.clock(),
                        args={"status": "submitted", "accepted": accepted,
                              **echo})
                await conn.send({"type": "submit_ok", "seq": seq,
                                 "accepted": accepted})
            elif kind == "release":
                await self._handle_release(conn, seq, msg)
            elif kind == "fetch_task":
                got = conn.endpoint.fetch_task_versioned(
                    msg["name"], if_version=msg.get("if_version"))
                await conn.send(_fetch_reply("task_data", seq, got))
            elif kind == "fetch_static":
                want_delta = bool(msg.get("delta")) and conn.proto >= 2
                got = conn.endpoint.serve_static_versioned(
                    msg["key"], if_version=msg.get("if_version"),
                    delta=want_delta)
                if conn.proto >= 2 and not got.not_modified:
                    # v2: full payloads AND deltas go binary + chunked
                    header, buffer = _fetch_reply_bin("static_data", seq,
                                                      got)
                    await conn.send_blob(header, buffer)
                else:
                    await conn.send(_fetch_reply("static_data", seq, got))
            elif kind == "heartbeat":
                # liveness already refreshed by the read loop (any frame
                # counts); the reply just completes the round-trip.  The
                # optional lease_id is advisory — a replayed heartbeat
                # naming a lease this connection no longer holds (post-
                # eviction reconnect) is harmless and stays tolerated,
                # mirroring parse_trace_context's posture on peer junk.
                self.heartbeats += 1
                reply: dict[str, Any] = {"type": "heartbeat_ok",
                                         "seq": seq}
                if self.fleet is not None and conn.proto >= 2:
                    # fleet plane armed: stamp the reply so the client
                    # can echo (t0, server_ts, t1) next heartbeat, and
                    # turn any echo riding THIS heartbeat into a clock-
                    # skew sample.  Without a fleet the reply stays
                    # byte-identical to pre-fleet servers.
                    reply["server_ts"] = conn.endpoint.queue.clock()
                    echo = parse_clock_echo(msg.get("echo"))
                    if echo is not None:
                        t0, sts, t1 = echo
                        self.fleet.clock_sample(
                            conn.client,
                            offset=sts - (t0 + t1) / 2.0, rtt=t1 - t0)
                await conn.send(reply)
            elif kind == "telemetry":
                # observability payload from an untrusted peer: parse
                # tolerantly, ingest when the fleet plane is armed, and
                # otherwise drop silently-but-counted.  Garbage costs
                # the sender its batch, never the server its connection.
                accepted = False
                if conn.proto >= 2 and self.fleet is not None:
                    parsed = parse_telemetry(msg.get("telemetry"))
                    accepted = self.fleet.ingest(
                        conn.client, parsed,
                        recv_ts=conn.endpoint.queue.clock())
                if accepted:
                    self.telemetry_accepted += 1
                else:
                    self.telemetry_dropped += 1
                await conn.send({"type": "telemetry_ok", "seq": seq,
                                 "accepted": accepted})
            elif kind == "error_report":
                conn.endpoint.queue.report_error(
                    int(msg["ticket_id"]), str(msg.get("error", "")),
                    conn.client)
                await conn.send({"type": "error_report_ok", "seq": seq})
            else:
                self.protocol_errors += 1
                await conn.send_error(seq, ProtocolError(
                    "bad-type", f"unknown message type {kind!r}"))
        except ProtocolError as e:
            self.protocol_errors += 1
            await conn.send_error(seq, e)
        except (ConnectionError, asyncio.IncompleteReadError):
            raise
        except KeyError as e:
            await conn.send_error(seq, ProtocolError(
                "unknown-key", f"no such task/static/field: {e}"))
        except Exception as e:             # never kill the connection on
            await conn.send_error(seq, ProtocolError(  # a handler bug
                "internal", repr(e)))

    async def _handle_lease(self, conn: _Connection, seq):
        # may park until tickets are eligible (or the round is terminal);
        # the client is sequential, so nothing else arrives meanwhile
        batch = await conn.endpoint.lease(conn.client)
        if batch is None:
            await conn.send({"type": "lease_grant", "seq": seq,
                             "done": True})
            return
        conn.leases[batch.lease_id] = batch
        grant = {"type": "lease_grant", "seq": seq, "done": False,
                 **batch.to_wire(encode_payload)}
        if self.tracer is not None and conn.proto >= 2:
            # trace context rides the v2 wire only when a tracer is
            # installed, so untraced traffic stays byte-identical; v1
            # peers never see the field (see docs/PROTOCOL.md)
            grant["trace"] = make_trace_context(lease=batch.lease_id,
                                                client=conn.client)
            self._wire_spans[batch.lease_id] = self.tracer.begin(
                "wire.lease", lane=True, cat="wire",
                track=f"client:{conn.client}",
                ts=conn.endpoint.queue.clock(),
                args={"lease": batch.lease_id, "client": conn.client,
                      "tickets": len(batch.tickets)})
        try:
            await conn.send(grant)
        except (ConnectionError, RuntimeError):
            # granted but undeliverable: hand the tickets straight back
            conn.leases.pop(batch.lease_id, None)
            if self.tracer is not None:
                self.tracer.end(self._wire_spans.pop(batch.lease_id, None),
                                ts=conn.endpoint.queue.clock(),
                                args={"status": "undeliverable"})
            await conn.endpoint.release_lease(batch, client_failed=True)
            raise

    async def _handle_release(self, conn: _Connection, seq, msg: dict):
        client_failed = bool(msg.get("client_failed", False))
        reset_vct = bool(msg.get("reset_vct", True))
        batch = conn.leases.pop(msg["lease_id"], None)
        if batch is not None:
            released = await conn.endpoint.release_lease(
                batch, client_failed=client_failed, reset_vct=reset_vct)
        else:
            released = conn.endpoint.queue.release(
                msg["lease_id"], client_failed=client_failed,
                reset_vct=reset_vct)
            conn.endpoint._notify_waiters()
        if self.tracer is not None:
            self.tracer.end(self._wire_spans.pop(msg["lease_id"], None),
                            ts=conn.endpoint.queue.clock(),
                            args={"status": "released",
                                  "released": released})
        await conn.send({"type": "release_ok", "seq": seq,
                         "released": released})


# ---------------------------------------------------------------------------
# Client
# ---------------------------------------------------------------------------


class ServerBusy(ConnectionError):
    """The server refused our ``hello`` with a ``busy`` frame (admission
    control).  A ConnectionError subclass so the reconnect loop treats it
    as retryable, never fatal; ``retry_after`` carries the server's
    (already-sanitised) backoff hint in seconds."""

    def __init__(self, retry_after: float):
        super().__init__(f"server busy, retry after ~{retry_after:.3g}s")
        self.retry_after = retry_after


def reconnect_backoff(attempt: int, *, base: float, cap: float,
                      rand: Callable[[], float]) -> float:
    """Delay before reconnect ``attempt`` (1-based): capped exponential
    backoff with jitter.

    The undecorated span doubles per attempt from ``base`` up to ``cap``;
    the returned delay is drawn uniformly from the span's upper half
    (``[span/2, span]``), so simultaneous victims of one server drop
    decorrelate (no thundering herd at 10^4 clients) while a positive
    floor still prevents a tight dial loop.  Pure — ``rand`` is injected
    (callers pass a seeded generator; tests pass constants)."""
    span = min(cap, base * (2.0 ** max(0, attempt - 1)))
    return span * (0.5 + 0.5 * rand())


class RemoteBrowserClient(BrowserNodeBase):
    """A simulated browser node that speaks ONLY the wire protocol.

    Holds no reference to any distributor object — just ``(host, port)``
    (``BrowserNodeBase`` state is initialised with ``dist=None``).  Runs
    the same basic-program loop as ``AsyncBrowserClient`` (lease →
    download code/data through a version-aware LRU cache → execute →
    submit), but every step is a framed round-trip; conditional fetches
    and ticket version pins share the in-process merge rule
    (``merge_versioned_fetch``), so PR 3's zero-staleness guarantee holds
    across the serialization boundary by construction.

    **Reconnect with resume** (see docs/PROTOCOL.md §Reconnect): on a
    connection error the client re-dials with capped **exponential
    backoff with jitter** (:func:`reconnect_backoff` — at browser scale,
    a member death drops thousands of connections at once and a linear
    retry schedule re-dials them in lockstep), re-submits any
    finished-but-unsubmitted results under the old lease id (the queue
    accepts late results; duplicates are dropped), and goes back to
    leasing.  Tickets stranded in the dead connection's lease return to
    the queue through heartbeat eviction (when the server runs it) or
    the watchdog — so a dropped connection delays work but never loses
    it.  A ``busy`` refusal (admission control) is retryable the same
    way, honouring the server's jittered ``retry_after`` hint.

    **Heartbeats**: executes longer than ``heartbeat_interval`` are
    chunked, with a ``heartbeat`` round-trip between chunks, so a
    slow-but-alive device holding a lease is never mistaken for a closed
    tab (``None`` disables; the mid-lease fetch round-trips also count
    as liveness server-side).
    """

    def __init__(self, host: str, port: int, profile: ClientProfile, *,
                 max_reconnects: int = 8, reconnect_delay: float = 0.05,
                 backoff_cap: float = 2.0,
                 heartbeat_interval: Optional[float] = 1.0,
                 max_frame_bytes: int = MAX_FRAME_BYTES,
                 max_proto: int = PROTOCOL_VERSION,
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 max_blob_bytes: int = MAX_BLOB_BYTES,
                 tracer=None, metrics=None, telemetry: bool = False,
                 clock: Callable[[], float] = time.monotonic):
        # cache/counters/failure-RNG come from the shared browser base;
        # there is no distributor object on this side of the wire
        self._init_browser(None, profile)
        # optional client-side tracer (in-process tests may share the
        # server's): records client.execute lanes; independent of the
        # trace-context echo, which only needs the server to be tracing
        self.tracer = tracer
        # optional client-LOCAL MetricsRegistry: busy refusals, backoff
        # sleeps, and reconnects land here (the client-side half of the
        # events the server only sees from its side of the wire).  With
        # ``telemetry=True`` on a v2 connection, snapshots of this
        # registry plus the tracer's drained span buffer flush to the
        # server's FleetAggregator, piggybacked on submits/heartbeats.
        # ``clock`` stamps heartbeat echoes for the server's clock-skew
        # estimate — wire the tracer's clock to the SAME callable so
        # shipped span timestamps live in the clock the skew remaps.
        self.metrics = metrics
        self.telemetry = telemetry
        self._clock = clock
        self._last_echo: Optional[dict] = None   # (t0, server_ts, t1)
        self.telemetry_sent = 0            # batches the server accepted
        self.telemetry_refused = 0         # batches it answered accepted=False
        self._m_busy = self._m_reconnects = self._m_backoff = None
        self._m_executed = self._m_heartbeats = None
        if metrics is not None:
            # no labels here: the FleetAggregator injects client= when
            # it merges per-client registries into the fleet snapshot
            self._m_busy = metrics.counter(
                "client.busy_refusals_total",
                "Hellos this client had refused with busy")
            self._m_reconnects = metrics.counter(
                "client.reconnects_total",
                "Reconnect attempts after transport failures")
            self._m_backoff = metrics.histogram(
                "client.backoff_sleep_seconds",
                "Jittered backoff sleeps before re-dialling")
            self._m_executed = metrics.counter(
                "client.executed_total", "Tickets executed")
            self._m_heartbeats = metrics.counter(
                "client.heartbeats_total", "Heartbeat round-trips sent")
        self.host = host
        self.port = port
        self.max_reconnects = max_reconnects
        self.reconnect_delay = reconnect_delay
        self.backoff_cap = backoff_cap
        self.heartbeat_interval = heartbeat_interval
        # backoff jitter draws come from a dedicated per-client RNG (NOT
        # the failure-simulation LCG, whose draw sequence tests pin) and
        # the sleep is injectable, so a backoff schedule is unit-testable
        # against a fake clock
        self._backoff_rand = random.Random(profile.name)
        self._sleep = asyncio.sleep
        self.max_frame_bytes = max_frame_bytes
        #: highest protocol version this client offers in ``hello``; set
        #: to 1 to behave exactly like a pre-v2 (JSON-only) client
        self.max_proto = max_proto
        self.chunk_bytes = chunk_bytes
        self.max_blob_bytes = max_blob_bytes
        self.proto = MIN_PROTOCOL_VERSION  # negotiated at hello time
        self.push_invalidations = 0        # server pushes that hit our cache
        self.reconnects = 0
        self.busy_refusals = 0             # hellos refused with ``busy``
        self.heartbeats_sent = 0
        self.leases_taken = 0
        self.deltas_applied = 0            # v2 delta fetches spliced in
        self.trace_contexts = 0            # grants that carried trace ctx
        # lease_id -> trace echo to attach to the submit (survives a
        # reconnect so a resumed submit still closes the server's span)
        self._trace_echo: dict[int, dict] = {}
        self.bytes_in = 0
        self.bytes_out = 0
        self.member: Optional[int] = None  # endpoint index from hello_ok
        self.done = False
        self._seq = itertools.count(1)
        self._reader: Optional[asyncio.StreamReader] = None
        self._writer: Optional[asyncio.StreamWriter] = None
        self._stopping = False
        # finished-but-unsubmitted results, parked for reconnect-resume:
        # (lease_id, {str(ticket_id): raw result}) or None — encoded per
        # the negotiated protocol only at submit time
        self._pending: Optional[tuple[int, dict]] = None

    # -- wire plumbing --------------------------------------------------------

    async def _connect(self):
        self._reader, self._writer = await asyncio.open_connection(
            self.host, self.port)
        # floor 1 so a v1 server accepts the hello as-is; ``max_proto``
        # advertises how high we can negotiate
        reply = await self._request({"type": "hello",
                                     "client": self.profile.name,
                                     "proto": MIN_PROTOCOL_VERSION,
                                     "max_proto": self.max_proto})
        if reply["type"] == "busy":
            # admission refusal: retryable backpressure, not an error —
            # close our half and surface the (sanitised) retry hint to
            # the reconnect loop
            self.busy_refusals += 1
            retry_after = parse_retry_after(
                reply.get("retry_after"), self.reconnect_delay)
            if self._m_busy is not None:
                self._m_busy.inc()
            if self.tracer is not None:
                self.tracer.instant(
                    "client.busy", cat="client",
                    track=f"client:{self.profile.name}",
                    args={"retry_after": retry_after})
            self._disconnect()
            raise ServerBusy(retry_after)
        proto = reply.get("proto", MIN_PROTOCOL_VERSION)
        if (not isinstance(proto, int) or isinstance(proto, bool)
                or not (MIN_PROTOCOL_VERSION <= proto <= self.max_proto)):
            raise ProtocolError(
                "proto-mismatch",
                f"server negotiated unsupported proto {proto!r}")
        self.proto = proto
        self.member = reply.get("member")

    def _disconnect(self):
        if self._writer is not None:
            try:
                self._writer.close()
            except RuntimeError:
                pass
        self._reader = self._writer = None

    async def _request(self, msg: dict, blob: Optional[bytes] = None
                       ) -> dict:
        """One framed round-trip: send ``msg`` (stamped with a fresh seq),
        return the reply bearing that seq.  A ``blob`` (v2 binary
        payload) is sent as header + chunk frames.  Pushed ``invalidate``
        frames arriving in between are applied inline; an ``error`` reply
        raises :class:`ProtocolError`; a closed stream raises
        ConnectionError (the run loop's reconnect trigger).  Chunked
        replies are reassembled by :func:`read_message` into
        ``reply["_blob"]``."""
        if self._writer is None:
            raise ConnectionResetError("not connected")
        seq = next(self._seq)
        frames = build_blob_frames({**msg, "seq": seq}, blob or b"",
                                   chunk_bytes=self.chunk_bytes,
                                   max_frame_bytes=self.max_frame_bytes)
        for frame in frames:
            self._writer.write(frame)
        await self._writer.drain()
        self.bytes_out += sum(len(f) for f in frames)
        while True:
            reply, n = await read_message(self._reader,
                                          max_bytes=self.max_frame_bytes,
                                          max_blob_bytes=self
                                          .max_blob_bytes)
            if reply is None:
                raise ConnectionResetError("server closed the connection")
            self.bytes_in += n
            if reply["type"] == "invalidate":
                self._apply_invalidate(reply)
                continue
            if reply["type"] == "error":
                # check BEFORE the seq filter: framing errors are sent
                # with seq=null and are fatal either way — skipping them
                # would turn "peer rejected our bytes" into a reconnect
                # loop that re-sends the identical doomed frame
                raise ProtocolError(reply.get("code", "error"),
                                    reply.get("message", ""))
            if reply.get("seq") != seq:
                continue                   # stale pre-reconnect reply
            return reply

    def _apply_invalidate(self, msg: dict):
        """Server push: a registry key was re-published.  Correctness
        never depends on this (ticket pins force revalidation); the push
        just stops us re-validating a copy the origin already knows is
        stale.

        v1 drops the copy outright.  v2 keeps the stale payload but
        voids its validation mark (``validated = -1`` fails every pin,
        including 0), so the next use revalidates conditionally — and the
        kept copy is exactly the **delta base** that lets the server ship
        only the changed leaves instead of a full payload."""
        key = str(msg.get("key"))
        entry = self.cache.pop(key)
        if entry is None:
            return
        self.push_invalidations += 1
        if self.proto >= 2:
            entry.validated = -1
            self.cache.put(key, entry)

    # -- version-aware cache (async mirror of BrowserNodeBase) ---------------

    async def _aget_versioned(self, cache_key: str, fetch,
                              min_version: int):
        """Async twin of ``BrowserNodeBase._get_versioned``: identical
        control flow, with the transport round-trip at the awaits, and
        the subtle merge decision delegated to the SAME pure helpers
        (``merge_versioned_fetch``/``merge_unconditional_fetch``) the
        in-process path uses — a coherence fix lands on both sides of
        the wire at once.  ``fetch(if_version)`` is a coroutine factory;
        ``min_version`` is the ticket's pin."""
        entry = self.cache.get(cache_key)
        if entry is not None and entry.validated >= min_version:
            return entry.value
        got = await fetch(entry.version if entry is not None else None)
        new, revalidated, refetch = merge_versioned_fetch(entry, got,
                                                          min_version)
        if refetch:
            new = merge_unconditional_fetch(await fetch(None), min_version)
        elif got.delta_base is not None:
            self.deltas_applied += 1       # changed leaves spliced in
        if revalidated:
            self.revalidations += 1
        self.cache.put(cache_key, new)
        return new.value

    async def _get_task(self, name: str, min_version: int = 0) -> TaskDef:
        """Task code through the cache; a pin newer than the cached entry
        forces a conditional ``fetch_task`` round-trip."""
        async def fetch(v):
            return _decode_fetch(await self._request(
                {"type": "fetch_task", "name": name, "if_version": v}))
        return await self._aget_versioned(f"task:{name}", fetch, min_version)

    async def _get_static(self, task: TaskDef, min_version: int) -> dict:
        """The task's statics through the cache, same revalidation rule.
        On a v2 connection a conditional fetch also asks for a **delta**
        (changed leaves relative to our cached version); the shared merge
        helper splices it in, or falls back to a full refetch when the
        base no longer matches."""
        out = {}
        for key in task.static_files:
            async def fetch(v, k=key):
                req = {"type": "fetch_static", "key": k, "if_version": v}
                if v is not None and self.proto >= 2:
                    req["delta"] = True
                return _decode_fetch(await self._request(req))
            out[key] = await self._aget_versioned(f"static:{key}", fetch,
                                                  min_version)
        return out

    # -- the basic-program loop ----------------------------------------------

    async def run(self):
        """Connect → lease → download → execute → submit, reconnecting on
        transport failure, until the server reports the work done (or the
        profile says the tab closes)."""
        failures = 0
        try:
            while not self._stopping:
                try:
                    if self._writer is None:
                        await self._connect()
                        failures = 0
                    if self._pending is not None:
                        # resume: re-submit results finished before the
                        # drop under their old lease id (dupes are fine)
                        lease_id, results = self._pending
                        await self._submit_results(lease_id, results)
                        self._pending = None
                    if not await self._one_lease():
                        break
                except ProtocolError:
                    raise                  # a peer speaking garbage is fatal
                except (ConnectionError, asyncio.IncompleteReadError,
                        OSError) as e:
                    self._disconnect()
                    if self._stopping:
                        break
                    failures += 1
                    if failures > self.max_reconnects:
                        raise ConnectionError(
                            f"{self.profile.name}: gave up after "
                            f"{self.max_reconnects} reconnects") from e
                    self.reconnects += 1
                    if self._m_reconnects is not None:
                        self._m_reconnects.inc()
                    if self.tracer is not None:
                        self.tracer.instant(
                            "client.reconnect", cat="client",
                            track=f"client:{self.profile.name}",
                            args={"attempt": failures,
                                  "busy": isinstance(e, ServerBusy)})
                    delay = reconnect_backoff(
                        failures, base=self.reconnect_delay,
                        cap=self.backoff_cap,
                        rand=self._backoff_rand.random)
                    if isinstance(e, ServerBusy):
                        # a busy server set the floor: honour its hint,
                        # jittered so refused clients don't re-dial as
                        # one synchronized wave
                        delay = max(delay, e.retry_after
                                    * (0.5 + 0.5
                                       * self._backoff_rand.random()))
                    if self._m_backoff is not None:
                        self._m_backoff.observe(delay)
                    if self.tracer is not None:
                        self.tracer.instant(
                            "client.backoff", cat="client",
                            track=f"client:{self.profile.name}",
                            args={"delay_s": delay})
                    await self._sleep(delay)
        finally:
            self.done = True
            self._disconnect()

    async def _submit_results(self, lease_id: int, results: dict) -> dict:
        """Submit a lease's results: v2 sends the whole dict as one
        binary blob (raw array buffers, no pickle+base64); v1 sends the
        per-ticket pickled-base64 form.  ``results`` maps str(ticket_id)
        to the RAW result object either way, so a reconnect that
        renegotiates the protocol re-encodes correctly on resume."""
        # echo trace context only when the grant carried it (server is
        # tracing, v2): untraced and v1 submits stay byte-identical.
        # Kept until the submit actually lands, so a resumed re-submit
        # after a reconnect still closes the server's wire span.
        extra = {}
        echo = self._trace_echo.get(lease_id)
        if echo is not None:
            extra["trace"] = echo
        if self.proto >= 2:
            manifest, buffer = encode_binary(results)
            reply = await self._request(
                {"type": "submit", "lease_id": lease_id,
                 "encoding": "bin", "manifest": manifest, **extra},
                blob=buffer)
        else:
            reply = await self._request(
                {"type": "submit", "lease_id": lease_id,
                 "results": {tid: encode_payload(r)
                             for tid, r in results.items()}, **extra})
        self._trace_echo.pop(lease_id, None)
        return reply

    async def _heartbeat(self, lease_id: Optional[int] = None):
        """One liveness round-trip; any frame refreshes the server's
        silence clock.  On a v2 connection to a fleet-plane server each
        exchange also advances the clock-skew protocol: the previous
        exchange's ``(t0, server_ts, t1)`` echo rides out, and this
        reply's ``server_ts`` (when present) seeds the next one.  A
        heartbeat is also a telemetry flush trigger."""
        msg: dict[str, Any] = {"type": "heartbeat"}
        if lease_id is not None:
            msg["lease_id"] = lease_id     # advisory, for log correlation
        if self.proto >= 2 and self._last_echo is not None:
            msg["echo"] = self._last_echo
            self._last_echo = None
        t0 = self._clock()
        reply = await self._request(msg)
        self.heartbeats_sent += 1
        if self._m_heartbeats is not None:
            self._m_heartbeats.inc()
        sts = reply.get("server_ts")
        if (self.proto >= 2 and isinstance(sts, (int, float))
                and not isinstance(sts, bool)):
            self._last_echo = make_clock_echo(t0, sts, self._clock())
        await self._flush_telemetry()

    async def _flush_telemetry(self):
        """Ship buffered observability to the server's FleetAggregator:
        the local registry snapshot plus the tracer's drained span
        buffer, as one ``telemetry`` frame.  No-op unless this client
        was built with ``telemetry=True`` and negotiated v2, or when
        there is nothing to send.  The server may still refuse
        (``accepted: false`` — no fleet aggregator armed); that costs
        this batch its spans (already drained) and is counted."""
        if not self.telemetry or self.proto < 2:
            return
        spans = self.tracer.drain() if self.tracer is not None else []
        metrics = None
        if self.metrics is not None:
            if self._m_executed is not None:
                self._m_executed.set_total(self.executed)
            metrics = self.metrics.snapshot()
        if not spans and not metrics:
            return
        dropped = (self.tracer.events_dropped
                   if self.tracer is not None else 0)
        reply = await self._request(
            {"type": "telemetry",
             "telemetry": make_telemetry(metrics, spans,
                                         dropped=dropped)})
        if reply.get("accepted"):
            self.telemetry_sent += 1
        else:
            self.telemetry_refused += 1

    async def _paced_sleep(self, seconds: float,
                           lease_id: Optional[int] = None):
        """Sleep (simulated compute / network latency) while holding a
        lease: stretches longer than ``heartbeat_interval`` are chunked
        with a heartbeat between chunks, so the eviction sweeper can tell
        *slow* from *gone*."""
        hb = self.heartbeat_interval
        while hb is not None and seconds > hb:
            await asyncio.sleep(hb)
            seconds -= hb
            await self._heartbeat(lease_id)
        if seconds > 0:
            await asyncio.sleep(seconds)

    async def _one_lease(self) -> bool:
        """One lease round; returns False when the server says the work is
        done (client exits).  Finished-but-unsubmitted results are parked
        in ``_pending`` so a reconnect can resume them."""
        self._pending = None
        reply = await self._request({"type": "lease_request"})
        if reply["type"] != "lease_grant":
            raise ProtocolError("bad-reply",
                                f"expected lease_grant, got {reply['type']}")
        if reply.get("done"):
            return False
        batch = LeaseBatch.from_wire(reply, decode_payload)
        ctx = parse_trace_context(reply.get("trace"))
        if ctx is not None:
            self.trace_contexts += 1
        self.leases_taken += 1
        if self.profile.latency:
            await self._paced_sleep(self.profile.latency, batch.lease_id)
        if (self.profile.die_after is not None
                and self.leases_taken > self.profile.die_after):
            # tab closed mid-lease: hand the tickets straight back
            await self._request({"type": "release",
                                 "lease_id": batch.lease_id,
                                 "client_failed": True})
            self._stopping = True
            return False
        results: dict[str, Any] = {}       # str(tid) -> raw result object
        failed = False
        tr = self.tracer
        exec_span = None
        t0 = time.monotonic() if (ctx is not None or tr is not None) else 0.0
        if tr is not None:
            exec_span = tr.begin("client.execute", lane=True, cat="client",
                                 track=f"client:{self.profile.name}",
                                 args={"lease": batch.lease_id,
                                       "tickets": len(batch.tickets)})
        try:
            for ticket in batch.tickets:
                try:
                    task = await self._get_task(ticket.task_name,
                                                ticket.task_version)
                    static = await self._get_static(task,
                                                    ticket.task_version)
                    if (self.profile.fail_prob
                            and self._rand() < self.profile.fail_prob):
                        raise RuntimeError("simulated browser crash in "
                                           f"{ticket.task_name}")
                    if self.profile.speed > 0:
                        await self._paced_sleep(
                            ticket.work / self.profile.speed,
                            batch.lease_id)
                    results[str(ticket.ticket_id)] = task.run(ticket.args,
                                                              static)
                    self.executed += 1
                except (ConnectionError, asyncio.IncompleteReadError,
                        OSError, ProtocolError):
                    # transport failure mid-lease: park what we finished
                    # so the reconnect path can resume-submit it
                    self._pending = (batch.lease_id, results)
                    raise
                except Exception:
                    self.errors += 1
                    # park BEFORE the report round-trip: if the connection
                    # drops during it, the finished results must still
                    # ride the reconnect-resume path
                    self._pending = (batch.lease_id, results)
                    await self._request({"type": "error_report",
                                         "ticket_id": ticket.ticket_id,
                                         "error": traceback.format_exc()})
                    self._pending = None
                    self._reload()         # paper: reload browser
                    failed = True
        finally:
            if tr is not None:
                tr.end(exec_span, args={"executed": len(results),
                                        "failed": failed})
        if ctx is not None:
            self._trace_echo[batch.lease_id] = make_trace_context(
                lease=batch.lease_id, client=self.profile.name,
                exec_s=time.monotonic() - t0)
        self._pending = (batch.lease_id, results)
        await self._submit_results(batch.lease_id, results)
        self._pending = None
        await self._flush_telemetry()      # submit is a flush trigger too
        if failed:
            # drop the lease bookkeeping for the errored tickets but keep
            # their cool-down (paper behaviour; mirrors AsyncBrowserClient)
            await self._request({"type": "release",
                                 "lease_id": batch.lease_id,
                                 "reset_vct": False})
        return True

    async def stop(self):
        """Ask the client to exit; drops the connection so a parked
        lease_request unblocks immediately."""
        self._stopping = True
        self._disconnect()


def spawn_remote_clients(address: tuple[str, int], profiles, **kw
                         ) -> tuple[list[RemoteBrowserClient],
                                    list[asyncio.Task]]:
    """Create and start one :class:`RemoteBrowserClient` task per profile
    (must be called with an event loop running).  Returns
    ``(clients, tasks)`` — await the tasks to join the clients."""
    loop = asyncio.get_running_loop()
    clients = [RemoteBrowserClient(address[0], address[1], p, **kw)
               for p in profiles]
    tasks = [loop.create_task(c.run()) for c in clients]
    return clients, tasks
