"""The CalculationFramework: the paper's Project / Task programming model.

Python rendering of the paper's Appendix API:

    class IsPrimeTask(TaskBase):
        static_code_files = ["is_prime"]
        def run(self, input, static):
            return {"is_prime": static["is_prime"](input["candidate"])}

    class PrimeListMakerProject(ProjectBase):
        name = "PrimeListMakerProject"
        def run(self):
            task = self.create_task(IsPrimeTask)
            task.calculate([{"candidate": i} for i in range(1, 10001)])
            task.block(lambda results: ...)

Results arrive ordered by input index, "as if they were processed by the
local machine".
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence

from repro.core.distributor import Distributor, TaskDef


class TaskBase:
    """Subclass and override ``run``; list dataset keys in static_code_files."""

    static_code_files: Sequence[str] = ()

    @classmethod
    def task_name(cls) -> str:
        """Queue-visible task identifier (defaults to the class name)."""
        return cls.__name__

    def run(self, input: Any, static: dict) -> Any:  # noqa: A002
        """Execute one ticket's worth of work on a client (override)."""
        raise NotImplementedError


class TaskHandle:
    """A registered task plus the ticket ids of its in-flight inputs
    (returned by :meth:`CalculationFramework`-driven ``create_task``)."""

    def __init__(self, framework: "CalculationFramework", task_cls):
        self.framework = framework
        self.task_cls = task_cls
        self._ticket_ids: list[int] = []
        inst = task_cls()
        self.framework.distributor.register_task(TaskDef(
            name=task_cls.task_name(),
            run=inst.run,
            static_files=tuple(task_cls.static_code_files),
        ))

    def calculate(self, inputs: Sequence[Any]):
        """Divide the arguments into tickets and enqueue them (paper
        §2.1.1).  Goes through the distributor so tickets pin the task's
        registry coherence version (re-registering a task mid-run then
        invalidates browser caches via the pins)."""
        self._ticket_ids = self.framework.distributor.add_work(
            self.task_cls.task_name(), inputs)

    def block(self, callback: Optional[Callable] = None,
              timeout: Optional[float] = None):
        """Wait for all of THIS task's tickets; returns results ordered
        like the inputs ("as if processed by the local machine"), passing
        them to ``callback`` first when given.  Raises TimeoutError with
        the console snapshot if ``timeout`` elapses.  Uses the queue's
        O(round) ``results_for`` rather than copying the whole results
        table, so long-running multi-task projects don't pay for history."""
        ok = self.framework.distributor.queue.wait_all(timeout)
        if not ok:
            raise TimeoutError(
                f"tickets unfinished: {self.framework.distributor.console()}")
        ordered = self.framework.distributor.queue.results_for(
            self._ticket_ids)
        if ordered is None:       # wait_all raced a concurrent producer
            raise TimeoutError(
                f"tickets unfinished: {self.framework.distributor.console()}")
        if callback is not None:
            callback(ordered)
        return ordered


class ProjectBase:
    """Subclass and override :meth:`run`; orchestrates Tasks (paper
    appendix: ``PrimeListMakerProject``)."""

    name = "Project"

    def __init__(self, framework: "CalculationFramework"):
        self.framework = framework

    def create_task(self, task_cls) -> TaskHandle:
        """Register ``task_cls`` with the distributor and hand back its
        handle for ``calculate`` / ``block``."""
        return TaskHandle(self.framework, task_cls)

    def run(self):
        """Project entry point: create tasks, calculate, block (override)."""
        raise NotImplementedError


@dataclass
class CalculationFramework:
    """The paper's top-level object: couples a project to a Distributor
    and its HTTPServer-style static store."""

    distributor: Distributor

    def add_static(self, key: str, value: Any):
        """Publish a dataset/helper on the HTTPServer (versioned: a
        re-publish bumps the key and invalidates caches)."""
        self.distributor.add_static(key, value)

    def run_project(self, project_cls, *args, **kwargs):
        """Instantiate (if needed) and run a project; returns its result."""
        project = project_cls(self, *args, **kwargs) if not isinstance(
            project_cls, ProjectBase) else project_cls
        self.distributor.project_name = getattr(project, "name",
                                                project.__class__.__name__)
        return project.run()
