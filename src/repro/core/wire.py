"""Wire-level codecs shared by the transport and the registry (protocol v2).

This module is a *leaf*: it imports nothing from :mod:`repro`, so both
:mod:`repro.core.transport` (the framing layer) and
:mod:`repro.core.distributor` (the versioned registry) can use it without
creating an import cycle.  It provides the three building blocks of wire
protocol v2 (see ``docs/PROTOCOL.md``):

* :class:`ProtocolError` — the one exception type every decoder raises.
  Historically defined in ``transport.py``; it lives here now and is
  re-exported there for compatibility.
* The **binary payload codec** (:func:`encode_binary` /
  :func:`decode_binary`): splits an arbitrary pytree into (a) a compact
  JSON-safe *manifest* describing each array leaf (dtype, shape, nbytes)
  plus a pickled skeleton for the non-array residue, and (b) one
  contiguous byte buffer holding the raw array data.  Array payloads
  cross the wire with zero pickle framing and zero base64 expansion.
* The **delta helpers** (:func:`flatten_tree`, :func:`leaf_equal`,
  :func:`apply_delta`): path-addressed leaf flattening used by the
  registry to stamp per-leaf versions and by clients to splice a
  changed-leaves delta into their cached full payload.

Decoding is adversarial-input territory (anonymous browsers connect to
the distributor), so every validation failure raises
:class:`ProtocolError` with a documented code and decoding never
allocates based on unchecked size fields: array extents are checked
against the actual buffer length before any array is materialized.
"""
from __future__ import annotations

import base64
import dataclasses
import pickle
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

try:  # registers the "bfloat16" (etc.) dtype names with numpy
    import ml_dtypes  # noqa: F401
except ImportError:  # pragma: no cover - present wherever jax is
    ml_dtypes = None

__all__ = [
    "ProtocolError", "DeltaApplyError",
    "encode_binary", "decode_binary",
    "flatten_tree", "leaf_equal", "apply_delta",
    "TRACE_CONTEXT_FIELDS", "make_trace_context", "parse_trace_context",
    "MAX_RETRY_AFTER_S", "parse_retry_after",
    "MAX_TELEMETRY_SPANS", "MAX_TELEMETRY_SERIES",
    "make_telemetry", "parse_telemetry",
    "make_clock_echo", "parse_clock_echo",
]

#: The optional ``trace`` object carried by ``lease_grant`` and ``submit``
#: frames (protocol v2, emitted only when the sender has a tracer; spec in
#: docs/PROTOCOL.md §Trace context).  Field -> accepted wire types.  v1
#: peers never see the field; tolerant parsers on both sides ignore it.
TRACE_CONTEXT_FIELDS: Dict[str, tuple] = {
    "lease": (int,),          # lease id the context rides on
    "client": (str,),         # client name (echoed on submit)
    "round": (int, str),      # training-round tag, when a trainer set one
    "exec_s": (int, float),   # client-measured execute time (submit echo)
}


def make_trace_context(**fields) -> Dict[str, Any]:
    """Build a wire ``trace`` object from the known fields (None values
    are dropped).  Unknown field names are a programming error and raise —
    the *parser* is the tolerant side, not the builder."""
    out: Dict[str, Any] = {}
    for k, v in fields.items():
        if k not in TRACE_CONTEXT_FIELDS:
            raise ValueError(f"unknown trace-context field {k!r}")
        if v is None:
            continue
        out[k] = v
    return out


def parse_trace_context(obj: Any) -> Optional[Dict[str, Any]]:
    """Tolerantly parse a peer's ``trace`` object: returns the recognised,
    correctly-typed fields, or None when ``obj`` is absent or not an
    object.  Never raises — trace context is observability metadata from
    an untrusted peer and must not be able to poison a connection (the
    fuzz tests drive junk through here)."""
    if not isinstance(obj, dict):
        return None
    out: Dict[str, Any] = {}
    for k, types in TRACE_CONTEXT_FIELDS.items():
        v = obj.get(k)
        if isinstance(v, types) and not isinstance(v, bool):
            out[k] = v
    return out


#: Ceiling on the ``retry_after`` hint a peer may impose via a ``busy``
#: refusal — an adversarial (or buggy) server must not be able to park a
#: client for an hour with one frame.
MAX_RETRY_AFTER_S = 60.0


def parse_retry_after(value: Any, default: float,
                      *, cap: float = MAX_RETRY_AFTER_S) -> float:
    """Tolerantly parse a ``busy`` refusal's ``retry_after`` hint (seconds).

    Same adversarial-input posture as :func:`parse_trace_context`: the
    hint comes from an untrusted peer, so anything that is not a finite
    non-negative real — missing, a bool, a string, NaN, negative —
    falls back to ``default``, and a sane value is clamped to ``cap``.
    Never raises."""
    if isinstance(value, bool) or not isinstance(value, (int, float)):
        return default
    v = float(value)
    if v != v or v < 0.0:                  # NaN or negative
        return default
    return min(v, cap)


# -- telemetry batches (protocol v2, docs/PROTOCOL.md §telemetry) ----------

#: ceilings on what a single ``telemetry`` frame may carry — an
#: adversarial client must not be able to make the server buffer an
#: unbounded span list or metric registry.  Excess entries are dropped
#: (and counted), never an error.
MAX_TELEMETRY_SPANS = 512
MAX_TELEMETRY_SERIES = 256

_SPAN_PHASES = ("X", "b", "e", "i")
_METRIC_KINDS = ("counter", "gauge", "histogram")


def _finite_num(v: Any) -> bool:
    return (isinstance(v, (int, float)) and not isinstance(v, bool)
            and v == v and v not in (float("inf"), float("-inf")))


def make_telemetry(metrics: Optional[dict], spans: Optional[List[dict]],
                   *, dropped: int = 0) -> Dict[str, Any]:
    """Build the ``telemetry`` payload object: a client's local
    ``MetricsRegistry.snapshot()`` plus a batch of drained tracer events
    (the decoded dict schema of ``Tracer.events()``), and the client's
    own cumulative drop count (ring-buffer evictions + flush drops).
    Builder side is strict by convention but has nothing to validate
    beyond shape — the *parser* is the tolerant side."""
    out: Dict[str, Any] = {"dropped": int(dropped)}
    if metrics:
        out["metrics"] = metrics
    if spans:
        out["spans"] = list(spans)
    return out


def parse_telemetry(obj: Any, *, max_spans: int = MAX_TELEMETRY_SPANS,
                    max_series: int = MAX_TELEMETRY_SERIES
                    ) -> Optional[Dict[str, Any]]:
    """Tolerantly parse a peer's ``telemetry`` payload.

    Returns ``{"metrics", "spans", "dropped", "local_drops"}`` where
    ``metrics`` holds only well-formed series (str name -> dict body
    with a known ``kind`` and a list of ``values``), ``spans`` only
    well-formed trace events (str name/track/cat, known ``ph``, finite
    ``ts``; ``dur``/``id``/``args`` sanitized), ``dropped`` is the
    peer's self-reported drop count, and ``local_drops`` counts every
    entry *this* parser discarded (malformed or over the caps).
    Returns None when ``obj`` is not an object at all.  Never raises —
    telemetry is observability metadata from an untrusted peer and a
    garbage batch must cost the sender its data, not the server its
    connection (the fuzz tests drive junk through here)."""
    if not isinstance(obj, dict):
        return None
    local_drops = 0

    metrics: Dict[str, Any] = {}
    raw_metrics = obj.get("metrics")
    if isinstance(raw_metrics, dict):
        for name in sorted(raw_metrics, key=str):
            body = raw_metrics[name]
            if (not isinstance(name, str) or not isinstance(body, dict)
                    or body.get("kind") not in _METRIC_KINDS
                    or not isinstance(body.get("values"), list)):
                local_drops += 1
                continue
            if len(metrics) >= max_series:
                local_drops += 1
                continue
            metrics[name] = {"kind": body["kind"],
                             "help": body.get("help", "")
                             if isinstance(body.get("help"), str) else "",
                             "values": body["values"]}
    elif raw_metrics is not None:
        local_drops += 1

    spans: List[Dict[str, Any]] = []
    raw_spans = obj.get("spans")
    if isinstance(raw_spans, list):
        for ev in raw_spans:
            if (not isinstance(ev, dict)
                    or not isinstance(ev.get("name"), str)
                    or not isinstance(ev.get("track"), str)
                    or ev.get("ph") not in _SPAN_PHASES
                    or not _finite_num(ev.get("ts"))):
                local_drops += 1
                continue
            if len(spans) >= max_spans:
                local_drops += 1
                continue
            clean: Dict[str, Any] = {
                "name": ev["name"], "ph": ev["ph"],
                "track": ev["track"],
                "cat": ev["cat"] if isinstance(ev.get("cat"), str)
                else "client",
                "ts": float(ev["ts"]),
            }
            if ev["ph"] == "X":
                dur = ev.get("dur")
                clean["dur"] = (float(dur)
                                if _finite_num(dur) and dur >= 0 else 0.0)
            elif ev["ph"] in ("b", "e"):
                sid = ev.get("id")
                if isinstance(sid, bool) or not isinstance(sid, int):
                    local_drops += 1
                    continue
                clean["id"] = sid
            if isinstance(ev.get("args"), dict):
                clean["args"] = ev["args"]
            spans.append(clean)
    elif raw_spans is not None:
        local_drops += 1

    dropped = obj.get("dropped")
    if not (isinstance(dropped, int) and not isinstance(dropped, bool)
            and dropped >= 0):
        dropped = 0
    return {"metrics": metrics, "spans": spans, "dropped": dropped,
            "local_drops": local_drops}


def make_clock_echo(t0: float, server_ts: float,
                    t1: float) -> Dict[str, float]:
    """Build the heartbeat ``echo`` object a client sends back after a
    ``heartbeat_ok`` carrying ``server_ts``: its own send time ``t0``,
    the server's stamp, and its receive time ``t1`` (all in the
    sender's respective clocks).  The server turns one echo into a
    clock-skew sample: ``offset = server_ts - (t0 + t1) / 2`` with
    uncertainty ``rtt = t1 - t0`` (NTP's symmetric-delay estimate)."""
    return {"t0": float(t0), "server_ts": float(server_ts),
            "t1": float(t1)}


def parse_clock_echo(obj: Any) -> Optional[Tuple[float, float, float]]:
    """Tolerantly parse a heartbeat ``echo`` object into
    ``(t0, server_ts, t1)``.  Returns None — never raises — unless all
    three fields are finite numbers with ``t1 >= t0`` (a negative RTT
    is necessarily garbage)."""
    if not isinstance(obj, dict):
        return None
    t0, sts, t1 = obj.get("t0"), obj.get("server_ts"), obj.get("t1")
    if not (_finite_num(t0) and _finite_num(sts) and _finite_num(t1)):
        return None
    if t1 < t0:
        return None
    return (float(t0), float(sts), float(t1))


#: hard ceiling on manifest array count (a manifest is decoded before its
#: buffer, so the count must be bounded independently of the data).
MAX_MANIFEST_ARRAYS = 1 << 16
#: hard ceiling on array rank accepted from the wire.
MAX_MANIFEST_NDIM = 32


class ProtocolError(Exception):
    """A wire-protocol violation.  ``code`` is a short machine-readable
    string from the table in docs/PROTOCOL.md; ``message`` is free text."""

    def __init__(self, code: str, message: str):
        super().__init__(f"{code}: {message}")
        self.code = code
        self.message = message


class DeltaApplyError(Exception):
    """A delta payload does not fit the base tree it claims to patch.

    Raised by :func:`apply_delta`; clients treat it as a cache miss and
    refetch the full payload rather than failing the fetch."""


# --------------------------------------------------------------------------
# binary payload codec
# --------------------------------------------------------------------------


class _ArrayRef:
    """Placeholder left in the pickled skeleton where an array leaf was
    extracted; ``index`` points into the manifest's array table."""

    __slots__ = ("index",)

    def __init__(self, index: int):
        self.index = index

    def __reduce__(self):
        return (_ArrayRef, (self.index,))


def _is_array_leaf(x: Any) -> bool:
    """True for numpy/jax array objects (not scalars, not lists)."""
    if isinstance(x, np.ndarray):
        return True
    if isinstance(x, np.generic):  # 0-d numpy scalar: pickle round-trips type
        return False
    return (hasattr(x, "__array__") and hasattr(x, "dtype")
            and hasattr(x, "shape") and hasattr(x, "ndim"))


def encode_binary(obj: Any) -> Tuple[Dict[str, Any], bytes]:
    """Split ``obj`` into a JSON-safe manifest and a raw byte buffer.

    Array leaves (numpy or jax, any dtype including bfloat16) are pulled
    out into ``buffer`` back-to-back in C order; everything else is
    pickled with :class:`_ArrayRef` placeholders and carried base64 in
    ``manifest["rest"]``.  ``decode_binary(manifest, buffer)`` inverts
    this bit-exactly."""
    arrays: List[np.ndarray] = []

    def extract(x):
        if _is_array_leaf(x):
            a = np.asarray(x)
            # ascontiguousarray alone would promote 0-d to (1,)
            arrays.append(np.ascontiguousarray(a).reshape(a.shape))
            return _ArrayRef(len(arrays) - 1)
        if isinstance(x, dict):
            return {k: extract(v) for k, v in x.items()}
        if isinstance(x, list):
            return [extract(v) for v in x]
        if isinstance(x, tuple):
            return tuple(extract(v) for v in x)
        if dataclasses.is_dataclass(x) and not isinstance(x, type):
            try:
                fields = {f.name: extract(getattr(x, f.name))
                          for f in dataclasses.fields(x) if f.init}
                return dataclasses.replace(x, **fields)
            except TypeError:
                return x  # exotic dataclass: fall back to whole-object pickle
        return x

    skeleton = extract(obj)
    manifest = {
        "arrays": [{"dtype": a.dtype.name, "shape": list(a.shape),
                    "nbytes": int(a.nbytes)} for a in arrays],
        "rest": base64.b64encode(
            pickle.dumps(skeleton, protocol=pickle.HIGHEST_PROTOCOL)
        ).decode("ascii"),
    }
    return manifest, b"".join(a.tobytes() for a in arrays)


def _resolve_dtype(name: Any) -> np.dtype:
    if not isinstance(name, str):
        raise ProtocolError("bad-manifest", f"dtype must be a string, "
                            f"got {type(name).__name__}")
    try:
        dt = np.dtype(name)
    except TypeError:
        dt = None
    if dt is None and ml_dtypes is not None:
        scalar = getattr(ml_dtypes, name, None)
        if scalar is not None:
            try:
                dt = np.dtype(scalar)
            except TypeError:
                dt = None
    if dt is None:
        raise ProtocolError("bad-manifest", f"unknown dtype {name!r}")
    if dt.hasobject:
        raise ProtocolError("bad-manifest",
                            f"object dtype {name!r} not allowed on the wire")
    return dt


def decode_binary(manifest: Any, buffer: bytes) -> Any:
    """Inverse of :func:`encode_binary`; validates everything.

    Every malformed-manifest condition (wrong types, unknown or object
    dtype, shape/nbytes mismatch, extents past the end of ``buffer``,
    dangling array references, un-unpicklable skeleton) raises
    ``ProtocolError("bad-manifest")``.  No array is allocated before its
    extent has been checked against ``len(buffer)``."""
    if not isinstance(manifest, dict):
        raise ProtocolError("bad-manifest", "manifest must be an object")
    entries = manifest.get("arrays")
    rest = manifest.get("rest")
    if not isinstance(entries, list) or not isinstance(rest, str):
        raise ProtocolError("bad-manifest",
                            "manifest needs 'arrays' list and 'rest' string")
    if len(entries) > MAX_MANIFEST_ARRAYS:
        raise ProtocolError("bad-manifest",
                            f"{len(entries)} arrays exceeds cap "
                            f"{MAX_MANIFEST_ARRAYS}")
    arrays: List[np.ndarray] = []
    offset = 0
    for i, entry in enumerate(entries):
        if not isinstance(entry, dict):
            raise ProtocolError("bad-manifest", f"array {i}: not an object")
        dt = _resolve_dtype(entry.get("dtype"))
        shape = entry.get("shape")
        nbytes = entry.get("nbytes")
        if (not isinstance(shape, list) or len(shape) > MAX_MANIFEST_NDIM
                or not all(isinstance(s, int) and not isinstance(s, bool)
                           and s >= 0 for s in shape)):
            raise ProtocolError("bad-manifest", f"array {i}: bad shape "
                                f"{shape!r}")
        count = 1
        for s in shape:
            count *= s
        if (not isinstance(nbytes, int) or isinstance(nbytes, bool)
                or nbytes != count * dt.itemsize):
            raise ProtocolError("bad-manifest",
                                f"array {i}: nbytes {nbytes!r} != "
                                f"prod(shape)*itemsize "
                                f"({count * dt.itemsize})")
        if offset + nbytes > len(buffer):
            raise ProtocolError("bad-manifest",
                                f"array {i}: extent [{offset}, "
                                f"{offset + nbytes}) past end of "
                                f"{len(buffer)}-byte buffer")
        arr = np.frombuffer(buffer, dtype=dt, count=count,
                            offset=offset).reshape(tuple(shape)).copy()
        arrays.append(arr)
        offset += nbytes
    if offset != len(buffer):
        raise ProtocolError("bad-manifest",
                            f"{len(buffer) - offset} trailing bytes after "
                            f"last declared array")
    try:
        skeleton = pickle.loads(base64.b64decode(rest, validate=True))
    except Exception as exc:
        raise ProtocolError("bad-manifest",
                            f"skeleton does not unpickle: {exc}") from None

    def restore(x):
        if isinstance(x, _ArrayRef):
            if not (0 <= x.index < len(arrays)):
                raise ProtocolError("bad-manifest",
                                    f"dangling array ref {x.index}")
            return arrays[x.index]
        if isinstance(x, dict):
            return {k: restore(v) for k, v in x.items()}
        if isinstance(x, list):
            return [restore(v) for v in x]
        if isinstance(x, tuple):
            return tuple(restore(v) for v in x)
        if dataclasses.is_dataclass(x) and not isinstance(x, type):
            try:
                fields = {f.name: restore(getattr(x, f.name))
                          for f in dataclasses.fields(x) if f.init}
                return dataclasses.replace(x, **fields)
            except TypeError:
                return x
        return x

    return restore(skeleton)


# --------------------------------------------------------------------------
# delta helpers: path-addressed leaf flattening
# --------------------------------------------------------------------------
#
# Paths are tuples of (tag, key) steps so they survive pickling inside a
# delta payload and never collide the way "/"-joined strings can:
#   (0, key)   dict entry
#   (1, i)     list element
#   (2, i)     tuple element
#   (3, name)  dataclass field

_DICT, _LIST, _TUPLE, _FIELD = 0, 1, 2, 3


def flatten_tree(tree: Any) -> Dict[tuple, Any]:
    """Map each leaf of ``tree`` to its path.  Containers (dict, list,
    tuple, dataclass) are traversed; everything else — arrays included —
    is a leaf.  Leaves are the *same objects* as in ``tree`` (no copy)."""
    out: Dict[tuple, Any] = {}

    def walk(x, path):
        if isinstance(x, dict):
            for k, v in x.items():
                walk(v, path + ((_DICT, k),))
        elif isinstance(x, list):
            for i, v in enumerate(x):
                walk(v, path + ((_LIST, i),))
        elif isinstance(x, tuple):
            for i, v in enumerate(x):
                walk(v, path + ((_TUPLE, i),))
        elif dataclasses.is_dataclass(x) and not isinstance(x, type):
            for f in dataclasses.fields(x):
                walk(getattr(x, f.name), path + ((_FIELD, f.name),))
        else:
            out[path] = x

    walk(tree, ())
    return out


def leaf_equal(a: Any, b: Any) -> bool:
    """Bit-exact leaf equality: arrays compare dtype + shape + raw bytes,
    scalars compare type *and* value (so ``1`` != ``1.0`` — a delta that
    skips a leaf must leave the client holding the identical object)."""
    a_is_arr = _is_array_leaf(a)
    if a_is_arr != _is_array_leaf(b):
        return False
    if a_is_arr:
        aa, bb = np.asarray(a), np.asarray(b)
        if aa.dtype != bb.dtype or aa.shape != bb.shape:
            return False
        try:  # byte view: bit-exact (NaN == NaN) without a tobytes() copy
            return bool(np.array_equal(aa.view(np.uint8),
                                       bb.view(np.uint8)))
        except (ValueError, TypeError):  # non-contiguous / 0-d views
            return aa.tobytes() == bb.tobytes()
    if type(a) is not type(b):
        return False
    try:
        return bool(a == b)
    except Exception:
        return False


def apply_delta(base: Any, changed: Dict[tuple, Any]) -> Any:
    """Return a copy of ``base`` with each ``path -> leaf`` spliced in.

    Copy-on-write: only containers on a changed path are rebuilt;
    untouched subtrees are shared with ``base``.  Raises
    :class:`DeltaApplyError` if a path does not exist in ``base`` with
    the expected container types (the registry never serves a delta
    across a structure change, so this only fires on corrupt input)."""
    for path, leaf in changed.items():
        base = _set_path(base, path, leaf)
    return base


def _set_path(node: Any, path: tuple, leaf: Any) -> Any:
    if not path:
        return leaf
    (tag, key), rest = path[0], path[1:]
    if tag == _DICT and isinstance(node, dict):
        if key not in node:
            raise DeltaApplyError(f"missing dict key {key!r}")
        out = dict(node)
        out[key] = _set_path(node[key], rest, leaf)
        return out
    if tag == _LIST and isinstance(node, list):
        if not (isinstance(key, int) and 0 <= key < len(node)):
            raise DeltaApplyError(f"list index {key!r} out of range")
        out = list(node)
        out[key] = _set_path(node[key], rest, leaf)
        return out
    if tag == _TUPLE and isinstance(node, tuple):
        if not (isinstance(key, int) and 0 <= key < len(node)):
            raise DeltaApplyError(f"tuple index {key!r} out of range")
        items = list(node)
        items[key] = _set_path(node[key], rest, leaf)
        return tuple(items)
    if tag == _FIELD and dataclasses.is_dataclass(node) \
            and not isinstance(node, type):
        names = {f.name for f in dataclasses.fields(node) if f.init}
        if key not in names:
            raise DeltaApplyError(f"missing dataclass field {key!r}")
        try:
            return dataclasses.replace(
                node, **{key: _set_path(getattr(node, key), rest, leaf)})
        except TypeError as exc:
            raise DeltaApplyError(f"cannot replace field {key!r}: {exc}")
    raise DeltaApplyError(
        f"path step ({tag}, {key!r}) does not match node "
        f"{type(node).__name__}")
