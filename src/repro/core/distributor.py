"""The Sashimi Distributor: HTTPServer + TicketDistributor analogue.

Two generations live here:

**Distributor v2** (``AsyncDistributor``) — the asyncio event-driven
scheduler this repo's scaling work builds on.  Clients check out *lease
batches* of tickets sized to their measured throughput (EWMA of completed
work units per second, kept in ``TicketQueue.stats``):

  * unknown clients get a small probe lease;
  * a fast client's next lease grows toward ``rate * target_lease_time``;
  * a slow client's shrinks — the paper's redistribution policy preserved,
    but *proactive*: a watchdog releases leases that overrun their ETA by
    ``grace``x instead of waiting out the full five-minute timeout.

Idle clients park on a wake event and are woken when tickets arrive or a
lease is released — no polling loops.

**Distributor v1** (``Distributor`` + ``BrowserClient`` threads) — the
original thread-per-client simulator, kept as the fixed-size baseline that
``benchmarks/scheduler_throughput.py`` compares against.  Each client:
  1. connects to the distributor (WebSocket analogue: method calls),
  2. requests a ticket,
  3. downloads the task code if not cached (LRU-GC'd cache, as in §2.1.2),
  4. downloads required datasets/static files from the "HTTPServer",
  5. executes the task, 6. returns the result, 7. loops.
On an execution error the client files an error report (with traceback) and
*reloads itself* (cache cleared), exactly as the paper describes.  Clients
can be configured to be slow or to die mid-task, which exercises the
ticket-redistribution fault tolerance.
"""
from __future__ import annotations

import asyncio
import collections
import threading
import time
import traceback
import warnings
from dataclasses import dataclass
from typing import Any, Callable, Optional

from repro.core.tickets import ClientStats, LeaseBatch, TicketQueue
from repro.core.wire import (DeltaApplyError, apply_delta, flatten_tree,
                             leaf_equal)

#: Delta staleness horizon: a client whose cached copy is more than this
#: many re-publishes behind gets a full payload instead of a delta (the
#: registry only keeps leaf stamps for the last DELTA_HISTORY versions).
DELTA_HISTORY = 8


class LRUCache:
    """Least-recently-used cache (the paper's in-browser GC).

    Tracks ``hits`` / ``misses`` / ``evictions`` counters so tests and the
    console can verify caching behaviour."""

    def __init__(self, capacity: int = 16):
        self.capacity = capacity
        self._d: "collections.OrderedDict[str, Any]" = collections.OrderedDict()
        self.evictions = 0
        self.hits = 0
        self.misses = 0

    def get(self, key: str):
        """Return the cached value (marking it most-recent) or None."""
        if key in self._d:
            self._d.move_to_end(key)
            self.hits += 1
            return self._d[key]
        self.misses += 1
        return None

    def put(self, key: str, value: Any):
        """Insert/refresh ``key``, evicting least-recently-used overflow."""
        if key in self._d:
            self._d.move_to_end(key)
        self._d[key] = value
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.evictions += 1

    def pop(self, key: str):
        """Remove and return ``key``'s value (None if absent) without
        touching the hit/miss counters — the targeted-invalidation path."""
        return self._d.pop(key, None)

    def clear(self):
        """Drop everything (the paper's browser reload)."""
        self._d.clear()


@dataclass
class TaskDef:
    """A distributable task: code + the static files/datasets it needs."""

    name: str
    run: Callable[[Any, dict], Any]          # (args, static_data) -> result
    static_files: tuple = ()                 # dataset keys served over "HTTP"
    # monotonic code version, stamped by HttpServerBase.register_task from
    # the registry clock; 0 = never registered.  Re-registering the same
    # name always gets a LARGER version, so caches can tell stale from
    # fresh without comparing payloads.
    version: int = 0


#: Sentinel returned by a conditional fetch whose ``if_version`` matched:
#: the client's copy is current, no payload moved (the HTTP 304 analogue).
NOT_MODIFIED = object()


@dataclass
class Fetched:
    """Result of a versioned registry fetch: the payload (or None when
    ``not_modified``), the server-side version it corresponds to, and
    whether the conditional check short-circuited the transfer.

    ``current`` is the transport's currency claim: the origin always
    serves current data; an edge clears it when its reply raced an
    invalidation (sub-floor fill), telling the browser not to trust the
    payload beyond its own version."""

    value: Any
    version: int
    not_modified: bool = False
    current: bool = True
    #: protocol v2 delta reply: the version the changed-leaf dict in
    #: ``value`` patches (None = ``value`` is a full payload).  Set only
    #: when the client asked for a delta and its base is inside the
    #: registry's DELTA_HISTORY window.
    delta_base: Optional[int] = None

    # -- wire codec (docs/PROTOCOL.md) ---------------------------------------

    def to_wire(self, encode_value) -> dict:
        """JSON-safe dict for the transport layer: a not-modified reply is
        version metadata only; otherwise ``payload`` carries the encoded
        value (``encode_value`` is the opaque payload codec)."""
        d = {"version": self.version, "not_modified": self.not_modified,
             "current": self.current}
        if not self.not_modified:
            d["payload"] = encode_value(self.value)
            if self.delta_base is not None:
                d["delta_base"] = self.delta_base
        return d

    @classmethod
    def from_wire(cls, d: dict, decode_value) -> "Fetched":
        """Rebuild a fetch reply from its wire dict (inverse of
        :meth:`to_wire`)."""
        if d["not_modified"]:
            return cls(None, d["version"], not_modified=True,
                       current=d.get("current", True))
        return cls(decode_value(d["payload"]), d["version"],
                   current=d.get("current", True),
                   delta_base=d.get("delta_base"))


@dataclass
class ClientProfile:
    """Simulated browser behaviour (shared by v1 threads and v2 tasks)."""

    name: str = "client"
    speed: float = 1.0            # work units executed per second (v2) /
    #                               multiplier on task duration (v1)
    fail_prob: float = 0.0        # probability a task raises
    die_after: Optional[int] = None   # abandon after N tickets (v1) or
    #                                   N leases (v2)
    latency: float = 0.0          # network latency per round-trip (s)
    cache_capacity: int = 16


# ---------------------------------------------------------------------------
# Ticket sizing policies (Distributor v2)
# ---------------------------------------------------------------------------


@dataclass
class FixedSizer:
    """v1 policy: every lease is ``size`` tickets, regardless of client."""

    size: int = 1

    def lease_size(self, stats: Optional[ClientStats]) -> int:
        """Constant batch size; client stats are ignored."""
        return self.size

    def expected_duration(self, stats, n_tickets: int) -> Optional[float]:
        """ETA from the client's EWMA rate, or None before any
        observation (the watchdog skips ETA-less leases)."""
        if stats is None or not stats.rate:
            return None
        return n_tickets * stats.mean_ticket_work / stats.rate


@dataclass
class AdaptiveSizer:
    """v2 policy: size each lease so it takes ~``target_lease_time`` seconds
    on *that* client, based on its EWMA throughput.

    ``lease_size = clamp(rate * target_lease_time, min_size, max_size)``.
    Unknown clients get ``probe_size`` so one cheap lease calibrates the
    EWMA before committing real volume."""

    target_lease_time: float = 0.25
    min_size: int = 1
    max_size: int = 64
    probe_size: int = 2

    def lease_size(self, stats: Optional[ClientStats]) -> int:
        """Tickets per lease for this client: rate-proportional, clamped
        to [min_size, max_size]; probe_size until the rate is known.
        ``rate`` is in work units/s, so convert through the client's mean
        ticket work to get a ticket count."""
        if (stats is None or not stats.rate
                or not stats.mean_ticket_work):
            return self.probe_size   # no (usable) measurement yet
        n = int(round(stats.rate * self.target_lease_time
                      / stats.mean_ticket_work))
        return max(self.min_size, min(self.max_size, n))

    def expected_duration(self, stats, n_tickets: int) -> Optional[float]:
        """ETA for a lease of ``n_tickets`` on this client (watchdog
        deadline input)."""
        if stats is None or not stats.rate:
            # No measurement yet: arm the watchdog with a generous multiple
            # of the design target so a dead client's probe lease still
            # comes back, while a merely-slow client gets to finish its
            # probe and report a rate (a released lease's late submit
            # still calibrates the EWMA via the queue's side-table).
            return 4.0 * self.target_lease_time
        return n_tickets * stats.mean_ticket_work / stats.rate


@dataclass
class _DeltaState:
    """Per-static leaf-stamp bookkeeping for delta serving.

    ``flat`` maps each leaf path (see :func:`repro.core.wire.flatten_tree`)
    to the *current* leaf object; ``stamps`` maps the same paths to the
    registry version at which each leaf last changed; ``history`` is the
    ordered list of the last DELTA_HISTORY publish versions over which the
    path set was stable.  A structure change (paths added/removed) resets
    both, so a delta never has to express leaf removal."""

    flat: dict
    stamps: dict
    history: list


def build_delta_fetched(state: Optional[_DeltaState], version: int,
                        if_version: Optional[int], *,
                        current: bool = True) -> Optional[Fetched]:
    """The pure delta-serving decision, shared by the origin registry and
    the federation edge caches so their semantics cannot diverge.

    Returns a delta :class:`Fetched` (``value`` = changed-leaves dict,
    ``delta_base`` = ``if_version``) when the client's base version is
    inside the stamp window and strictly behind ``version``; otherwise
    None (caller falls back to a full payload)."""
    if (state is None or if_version is None or if_version == version
            or if_version not in state.history):
        return None
    changed = {p: leaf for p, leaf in state.flat.items()
               if state.stamps[p] > if_version}
    return Fetched(changed, version, current=current, delta_base=if_version)


class HttpServerBase:
    """The paper's HTTPServer half, shared by Distributor v1 and v2: a
    **versioned registry** of task code + static assets published to
    clients, with a split download ledger.

    Every ``register_task`` / ``add_static`` stamps the key with a fresh
    value of one registry-wide monotonic clock, so versions are totally
    ordered across keys.  Fetches can be **conditional** (ETag analogue):
    pass ``if_version`` and a current copy costs a counter bump
    (``revalidation_count``) instead of a payload copy
    (``download_count``).  Re-registering a key notifies invalidation
    subscribers (edge caches) with the new version, so exactly that key is
    busted fabric-wide — no full ``clear()``.

      * ``download_count[key]``      — full payload transfers (cold misses
                                       and version-mismatch refetches);
      * ``revalidation_count[key]``  — conditional fetches answered
                                       "not modified" (a counter bump)."""

    def __init__(self):
        self.tasks: dict[str, TaskDef] = {}
        self.static_store: dict[str, Any] = {}
        self.download_count: collections.Counter = collections.Counter()
        self.revalidation_count: collections.Counter = collections.Counter()
        #: partial (changed-leaves-only) transfers, keyed like
        #: download_count — a delta is neither a full download nor a 304
        self.delta_count: collections.Counter = collections.Counter()
        self._count_lock = threading.Lock()
        self._registry_clock = 0                 # shared monotonic versions
        self._static_versions: dict[str, int] = {}
        self._static_delta: dict[str, _DeltaState] = {}
        self._invalidation_listeners: list[Callable[[str, int], None]] = []

    # -- publishing (producer side) ------------------------------------------

    def subscribe_invalidation(self, listener: Callable[[str, int], None]):
        """Register ``listener(cache_key, new_version)`` to be called when
        a task ("task:<name>") or static ("static:<key>") is re-published.
        Edge caches subscribe so a re-register invalidates exactly that
        key everywhere instead of nuking whole stores."""
        with self._count_lock:
            self._invalidation_listeners.append(listener)

    def _notify_invalidation(self, cache_key: str, version: int):
        # called OUTSIDE _count_lock: a listener (edge) may take its own
        # lock and a concurrent edge miss holds that lock while fetching
        # from us — holding ours here would deadlock
        for fn in list(self._invalidation_listeners):
            fn(cache_key, version)

    def register_task(self, task: TaskDef):
        """Publish (or re-publish) a task's code.  Stamps ``task.version``
        from the registry clock and fans out an invalidation for the key."""
        with self._count_lock:
            self._registry_clock += 1
            task.version = self._registry_clock
            self.tasks[task.name] = task
        self._notify_invalidation(f"task:{task.name}", task.version)

    def add_static(self, key: str, value: Any):
        """Publish (or re-publish) a dataset/helper; bumps its version and
        fans out an invalidation for the key.

        Also stamps each leaf of the value with the version at which it
        last changed (protocol v2 delta encoding): a re-publish that keeps
        the tree structure compares leaves bit-exactly against the previous
        payload, so a later ``serve_static_versioned(..., delta=True)`` can
        ship only the changed leaves.  A structure change resets the stamp
        window — the next conditional fetch gets a full payload."""
        with self._count_lock:
            self._registry_clock += 1
            version = self._registry_clock
            self._static_versions[key] = version
            self.static_store[key] = value
            new_flat = flatten_tree(value)
            prev = self._static_delta.get(key)
            if prev is not None and prev.flat.keys() == new_flat.keys():
                stamps = {p: (prev.stamps[p]
                              if leaf_equal(prev.flat[p], leaf) else version)
                          for p, leaf in new_flat.items()}
                history = (prev.history + [version])[-DELTA_HISTORY:]
            else:
                stamps = {p: version for p in new_flat}
                history = [version]
            self._static_delta[key] = _DeltaState(new_flat, stamps, history)
        self._notify_invalidation(f"static:{key}", version)

    # -- versions -------------------------------------------------------------

    def static_version(self, key: str) -> int:
        """Current version of a static asset (0 = unversioned, e.g. the
        store was written to directly)."""
        return self._static_versions.get(key, 0)

    def task_version(self, name: str) -> int:
        """The task's **coherence version**: max over its code version and
        its declared statics' versions.  This is what tickets pin — a
        client validated at this version is guaranteed fresh code AND
        fresh data for the task, while unchanged assets still revalidate
        as counter bumps."""
        task = self.tasks.get(name)
        if task is None:
            return 0
        return max([task.version]
                   + [self._static_versions.get(k, 0)
                      for k in task.static_files])

    # -- serving (client side) ------------------------------------------------

    def fetch_task_versioned(self, name: str,
                             if_version: Optional[int] = None) -> Fetched:
        """Download task code, conditionally: when ``if_version`` matches
        the current code version the reply is a not-modified stub
        (revalidation ledger), else the full payload (download ledger)."""
        with self._count_lock:
            task = self.tasks[name]
            if if_version is not None and task.version == if_version:
                self.revalidation_count[f"task:{name}"] += 1
                return Fetched(None, task.version, not_modified=True)
            self.download_count[f"task:{name}"] += 1
            return Fetched(task, task.version)

    def serve_static_versioned(self, key: str,
                               if_version: Optional[int] = None, *,
                               delta: bool = False) -> Fetched:
        """Download a static asset, conditionally (see
        :meth:`fetch_task_versioned`).

        With ``delta=True`` (protocol v2) a client whose ``if_version`` is
        inside the DELTA_HISTORY stamp window gets only the leaves that
        changed since (``delta_count`` ledger); past the horizon — or
        across a structure change — it falls back to the full payload."""
        with self._count_lock:
            value = self.static_store[key]
            version = self._static_versions.get(key, 0)
            if if_version is not None and version == if_version:
                self.revalidation_count[key] += 1
                return Fetched(None, version, not_modified=True)
            if delta:
                got = build_delta_fetched(self._static_delta.get(key),
                                          version, if_version)
                if got is not None:
                    self.delta_count[key] += 1
                    return got
            self.download_count[key] += 1
            return Fetched(value, version)

    def static_delta_state(self, key: str
                           ) -> Optional[tuple[int, _DeltaState]]:
        """Snapshot ``(version, delta_state)`` for a static, taken
        atomically — an edge cache stores it alongside the payload it just
        fetched (discarding it if the versions disagree, i.e. the fetch
        raced a re-publish) so it can serve deltas without an origin
        round-trip."""
        with self._count_lock:
            state = self._static_delta.get(key)
            if state is None:
                return None
            return (self._static_versions.get(key, 0),
                    _DeltaState(dict(state.flat), dict(state.stamps),
                                list(state.history)))

    def static_delta_stats(self, key: str) -> dict:
        """Observability for the training loop: how much of the last
        publish of ``key`` actually changed (what a v2 delta fetch ships)
        versus the total leaf count."""
        with self._count_lock:
            state = self._static_delta.get(key)
            version = self._static_versions.get(key, 0)
            if state is None:
                return {"version": version, "leaves": 0, "changed": 0,
                        "window": 0}
            return {
                "version": version,
                "leaves": len(state.flat),
                "changed": sum(1 for p in state.flat
                               if state.stamps[p] == version),
                "window": len(state.history),
            }

    def serve_static(self, key: str):
        """Unconditional static download (v1 compat surface)."""
        return self.serve_static_versioned(key).value

    def fetch_task(self, name: str) -> TaskDef:
        """Unconditional task-code download (v1 compat surface)."""
        return self.fetch_task_versioned(name).value


@dataclass
class _CacheEntry:
    """A browser-cache slot: payload + the server version it carries +
    the highest ticket pin it has been validated against (``validated >=
    pin`` means no round-trip is needed for that pin)."""

    value: Any
    version: int
    validated: int


def merge_versioned_fetch(entry: Optional[_CacheEntry], got: Fetched,
                          min_version: int
                          ) -> tuple[Optional[_CacheEntry], bool, bool]:
    """The pure cache-merge decision for the download-through-cache rule,
    shared by the sync in-process path (``BrowserNodeBase``) and the
    async wire path (``transport.RemoteBrowserClient``) so the two
    staleness guarantees can never diverge.

    ``entry`` is the current cache slot (or None), ``got`` the reply to a
    conditional fetch, ``min_version`` the ticket's pin.  Returns
    ``(new_entry, revalidated, needs_refetch)``:

      * ``revalidated`` — the reply was an authoritative "not modified";
        the entry is re-validated at the pin (counter-bump accounting);
      * ``needs_refetch`` — the payload was served by an edge whose fill
        raced an invalidation (``current=False``): retry once
        unconditionally and fold the retry with
        :func:`merge_unconditional_fetch`;
      * otherwise ``new_entry`` carries the fresh payload, validated at
        the pin.

    A **delta** reply (``got.delta_base`` set, protocol v2) is spliced
    into the cached entry with :func:`repro.core.wire.apply_delta`; if the
    entry does not match the delta's base version — or the patch does not
    fit — the delta is discarded and ``needs_refetch`` asks for a full
    payload instead, so a bad delta can degrade to an extra round-trip but
    never to a wrong value."""
    if got.not_modified:
        # authoritative "your copy is current": validate at the pin
        return (_CacheEntry(entry.value, entry.version,
                            max(min_version, entry.version)), True, False)
    if not got.current:
        return None, False, True           # heal through a raced edge fill
    if got.delta_base is not None:
        if entry is None or entry.version != got.delta_base:
            return None, False, True       # base moved: take a full payload
        try:
            merged = apply_delta(entry.value, got.value)
        except DeltaApplyError:
            return None, False, True       # corrupt delta: full payload
        return (_CacheEntry(merged, got.version,
                            max(min_version, got.version)), False, False)
    return (_CacheEntry(got.value, got.version,
                        max(min_version, got.version)), False, False)


def merge_unconditional_fetch(got: Fetched, min_version: int) -> _CacheEntry:
    """Fold the retry after a raced edge fill: validate at the pin only
    if the transport now claims currency, else only at the payload's own
    version — so the next pinned ticket revalidates instead of freezing
    the staleness in."""
    validated = (max(min_version, got.version) if got.current
                 else got.version)
    return _CacheEntry(got.value, got.version, validated)


class BrowserNodeBase:
    """Per-client state and helpers shared by the v1 thread client and the
    v2 asyncio client: LRU cache, counters, deterministic failure RNG, and
    the paper's download-through-cache / reload-on-error behaviours.

    The cache is **version-aware**: each entry remembers the registry
    version it was downloaded at.  A ticket pinned at ``task_version`` >
    the entry's validated mark forces a *conditional* refetch — unchanged
    assets come back "not modified" (a counter bump on the server), stale
    ones are re-downloaded.  A ticket pinned at or below the validated
    mark runs straight from cache, which is exactly the pinned-version
    guarantee for leases taken before a re-register."""

    def _init_browser(self, distributor, profile: ClientProfile):
        self.dist = distributor
        self.profile = profile
        self.cache = LRUCache(profile.cache_capacity)
        self.executed = 0
        self.errors = 0
        self.reloads = 0
        self.revalidations = 0       # conditional fetches answered 304
        self._rng_state = hash(profile.name) & 0xFFFFFFFF

    def _rand(self) -> float:
        # tiny deterministic LCG so failures are reproducible
        self._rng_state = (1103515245 * self._rng_state + 12345) & 0x7FFFFFFF
        return self._rng_state / 0x7FFFFFFF

    def _get_versioned(self, cache_key: str, fetch, min_version: int):
        """The shared download-through-cache rule for task code AND
        statics.  ``fetch(if_version)`` is the transport (origin or
        edge); ``min_version`` is the ticket's pin.

          * entry validated at >= the pin: serve from cache, no trip;
          * otherwise fetch conditionally and fold the reply with
            :func:`merge_versioned_fetch` — "not modified" bumps the
            validated mark, a payload replaces the entry, and a payload
            the transport does NOT claim current (an edge whose fill
            raced an invalidation) is retried once unconditionally."""
        entry = self.cache.get(cache_key)
        if entry is not None and entry.validated >= min_version:
            return entry.value
        got = fetch(entry.version if entry is not None else None)
        new, revalidated, refetch = merge_versioned_fetch(entry, got,
                                                          min_version)
        if refetch:
            new = merge_unconditional_fetch(fetch(None), min_version)
        if revalidated:
            self.revalidations += 1
        self.cache.put(cache_key, new)
        return new.value

    def _get_task(self, name: str, min_version: int = 0) -> TaskDef:
        """Step 3: task code through the cache, revalidating when the
        ticket's pin (``min_version``) outruns the cached entry."""
        return self._get_versioned(
            f"task:{name}",
            lambda v: self.dist.fetch_task_versioned(name, if_version=v),
            min_version)

    def _get_static(self, task: TaskDef, min_version: int = 0) -> dict:
        """Step 4: the task's datasets through the cache, same
        revalidation rule as :meth:`_get_task`."""
        return {
            key: self._get_versioned(
                f"static:{key}",
                lambda v, k=key: self.dist.serve_static_versioned(
                    k, if_version=v),
                min_version)
            for key in task.static_files}

    def _reload(self):
        """Paper: on error the browser reloads itself."""
        self.cache.clear()
        self.reloads += 1


# ---------------------------------------------------------------------------
# Distributor v2: asyncio event-driven scheduler
# ---------------------------------------------------------------------------


class AsyncDistributor(HttpServerBase):
    """TicketDistributor + HTTPServer, asyncio edition (Distributor v2).

    Serves batched ticket leases sized by ``sizer`` (default
    :class:`AdaptiveSizer`).  A watchdog proactively releases leases that
    overrun their throughput-based ETA by ``grace``x, so work stranded on a
    stalled client is redistributed in seconds rather than after the
    paper's five-minute timeout.

    The clock is injectable for deterministic tests (see
    ``docs/ARCHITECTURE.md`` §Injectable clock); it must agree with the
    event loop's notion of elapsed time when simulated clients sleep.
    """

    def __init__(self, *, timeout: float = 300.0,
                 redistribute_min: float = 10.0,
                 clock: Callable[[], float] = time.monotonic,
                 sizer=None, grace: float = 3.0,
                 watchdog_interval: float = 0.05,
                 keep_alive: bool = False,
                 project_name: str = "project",
                 queue=None, tracer=None):
        super().__init__()
        # queue may be shared: a federation passes one ShardedTicketQueue
        # (duck-type compatible) to every member distributor
        self.queue = queue if queue is not None else TicketQueue(
            timeout=timeout, redistribute_min=redistribute_min, clock=clock,
            tracer=tracer)
        # a shared queue brings its own tracer; in-process clients, the
        # transport server and the round engine all look it up here
        self.tracer = (tracer if tracer is not None
                       else getattr(self.queue, "tracer", None))
        #: structured diagnosis of the last run_until_done give-up (the
        #: queue snapshot + outstanding leases at expiry), None if none
        self.last_stall_report: Optional[dict] = None
        self.sizer = sizer if sizer is not None else AdaptiveSizer()
        self.grace = grace
        # keep_alive: clients/watchdog survive a drained queue and wait for
        # the next add_work round (used by SplitConcurrentDispatcher, which
        # runs one ticket round per training step); shutdown() ends them.
        self.keep_alive = keep_alive
        self.watchdog_interval = watchdog_interval
        self.project_name = project_name
        self.clients: list["AsyncBrowserClient"] = []
        self._work_added = False
        self._wake: Optional[asyncio.Event] = None
        self._client_tasks: list[asyncio.Task] = []
        self._watchdog_task: Optional[asyncio.Task] = None

    # -- scheduler core (HTTPServer API inherited from HttpServerBase) -------

    def _wake_event(self) -> asyncio.Event:
        """Current wake epoch.  Waiters capture it BEFORE probing the queue
        (so a concurrent notify can't be lost), then await it; every notify
        sets the old epoch and installs a fresh one.  Plain Events have
        clean cancellation semantics, unlike asyncio.Condition on 3.10."""
        if self._wake is None:
            self._wake = asyncio.Event()
        return self._wake

    def _notify_waiters(self):
        ev = self._wake
        self._wake = asyncio.Event()
        if ev is not None:
            ev.set()

    @staticmethod
    async def _wait_on(wake: asyncio.Event, timeout: float):
        """Park on a captured wake epoch for at most ``timeout`` seconds.
        Uses asyncio.wait — NOT wait_for — because wait_for can swallow an
        outer cancel arriving during its own timeout (bpo-42130), which
        would leak the parked task past shutdown()."""
        waiter = asyncio.ensure_future(wake.wait())
        try:
            await asyncio.wait({waiter}, timeout=timeout)
        finally:
            if not waiter.done():
                waiter.cancel()

    def _terminal(self) -> bool:
        return (not self.keep_alive and self._work_added
                and self.queue.all_done())

    def add_work(self, task_name: str, args_list, *,
                 work: float = 1.0,
                 shard: Optional[int] = None) -> list[int]:
        """Enqueue tickets (non-async producer API); wakes idle clients.
        Tickets pin the task's current registry coherence version, so a
        later re-register can't make them execute stale assets.
        ``shard`` places the batch on an explicit queue shard (sharded
        stores only — the training fabric's per-member affinity)."""
        kw = {} if shard is None else {"shard": shard}
        tids = self.queue.add_many(task_name, args_list, work=work,
                                   task_version=self.task_version(task_name),
                                   **kw)
        self._work_added = True
        self._notify_waiters()
        return tids

    def client_rates(self) -> dict:
        """{client: EWMA work-units/s} (None until first measured) — the
        feed for ``split_parallel.adaptive_shard_sizes``, so producers can
        size shards to measured throughput.  Same surface as
        ``FederatedDistributor.client_rates``."""
        return {name: s.rate for name, s in self.queue.stats.items()}

    def _queue_lease(self, client_name: str, n: int):
        """Queue checkout hook: a federation member overrides this to
        prefer its home shards and steal from the rest when home drains."""
        return self.queue.lease(client_name, n)

    async def lease(self, client_name: str) -> Optional[LeaseBatch]:
        """Check out the next lease for ``client_name``, sized by the
        policy.  Parks on the condition until tickets are eligible; returns
        None once every ticket is complete."""
        while True:
            # An empty queue starts out "done"; only treat done as terminal
            # once a producer has actually enqueued work (clients may be
            # spawned before the first add_work call).  In keep_alive mode
            # clients park instead, awaiting the next round's add_work.
            if self._terminal():
                return None
            # capture the wake epoch BEFORE probing, so an add_work /
            # submit / release landing in between still wakes us
            wake = self._wake_event()
            stats = self.queue.stats.get(client_name)
            n = self.sizer.lease_size(stats)
            batch = self._queue_lease(client_name, n)
            if batch is not None:
                # ETA from the tickets actually GRANTED (the queue may hand
                # out fewer than requested near the end of a round)
                batch.expected_duration = self.sizer.expected_duration(
                    stats, len(batch.tickets))
                return batch
            # park until notified, or until the earliest cool-down expiry
            # (no event announces those; fall back to redistribute_min)
            hint = self.queue.seconds_until_eligible()
            pause = (self.queue.redistribute_min if hint is None
                     else max(min(hint, self.queue.redistribute_min), 1e-4))
            await self._wait_on(wake, pause)

    async def submit_batch(self, batch: LeaseBatch, results: dict) -> int:
        """Turn in a lease's results; wakes waiters (done or new
        redistribution candidates)."""
        accepted = self.queue.submit_batch(batch.lease_id, results,
                                           batch.client)
        self._notify_waiters()
        return accepted

    async def release_lease(self, batch: LeaseBatch, *,
                            client_failed: bool = False,
                            reset_vct: bool = True) -> int:
        """Give a lease's unfinished tickets back (client death path);
        ``reset_vct=False`` keeps the cool-down (error-retry path)."""
        n = self.queue.release(batch.lease_id, client_failed=client_failed,
                               reset_vct=reset_vct)
        if n:
            self._notify_waiters()
        return n

    async def evict_client_leases(self, client: str) -> int:
        """Force-release EVERY outstanding lease checked out by
        ``client`` — the distributor half of heartbeat eviction (see
        ``core/transport.py``): when a browser tab is declared gone, its
        stranded work goes back into circulation immediately instead of
        waiting out the watchdog's ``grace x ETA`` deadline.  Also the
        chaos harness's server-side tab-close lever.  Returns the number
        of tickets released."""
        n = 0
        for batch in self.queue.outstanding_leases():
            if batch.client == client:
                n += self.queue.release(batch.lease_id, client_failed=True)
        if n:
            self._notify_waiters()
        return n

    async def _watchdog(self):
        """Proactive redistribution: release leases overrunning their ETA."""
        while not self._terminal():
            now = self.queue.clock()
            for batch in self.queue.outstanding_leases():
                eta = batch.expected_duration
                if eta is None:
                    continue
                if now - batch.issued_at > self.grace * max(eta, 1e-3):
                    await self.release_lease(batch, client_failed=True)
            await asyncio.sleep(self.watchdog_interval)

    # -- client/session management ------------------------------------------

    def transport_endpoints(self) -> list["AsyncDistributor"]:
        """The lease/fetch endpoints a ``TransportServer`` may bind remote
        connections to — for a single distributor, itself.  A federation
        returns its alive members, so each remote client lands on one
        member's scheduler + edge cache (see ``core/transport.py``)."""
        return [self]

    def ensure_watchdog(self):
        """Arm the lease watchdog if it isn't running (must be called with
        an event loop running).  Spawning in-process clients does this
        automatically; a ``TransportServer`` serving only remote clients
        calls it explicitly.  The ``.done()`` check matters: a
        non-keep_alive watchdog self-terminates when a round drains, and a
        later spawn/connection must arm a fresh one."""
        if self._watchdog_task is None or self._watchdog_task.done():
            loop = asyncio.get_running_loop()
            self._watchdog_task = loop.create_task(self._watchdog())

    def spawn_clients(self, profiles) -> list["AsyncBrowserClient"]:
        """Create one :class:`AsyncBrowserClient` task per profile (must be
        called with an event loop running)."""
        loop = asyncio.get_running_loop()
        cs = [AsyncBrowserClient(self, p) for p in profiles]
        self.clients.extend(cs)
        self._client_tasks.extend(loop.create_task(c.run()) for c in cs)
        self.ensure_watchdog()
        return cs

    async def run_until_done(self, timeout: float = 60.0, *,
                             wall_cap: Optional[float] = None) -> bool:
        """Drive the loop until every ticket completes, then shut down the
        clients/watchdog; returns False on timeout (also shut down).

        ``timeout`` is measured on the queue's injectable clock — a
        virtual-clock sim times out in *virtual* seconds instead of racing
        wall time.  ``wall_cap`` (wall seconds, default
        ``max(timeout, 60)``) is the safety net for a virtual clock that
        never advances; virtual-clock tests exercising wedge scenarios
        should pass a small cap so a regression fails in seconds."""
        deadline = self.queue.clock() + timeout
        if wall_cap is None:
            wall_cap = max(timeout, 60.0)
        wall_deadline = time.monotonic() + wall_cap
        while not self.queue.all_done():
            vnow = self.queue.clock()
            if vnow > deadline or time.monotonic() > wall_deadline:
                # never silently: a stall here is a scheduling bug or a
                # wedged virtual clock, and the state that explains it is
                # about to be torn down — snapshot it first
                reason = ("timeout" if vnow > deadline else "wall_cap")
                report = self._stall_report(reason, vnow)
                self.last_stall_report = report
                if self.tracer is not None:
                    self.tracer.instant("distributor.stall", track="queue",
                                        cat="warning", ts=vnow, args=report)
                warnings.warn(
                    "run_until_done gave up (%s expired): %d ticket(s) "
                    "incomplete, %d outstanding lease(s); full queue "
                    "snapshot in .last_stall_report" % (
                        reason,
                        report["snapshot"]["tickets"]
                        - report["snapshot"]["executed"],
                        len(report["outstanding_leases"])),
                    RuntimeWarning, stacklevel=2)
                await self.shutdown()
                return False
            # event-driven: every submit/release notifies; the timeout is
            # only a fallback heartbeat
            wake = self._wake_event()
            if self.queue.all_done():
                break
            await self._wait_on(wake, 0.05)
        await self.shutdown()
        return True

    def _stall_report(self, reason: str, vnow: float) -> dict:
        """JSON-safe diagnosis of a wedged run: the control-console
        snapshot (which carries every client's EWMA rate), plus each
        outstanding lease with its age against its ETA — the two things
        needed to tell a straggler from a lost wake-up."""
        return {
            "reason": reason,
            "virtual_clock": vnow,
            "snapshot": self.queue.snapshot(),
            "client_rates": self.client_rates(),
            "outstanding_leases": [
                {"lease": b.lease_id, "client": b.client,
                 "tickets": [t.ticket_id for t in b.tickets],
                 "issued_at": b.issued_at,
                 "age_s": vnow - b.issued_at,
                 "expected_duration": b.expected_duration}
                for b in self.queue.outstanding_leases()],
        }

    async def shutdown(self):
        """Cancel client + watchdog tasks and wait for them to unwind."""
        self._notify_waiters()
        tasks = list(self._client_tasks)
        if self._watchdog_task is not None:
            tasks.append(self._watchdog_task)
        for t in tasks:
            t.cancel()
        await asyncio.gather(*tasks, return_exceptions=True)
        self._client_tasks.clear()
        self._watchdog_task = None

    def console(self) -> dict:
        """The paper's control console view (v2 edition)."""
        snap = self.queue.snapshot()
        snap["project"] = self.project_name
        snap["client_views"] = [
            {"name": c.profile.name, "executed": c.executed,
             "errors": c.errors, "alive": not c.done}
            for c in self.clients
        ]
        return snap


class AsyncBrowserClient(BrowserNodeBase):
    """A simulated browser node as an asyncio task (Distributor v2).

    Runs the paper's basic-program loop over the batched-lease API: lease →
    download code/data (LRU-cached) → execute each ticket → submit the
    batch.  ``profile.speed`` is the client's work-units-per-second; task
    execution is simulated with ``asyncio.sleep(work / speed)`` so
    heterogeneous clients genuinely take different wall-clock time."""

    def __init__(self, distributor: AsyncDistributor, profile: ClientProfile):
        self._init_browser(distributor, profile)
        self.leases_taken = 0
        self.done = False

    async def run(self):
        """Lease → download → execute → submit, until the queue drains
        (or the profile says the tab closes)."""
        try:
            while True:
                batch = await self.dist.lease(self.profile.name)
                if batch is None:
                    break
                self.leases_taken += 1
                if self.profile.latency:
                    await asyncio.sleep(self.profile.latency)
                if (self.profile.die_after is not None
                        and self.leases_taken > self.profile.die_after):
                    # tab closed mid-lease: tickets go straight back
                    await self.dist.release_lease(batch, client_failed=True)
                    break
                results: dict[int, Any] = {}
                failed = False
                tr = self.dist.tracer
                exec_span = None
                if tr is not None:
                    exec_span = tr.begin(
                        "client.execute", lane=True, cat="client",
                        track=f"client:{self.profile.name}",
                        ts=self.dist.queue.clock(),
                        args={"lease": batch.lease_id,
                              "tickets": len(batch.tickets)})
                try:
                    await self._run_tickets(batch, results)
                except Exception:
                    failed = True
                finally:
                    if tr is not None:
                        tr.end(exec_span, ts=self.dist.queue.clock(),
                               args={"executed": len(results),
                                     "failed": failed})
                await self.dist.submit_batch(batch, results)
                if failed:
                    # drop the lease bookkeeping for the errored tickets
                    # but keep their redistribute_min cool-down (paper
                    # behaviour) — a deterministically failing task must
                    # not hot-loop at event-loop speed
                    await self.dist.release_lease(batch, reset_vct=False)
        finally:
            self.done = True

    async def _run_tickets(self, batch: LeaseBatch, results: dict):
        """Execute a lease's tickets into ``results``; raises after the
        loop if any ticket errored (the caller releases the lease with the
        cool-down kept)."""
        failed = False
        for ticket in batch.tickets:
            try:
                # the ticket's pinned version drives revalidation:
                # a pin newer than the cached entry forces a
                # conditional refetch, so post-re-register tickets
                # can never execute stale code or data
                task = self._get_task(ticket.task_name,
                                      ticket.task_version)
                static = self._get_static(task, ticket.task_version)
                if (self.profile.fail_prob
                        and self._rand() < self.profile.fail_prob):
                    raise RuntimeError(
                        "simulated browser crash in "
                        f"{ticket.task_name}")
                if self.profile.speed > 0:
                    await asyncio.sleep(
                        ticket.work / self.profile.speed)
                results[ticket.ticket_id] = task.run(ticket.args,
                                                     static)
                self.executed += 1
            except Exception:
                self.errors += 1
                self.dist.queue.report_error(
                    ticket.ticket_id, traceback.format_exc(),
                    self.profile.name)
                self._reload()
                failed = True
        if failed:
            raise RuntimeError("ticket(s) errored in lease "
                               f"{batch.lease_id}")


# ---------------------------------------------------------------------------
# Distributor v1: thread-per-client baseline (fixed-size tickets)
# ---------------------------------------------------------------------------


class Distributor(HttpServerBase):
    """TicketDistributor + HTTPServer in one object (v1 baseline)."""

    def __init__(self, *, timeout: float = 300.0,
                 redistribute_min: float = 10.0,
                 clock: Callable[[], float] = time.monotonic,
                 project_name: str = "project"):
        super().__init__()
        self.queue = TicketQueue(timeout=timeout,
                                 redistribute_min=redistribute_min,
                                 clock=clock)
        self.project_name = project_name
        self.clients: list["BrowserClient"] = []

    # client management (HTTPServer API inherited from HttpServerBase) -------

    def add_work(self, task_name: str, args_list, *,
                 work: float = 1.0) -> list[int]:
        """Enqueue version-pinned tickets (v1 mirror of the v2 producer
        API); the thread clients poll, so no wake-up is needed."""
        return self.queue.add_many(task_name, args_list, work=work,
                                   task_version=self.task_version(task_name))

    def spawn_clients(self, profiles) -> list["BrowserClient"]:
        """Start one daemon thread per profile."""
        cs = [BrowserClient(self, p) for p in profiles]
        self.clients.extend(cs)
        for c in cs:
            c.start()
        return cs

    def shutdown(self):
        """Stop and join all client threads."""
        for c in self.clients:
            c.stop()
        for c in self.clients:
            c.join(timeout=5)
        self.clients.clear()

    def console(self) -> dict:
        """The paper's control console view."""
        snap = self.queue.snapshot()
        snap["project"] = self.project_name
        snap["clients"] = [
            {"name": c.profile.name, "executed": c.executed,
             "errors": c.errors, "alive": c.is_alive()}
            for c in self.clients
        ]
        return snap


class BrowserClient(threading.Thread, BrowserNodeBase):
    """A simulated browser node running the paper's basic-program loop."""

    def __init__(self, distributor: Distributor, profile: ClientProfile):
        super().__init__(daemon=True)
        self._init_browser(distributor, profile)
        # NB: named _stop_requested because threading.Thread owns a private
        # _stop() method; shadowing it breaks Thread.join().
        self._stop_requested = threading.Event()

    def stop(self):
        """Ask the client thread to exit after its current ticket."""
        self._stop_requested.set()

    def run(self):
        """The paper's steps 2-7: request → download → execute → submit."""
        while not self._stop_requested.is_set():
            ticket = self.dist.queue.request()       # step 2: ticket request
            if ticket is None:
                if self.dist.queue.all_done():
                    time.sleep(0.001)
                else:
                    time.sleep(0.002)
                continue
            if self.profile.latency:
                time.sleep(self.profile.latency)
            try:
                task = self._get_task(ticket.task_name, ticket.task_version)
                static = self._get_static(task, ticket.task_version)
                if self.profile.fail_prob and self._rand() < self.profile.fail_prob:
                    raise RuntimeError(
                        f"simulated browser crash in {ticket.task_name}")
                t0 = time.perf_counter()
                result = task.run(ticket.args, static)
                if 0 < self.profile.speed < 1.0:
                    # profile.speed is a duration multiplier in v1: a 0.2x
                    # client takes 5x the real execution time.  Sleep the
                    # difference so slow clients genuinely hold tickets
                    # longer (speeds >= 1 can't shrink real compute).
                    elapsed = time.perf_counter() - t0
                    time.sleep(elapsed * (1.0 / self.profile.speed - 1.0))
                self.dist.queue.submit(ticket.ticket_id, result,
                                       self.profile.name)
                self.executed += 1
            except Exception:
                self.errors += 1
                self.dist.queue.report_error(
                    ticket.ticket_id, traceback.format_exc(),
                    self.profile.name)
                self._reload()                        # paper: reload browser
            if (self.profile.die_after is not None
                    and self.executed + self.errors
                    >= self.profile.die_after):
                return                                # browser tab closed
