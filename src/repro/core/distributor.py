"""The Sashimi Distributor: HTTPServer + TicketDistributor analogue with
simulated browser clients.

The paper's browsers become ``BrowserClient`` threads.  Each client:
  1. connects to the distributor (WebSocket analogue: method calls),
  2. requests a ticket,
  3. downloads the task code if not cached (LRU-GC'd cache, as in §2.1.2),
  4. downloads required datasets/static files from the "HTTPServer",
  5. executes the task, 6. returns the result, 7. loops.
On an execution error the client files an error report (with traceback) and
*reloads itself* (cache cleared), exactly as the paper describes.  Clients
can be configured to be slow or to die mid-task, which exercises the
ticket-redistribution fault tolerance.
"""
from __future__ import annotations

import collections
import threading
import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.core.tickets import TicketQueue


class LRUCache:
    """Least-recently-used cache (the paper's in-browser GC)."""

    def __init__(self, capacity: int = 16):
        self.capacity = capacity
        self._d: "collections.OrderedDict[str, Any]" = collections.OrderedDict()
        self.evictions = 0
        self.hits = 0
        self.misses = 0

    def get(self, key: str):
        if key in self._d:
            self._d.move_to_end(key)
            self.hits += 1
            return self._d[key]
        self.misses += 1
        return None

    def put(self, key: str, value: Any):
        if key in self._d:
            self._d.move_to_end(key)
        self._d[key] = value
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.evictions += 1

    def clear(self):
        self._d.clear()


@dataclass
class TaskDef:
    """A distributable task: code + the static files/datasets it needs."""

    name: str
    run: Callable[[Any, dict], Any]          # (args, static_data) -> result
    static_files: tuple = ()                 # dataset keys served over "HTTP"


@dataclass
class ClientProfile:
    """Simulated browser behaviour."""

    name: str = "client"
    speed: float = 1.0            # multiplier on task work_fn duration
    fail_prob: float = 0.0        # probability a task raises
    die_after: Optional[int] = None   # abandon (thread exit) after N tickets
    latency: float = 0.0          # network latency per round-trip (s)
    cache_capacity: int = 16


class Distributor:
    """TicketDistributor + HTTPServer in one object."""

    def __init__(self, *, timeout: float = 300.0,
                 redistribute_min: float = 10.0,
                 clock: Callable[[], float] = time.monotonic,
                 project_name: str = "project"):
        self.queue = TicketQueue(timeout=timeout,
                                 redistribute_min=redistribute_min,
                                 clock=clock)
        self.project_name = project_name
        self.tasks: dict[str, TaskDef] = {}
        self.static_store: dict[str, Any] = {}   # HTTPServer assets
        self.download_count: collections.Counter = collections.Counter()
        self.clients: list["BrowserClient"] = []
        self._lock = threading.Lock()

    # HTTPServer API -----------------------------------------------------

    def register_task(self, task: TaskDef):
        self.tasks[task.name] = task

    def serve_static(self, key: str):
        with self._lock:
            self.download_count[key] += 1
        return self.static_store[key]

    def fetch_task(self, name: str) -> TaskDef:
        with self._lock:
            self.download_count[f"task:{name}"] += 1
        return self.tasks[name]

    # client management ----------------------------------------------------

    def spawn_clients(self, profiles) -> list["BrowserClient"]:
        cs = [BrowserClient(self, p) for p in profiles]
        self.clients.extend(cs)
        for c in cs:
            c.start()
        return cs

    def shutdown(self):
        for c in self.clients:
            c.stop()
        for c in self.clients:
            c.join(timeout=5)
        self.clients.clear()

    def console(self) -> dict:
        """The paper's control console view."""
        snap = self.queue.snapshot()
        snap["project"] = self.project_name
        snap["clients"] = [
            {"name": c.profile.name, "executed": c.executed,
             "errors": c.errors, "alive": c.is_alive()}
            for c in self.clients
        ]
        return snap


class BrowserClient(threading.Thread):
    """A simulated browser node running the paper's basic-program loop."""

    def __init__(self, distributor: Distributor, profile: ClientProfile):
        super().__init__(daemon=True)
        self.dist = distributor
        self.profile = profile
        self.cache = LRUCache(profile.cache_capacity)
        self.executed = 0
        self.errors = 0
        self.reloads = 0
        self._stop = threading.Event()
        self._rng_state = hash(profile.name) & 0xFFFFFFFF

    def stop(self):
        self._stop.set()

    def _rand(self) -> float:
        # tiny deterministic LCG so failures are reproducible
        self._rng_state = (1103515245 * self._rng_state + 12345) & 0x7FFFFFFF
        return self._rng_state / 0x7FFFFFFF

    def _get_task(self, name: str) -> TaskDef:
        cached = self.cache.get(f"task:{name}")
        if cached is not None:
            return cached
        task = self.dist.fetch_task(name)           # step 3: download code
        self.cache.put(f"task:{name}", task)
        return task

    def _get_static(self, task: TaskDef) -> dict:
        data = {}
        for key in task.static_files:               # step 4: download data
            cached = self.cache.get(f"static:{key}")
            if cached is None:
                cached = self.dist.serve_static(key)
                self.cache.put(f"static:{key}", cached)
            data[key] = cached
        return data

    def _reload(self):
        """Paper: on error the browser reloads itself."""
        self.cache.clear()
        self.reloads += 1

    def run(self):
        while not self._stop.is_set():
            ticket = self.dist.queue.request()       # step 2: ticket request
            if ticket is None:
                if self.dist.queue.all_done():
                    time.sleep(0.001)
                else:
                    time.sleep(0.002)
                continue
            if self.profile.latency:
                time.sleep(self.profile.latency)
            try:
                task = self._get_task(ticket.task_name)
                static = self._get_static(task)
                if self.profile.fail_prob and self._rand() < self.profile.fail_prob:
                    raise RuntimeError(
                        f"simulated browser crash in {ticket.task_name}")
                result = task.run(ticket.args, static)
                if self.profile.speed != 1.0:
                    time.sleep(0)  # speed modelled inside task work functions
                self.dist.queue.submit(ticket.ticket_id, result,
                                       self.profile.name)
                self.executed += 1
            except Exception:
                self.errors += 1
                self.dist.queue.report_error(
                    ticket.ticket_id, traceback.format_exc(),
                    self.profile.name)
                self._reload()                        # paper: reload browser
            if (self.profile.die_after is not None
                    and self.executed + self.errors
                    >= self.profile.die_after):
                return                                # browser tab closed
