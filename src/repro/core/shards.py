"""Sharded ticket store: the federation fabric's queue-of-queues.

One ``TicketQueue`` behind one lock is the seed's scaling ceiling — every
producer ``add_many`` and every client ``lease``/``submit_batch`` from any
distributor serialises on the same mutex.  :class:`ShardedTicketQueue`
partitions tickets **by task** into per-shard ``TicketQueue``s, each with
its own lock, so traffic for different tasks never contends and a
federation of distributors can drive the same store concurrently.

The paper's §2.1.2 ordering rule survives sharding via a two-step
**peek/checkout min-VCT merge**:

  1. ``peek_eligible`` each candidate shard for its top-k eligible
     ``(virtual_created_time, ticket_id)`` pairs (per-shard lock, held
     briefly);
  2. merge the candidates globally, keep the k smallest, and check the
     winners out of their shards with ``lease_tickets`` under one shared
     **lease id** — so a single lease batch may interleave tickets from
     several shards in exact global ascending-VCT order.

A ticket completed or re-cooled between peek and checkout is skipped by
``lease_tickets`` (another client won the race); the global order degrades
gracefully under contention and is *exact* when operations are serialised
(property-tested against a single ``TicketQueue`` in
``tests/test_shards.py``).

Global invariants the sharded store maintains on top of its shards:

  * **ticket ids** come from one shared counter, so they are globally
    unique and assigned in arrival order (VCT ties break identically to
    the single-queue case);
  * **lease ids** come from one shared counter; a cross-shard lease uses
    the same id in every member shard, and the store keeps the global
    ``LeaseBatch`` plus the set of shards it touched for routing;
  * **client stats** (EWMA rate, lease/failure counts) are booked exactly
    once at the global level — member shards are told ``observe=False`` so
    a lease spanning three shards still folds ONE (work, duration) sample
    into the client's rate.

Lock order: the store's small ``_meta_lock`` (routing tables) may be held
while taking a shard lock, never the reverse — shards know nothing about
the store, so no cycle is possible.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
import zlib
import collections
from typing import Any, Callable, Optional

from repro.core.tickets import ClientStats, LeaseBatch, Ticket, TicketQueue


def shard_index(task_name: str, n_shards: int) -> int:
    """Stable task → shard mapping (crc32, not ``hash``: Python salts
    string hashes per process, and shard placement must agree between a
    producer and a distributor restarted later)."""
    return zlib.crc32(task_name.encode()) % n_shards


class ShardedTicketQueue:
    """Drop-in ``TicketQueue`` replacement partitioned by task.

    Duck-type compatible with the surface ``AsyncDistributor`` and
    ``SplitConcurrentDispatcher`` use (``add_many`` / ``lease`` /
    ``submit_batch`` / ``release`` / ``results_for`` / ``prune`` /
    ``snapshot`` / ...), plus a ``shards=`` hint on :meth:`lease` so a
    federation member can prefer its *home* shards and steal from the rest
    only when home runs dry.
    """

    def __init__(self, n_shards: int = 4, *, timeout: float = 300.0,
                 redistribute_min: float = 10.0,
                 clock: Callable[[], float] = time.monotonic,
                 tracer=None):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = n_shards
        self.timeout = timeout
        self.redistribute_min = redistribute_min
        self.clock = clock
        # shards share the store's tracer for per-ticket lifecycle spans;
        # cross-shard leases are traced once here (shards are checked out
        # with observe=False, which also skips their per-shard lease span)
        self.tracer = tracer
        self._lease_spans: dict[int, int] = {}    # guarded by _meta_lock
        self.shards: list[TicketQueue] = [
            TicketQueue(timeout=timeout, redistribute_min=redistribute_min,
                        clock=clock, tracer=tracer)
            for _ in range(n_shards)]
        # one id stream across shards: globally unique, arrival-ordered
        # (itertools.count.__next__ is atomic under the GIL)
        shared_ids = itertools.count()
        for sh in self.shards:
            sh._ids = shared_ids
        self._lease_ids = itertools.count()
        self._meta_lock = threading.Lock()
        self._ticket_shard: dict[int, TicketQueue] = {}
        # global lease routing: lease_id -> (batch, shards it touched)
        self._leases: dict[int, tuple[LeaseBatch, list[TicketQueue]]] = {}
        self._released_leases: "collections.OrderedDict[int, LeaseBatch]" = \
            collections.OrderedDict()
        self._stats_lock = threading.Lock()
        self.stats: dict[str, ClientStats] = {}
        self.releases = 0

    # -- routing --------------------------------------------------------------

    def shard_for(self, task_name: str) -> TicketQueue:
        """The shard that owns ``task_name``'s tickets."""
        return self.shards[shard_index(task_name, self.n_shards)]

    def _route_results(self, results: dict) -> dict:
        """Group a {ticket_id: result} dict by owning shard (unknown ids —
        already pruned — are dropped, matching TicketQueue.submit)."""
        by_shard: dict[int, tuple[TicketQueue, dict]] = {}
        with self._meta_lock:
            for tid, r in results.items():
                sh = self._ticket_shard.get(tid)
                if sh is not None:
                    by_shard.setdefault(id(sh), (sh, {}))[1][tid] = r
        return by_shard

    # -- producer side --------------------------------------------------------

    def add(self, task_name: str, args: Any, *, work: float = 1.0,
            task_version: int = 0, shard: Optional[int] = None) -> int:
        """Enqueue one ticket on its task's shard (or an explicit
        ``shard`` index — see :meth:`add_many`); returns its id."""
        sh = (self.shard_for(task_name) if shard is None
              else self.shards[shard])
        tid = sh.add(task_name, args, work=work, task_version=task_version)
        with self._meta_lock:
            self._ticket_shard[tid] = sh
        if self.tracer is not None:
            self.tracer.instant(
                "ticket.route", track="queue", cat="ticket",
                ts=self.clock(),
                args={"shard": self.shards.index(sh), "tickets": 1,
                      "task": task_name})
        return tid

    def add_many(self, task_name: str, args_list, *, work=1.0,
                 task_version: int = 0,
                 shard: Optional[int] = None) -> list[int]:
        """Bulk-enqueue on the owning shard (one shard lock acquisition;
        producers for different tasks don't contend at all).

        ``shard`` overrides the task-name hash with an explicit shard
        index — the training fabric uses it to spread one task's round of
        tickets across the federation members' *home* shards (per-member
        shard affinity), so each member serves its slice from its own
        locks instead of stealing everything from one hot shard.  All
        downstream routing (submit / results / prune) follows the
        per-ticket table, so placement is free to differ per round."""
        sh = (self.shard_for(task_name) if shard is None
              else self.shards[shard])
        tids = sh.add_many(task_name, args_list, work=work,
                           task_version=task_version)
        with self._meta_lock:
            for tid in tids:
                self._ticket_shard[tid] = sh
        if self.tracer is not None and tids:
            self.tracer.instant(
                "ticket.route", track="queue", cat="ticket",
                ts=self.clock(),
                args={"shard": self.shards.index(sh), "tickets": len(tids),
                      "task": task_name})
        return tids

    # -- client side: batched leases ------------------------------------------

    def lease(self, client: str, max_tickets: int = 1,
              *, expected_duration: Optional[float] = None,
              shards: Optional[list[TicketQueue]] = None
              ) -> Optional[LeaseBatch]:
        """Check out up to ``max_tickets`` tickets in global ascending-VCT
        order, merged across ``shards`` (default: all of them).

        A federation member passes its home shards here and falls back to
        the full set to steal (see ``federation.FederationMember``)."""
        now = self.clock()
        pool = self.shards if shards is None else shards
        # step 1: peek each shard's top-k (brief per-shard locks)
        candidates: list[tuple[float, int, TicketQueue]] = []
        for sh in pool:
            candidates.extend(
                (vct, tid, sh)
                for vct, tid in sh.peek_eligible(max_tickets, now=now))
        if not candidates:
            return None
        picked = heapq.nsmallest(max_tickets, candidates,
                                 key=lambda c: c[:2])
        # step 2: check the winners out shard by shard under ONE lease id
        lease_id = next(self._lease_ids)
        per_shard: dict[int, tuple[TicketQueue, list[int]]] = {}
        for _, tid, sh in picked:
            per_shard.setdefault(id(sh), (sh, []))[1].append(tid)
        granted: dict[int, Ticket] = {}
        touched: list[TicketQueue] = []
        for sh, tids in per_shard.values():
            sub = sh.lease_tickets(client, tids, lease_id=lease_id, now=now,
                                   observe=False)
            if sub is not None:
                touched.append(sh)
                granted.update((t.ticket_id, t) for t in sub.tickets)
        if not granted:
            return None          # lost every race between peek and checkout
        # assemble client-side copies in the merged global order
        copies = [granted[tid] for _, tid, _ in picked if tid in granted]
        batch = LeaseBatch(lease_id, client, copies, now,
                           expected_duration=expected_duration,
                           shards=touched)
        with self._meta_lock:
            self._leases[lease_id] = (batch, touched)
            if self.tracer is not None:
                self._lease_spans[lease_id] = self.tracer.begin(
                    "lease", track="queue", cat="lease", ts=now,
                    args={"lease": lease_id, "client": client,
                          "tickets": len(copies), "shards": len(touched)})
        with self._stats_lock:
            self.stats.setdefault(client, ClientStats(client)).leases += 1
        return batch

    def submit_batch(self, lease_id: int, results: dict,
                     client: str = "?") -> int:
        """Record a lease's results, routing each ticket to its shard;
        folds ONE EWMA sample (total accepted work over the lease's full
        duration) into the client's global stats."""
        now = self.clock()
        with self._meta_lock:
            entry = self._leases.get(lease_id)
            batch = (entry[0] if entry is not None
                     else self._released_leases.pop(lease_id, None))
        accepted = 0
        accepted_work = 0.0
        for sh, sub in self._route_results(results).values():
            a, w = sh.submit_batch_ex(lease_id, sub, client, observe=False)
            accepted += a
            accepted_work += w
        if accepted and batch is not None:
            with self._stats_lock:
                self.stats.setdefault(client, ClientStats(client)).observe(
                    accepted_work, now - batch.issued_at, tickets=accepted)
        self._gc_lease(lease_id)
        # a redistributed ticket can sit in several leases: this submit
        # may have drained OTHER leases' last outstanding tickets at the
        # shard level — sweep them too, so their store records don't
        # linger for the watchdog (the per-shard GC already ran)
        with self._meta_lock:
            others = [lid for lid in self._leases if lid != lease_id]
        for lid in others:
            self._gc_lease(lid)
        return accepted

    def _gc_lease(self, lease_id: int):
        """Drop the global lease record once no member shard still holds
        outstanding tickets for it (mirrors TicketQueue's per-shard GC,
        so the watchdog never sees a fully-drained lease)."""
        with self._meta_lock:
            entry = self._leases.get(lease_id)
            if entry is None:
                return
            batch, touched = entry
            if not any(sh.lease_is_outstanding(lease_id) for sh in touched):
                del self._leases[lease_id]
                if self.tracer is not None:
                    self.tracer.end(self._lease_spans.pop(lease_id, None),
                                    ts=self.clock(),
                                    args={"status": "drained"})

    def release(self, lease_id: int, *, client_failed: bool = False,
                reset_vct: bool = True) -> int:
        """Return a lease's unfinished tickets across every shard it
        touched (member died / watchdog overrun); global failure and
        release counters are booked once, not once per shard."""
        with self._meta_lock:
            entry = self._leases.pop(lease_id, None)
            if entry is not None:
                # park the batch for late submits IN the same critical
                # section as the pop — a concurrent submit_batch must
                # always find the batch in one of the two tables, or its
                # EWMA observation would be silently skipped
                self._released_leases[lease_id] = entry[0]
                while len(self._released_leases) > 256:
                    self._released_leases.popitem(last=False)
                if self.tracer is not None:
                    self.tracer.end(self._lease_spans.pop(lease_id, None),
                                    ts=self.clock(),
                                    args={"status": "released",
                                          "client_failed": client_failed,
                                          "reset_vct": reset_vct})
        if entry is None:
            return 0
        batch, touched = entry
        released = sum(
            sh.release(lease_id, client_failed=False, reset_vct=reset_vct)
            for sh in touched)
        with self._stats_lock:
            if released:
                self.releases += 1
            if client_failed:
                self.stats.setdefault(
                    batch.client, ClientStats(batch.client)).failures += 1
        return released

    # -- client side: v1 single-ticket API ------------------------------------

    def request(self) -> Optional[Ticket]:
        """v1 compat: hand out the single globally-min-VCT ticket."""
        now = self.clock()
        best = min((c for sh in self.shards
                    for c in ((vct, tid, sh) for vct, tid
                              in sh.peek_eligible(1, now=now))),
                   default=None, key=lambda c: c[:2])
        if best is None:
            return None
        return best[2].request()

    def submit(self, ticket_id: int, result: Any, client: str = "?") -> bool:
        """v1 compat: route a single result to its shard."""
        with self._meta_lock:
            sh = self._ticket_shard.get(ticket_id)
        return sh.submit(ticket_id, result, client) if sh else False

    # -- scheduler support -----------------------------------------------------

    def seconds_until_eligible(self) -> Optional[float]:
        """Minimum over shards: time until ANY cool-down expires."""
        best = None
        for sh in self.shards:
            r = sh.seconds_until_eligible()
            if r is None:
                continue
            if r <= 0:
                return 0.0
            if best is None or r < best:
                best = r
        return best

    def outstanding_leases(self) -> list[LeaseBatch]:
        """Global leases with at least one unfinished ticket in some shard
        (the federation members' shared watchdog input)."""
        with self._meta_lock:
            entries = list(self._leases.values())
        return [batch for batch, touched in entries
                if any(sh.lease_is_outstanding(batch.lease_id)
                       for sh in touched)]

    def results_for(self, ticket_ids) -> Optional[list]:
        """Results for exactly ``ticket_ids`` in order, or None if any is
        unfinished (routes each id to its shard)."""
        out = []
        with self._meta_lock:
            shards = [self._ticket_shard.get(tid) for tid in ticket_ids]
        for tid, sh in zip(ticket_ids, shards):
            if sh is None:
                return None
            got = sh.results_for([tid])
            if got is None:
                return None
            out.append(got[0])
        return out

    def prune(self, ticket_ids) -> int:
        """Forget completed tickets and their shard-routing entries.

        Three lock acquisitions total (route, per-shard prune, routing
        cleanup) — NOT one ``_meta_lock`` round per ticket, which made
        pruning a long round O(n) lock traffic."""
        pruned: list = []
        for sh, tids in self._route_ids(ticket_ids):
            pruned.extend(sh.prune_ex(tids))
        if pruned:
            with self._meta_lock:
                for tid in pruned:
                    self._ticket_shard.pop(tid, None)
        return len(pruned)

    def _route_ids(self, ticket_ids) -> list[tuple[TicketQueue, list]]:
        """Group ticket ids by owning shard (one ``_meta_lock``
        acquisition; unknown — already pruned — ids are dropped)."""
        with self._meta_lock:
            routed = [(tid, self._ticket_shard.get(tid))
                      for tid in ticket_ids]
        by_shard: dict[int, tuple[TicketQueue, list]] = {}
        for tid, sh in routed:
            if sh is not None:
                by_shard.setdefault(id(sh), (sh, []))[1].append(tid)
        return list(by_shard.values())

    def cancel(self, ticket_ids) -> int:
        """Force-complete tickets with the CANCELLED sentinel, routed to
        their owning shards (the K-of-N barrier's fold path)."""
        n = sum(sh.cancel(tids) for sh, tids in self._route_ids(ticket_ids))
        if n:
            # GC global lease records fully drained by the cancellations:
            # a dead client's never-submitted lease would otherwise leak
            # its _leases entry forever (no watchdog patrols a lease with
            # no outstanding tickets, and no submit runs _gc_lease)
            with self._meta_lock:
                drained = [
                    lid for lid, (_, touched) in self._leases.items()
                    if not any(sh.lease_is_outstanding(lid)
                               for sh in touched)]
                for lid in drained:
                    del self._leases[lid]
                    if self.tracer is not None:
                        self.tracer.end(self._lease_spans.pop(lid, None),
                                        ts=self.clock(),
                                        args={"status": "drained"})
        return n

    def completed_results(self, ticket_ids) -> dict:
        """{ticket_id: result} for the already-completed subset (partial-
        progress probe for round barriers; routes each id to its shard)."""
        out: dict = {}
        for sh, tids in self._route_ids(ticket_ids):
            out.update(sh.completed_results(tids))
        return out

    def report_error(self, ticket_id: int, error: str, client: str = "?"):
        """Route an error report to the owning shard."""
        with self._meta_lock:
            sh = self._ticket_shard.get(ticket_id)
        if sh is not None:
            sh.report_error(ticket_id, error, client)

    # -- introspection ---------------------------------------------------------

    def all_done(self) -> bool:
        """True when every shard's every ticket has a result."""
        return all(sh.all_done() for sh in self.shards)

    def wait_all(self, timeout: Optional[float] = None) -> bool:
        """Block until every shard drains (or ``timeout`` elapses)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        for sh in self.shards:
            remaining = (None if deadline is None
                         else max(deadline - time.monotonic(), 0.0))
            if not sh.wait_all(remaining):
                return False
        return True

    def results(self) -> dict[int, Any]:
        """{ticket_id: result} merged across shards."""
        out: dict[int, Any] = {}
        for sh in self.shards:
            out.update(sh.results())
        return out

    def snapshot(self) -> dict:
        """Control-console counters summed over shards, with global client
        stats and a per-shard breakdown."""
        shard_snaps = [sh.snapshot() for sh in self.shards]
        summed = {k: sum(s[k] for s in shard_snaps)
                  for k in ("tickets", "waiting", "in_flight", "executed",
                            "errors", "redistributions", "duplicates")}
        with self._stats_lock:
            summed["lease_releases"] = self.releases
            summed["clients"] = {
                name: {"rate": s.rate, "leases": s.leases,
                       "completed": s.completed_tickets,
                       "failures": s.failures}
                for name, s in self.stats.items()}
        summed["shards"] = [
            {"tickets": s["tickets"], "waiting": s["waiting"],
             "in_flight": s["in_flight"], "executed": s["executed"]}
            for s in shard_snaps]
        return summed
