"""Sashimi's distributed-calculation core (the paper's primary system).

Modules map to the paper as follows (see README.md for the full table):

  * ``tickets``        — §2.1.2 virtual-created-time ticket queue, plus the
                         Distributor v2 lease-batch / client-speed
                         extensions;
  * ``distributor``    — the TicketDistributor + HTTPServer analogue: v2 is
                         the asyncio adaptive scheduler, v1 the
                         thread-per-client baseline;
  * ``project``        — the Project / Task programming model from the
                         paper's appendix;
  * ``split_parallel`` — §4.1 split-training strategies and the dispatcher
                         wiring them onto the ticket scheduler;
  * ``shards``         — sharded ticket store (per-task shards, per-shard
                         locks, global min-VCT merge — beyond-paper);
  * ``federation``     — multi-distributor federation: home-shard members
                         with work-stealing plus the edge cache tier in
                         front of the origin HTTP store (beyond-paper);
  * ``transport``      — the cross-host wire protocol (length-prefixed
                         JSON frames, loopback server, remote clients
                         with reconnect-resume; spec in docs/PROTOCOL.md).
"""
