"""The Sashimi ticket queue — the paper's §2.1.2 algorithm, verbatim.

Tickets are served in ascending **virtual created time** (VCT):

  * an undistributed ticket's VCT is its creation time;
  * once distributed, its VCT becomes ``last_distributed_at + timeout``
    (paper: five minutes) — i.e. if no result arrives within the timeout the
    ticket sorts as if re-created and another client picks it up;
  * when no fresh tickets remain, distributed-but-unfinished tickets are
    *redistributed* in ascending last-distribution order, but never more
    often than ``redistribute_min`` (paper: ten seconds) per ticket — this
    prevents the last ticket from stampeding to every idle client.

The first result submitted for a ticket wins; duplicates are dropped.
Thread-safe; the clock is injectable so tests can run timeouts in
milliseconds.
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional


@dataclass
class Ticket:
    ticket_id: int
    task_name: str
    args: Any
    created_at: float
    distribute_count: int = 0
    last_distributed_at: float = -float("inf")
    completed: bool = False
    result: Any = None
    completed_by: Optional[str] = None
    error_reports: list = field(default_factory=list)

    def virtual_created_time(self, timeout: float) -> float:
        if self.distribute_count == 0:
            return self.created_at
        return self.last_distributed_at + timeout


class TicketQueue:
    def __init__(self, *, timeout: float = 300.0,
                 redistribute_min: float = 10.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.redistribute_min = redistribute_min
        self.clock = clock
        self._lock = threading.Lock()
        self._tickets: dict[int, Ticket] = {}
        self._ids = itertools.count()
        self._done = threading.Event()
        self._done.set()

    # -- producer side ------------------------------------------------------

    def add(self, task_name: str, args: Any) -> int:
        with self._lock:
            tid = next(self._ids)
            self._tickets[tid] = Ticket(tid, task_name, args, self.clock())
            self._done.clear()
            return tid

    def add_many(self, task_name: str, args_list) -> list[int]:
        return [self.add(task_name, a) for a in args_list]

    # -- distributor side ----------------------------------------------------

    def request(self) -> Optional[Ticket]:
        """Hand out the next ticket by ascending VCT (the paper's SQL query)."""
        now = self.clock()
        with self._lock:
            best = None
            best_key = None
            for t in self._tickets.values():
                if t.completed:
                    continue
                if (t.distribute_count > 0
                        and now - t.last_distributed_at
                        < self.redistribute_min):
                    continue  # min 10 s between redistributions
                key = (t.virtual_created_time(self.timeout), t.ticket_id)
                if best_key is None or key < best_key:
                    best, best_key = t, key
            if best is None:
                return None
            best.distribute_count += 1
            best.last_distributed_at = now
            return Ticket(best.ticket_id, best.task_name, best.args,
                          best.created_at, best.distribute_count,
                          best.last_distributed_at)

    def submit(self, ticket_id: int, result: Any, client: str = "?") -> bool:
        """Record a result; returns False for duplicates/unknown tickets."""
        with self._lock:
            t = self._tickets.get(ticket_id)
            if t is None or t.completed:
                return False
            t.completed = True
            t.result = result
            t.completed_by = client
            if all(x.completed for x in self._tickets.values()):
                self._done.set()
            return True

    def report_error(self, ticket_id: int, error: str, client: str = "?"):
        """Paper: error report incl. stack trace is sent, browser reloads."""
        with self._lock:
            t = self._tickets.get(ticket_id)
            if t is not None:
                t.error_reports.append((client, error))

    # -- introspection -------------------------------------------------------

    def wait_all(self, timeout: Optional[float] = None) -> bool:
        return self._done.wait(timeout)

    def results(self) -> dict[int, Any]:
        with self._lock:
            return {tid: t.result for tid, t in self._tickets.items()
                    if t.completed}

    def snapshot(self) -> dict:
        """The paper's control-console counters."""
        with self._lock:
            ts = list(self._tickets.values())
            return {
                "tickets": len(ts),
                "waiting": sum(1 for t in ts if not t.completed
                               and t.distribute_count == 0),
                "in_flight": sum(1 for t in ts if not t.completed
                                 and t.distribute_count > 0),
                "executed": sum(1 for t in ts if t.completed),
                "errors": sum(len(t.error_reports) for t in ts),
                "redistributions": sum(max(t.distribute_count - 1, 0)
                                       for t in ts),
            }

    def all_done(self) -> bool:
        return self._done.is_set()
