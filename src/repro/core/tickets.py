"""The Sashimi ticket queue — the paper's §2.1.2 algorithm, extended.

Tickets are served in ascending **virtual created time** (VCT):

  * an undistributed ticket's VCT is its creation time;
  * once distributed, its VCT becomes ``last_distributed_at + timeout``
    (paper: five minutes) — i.e. if no result arrives within the timeout the
    ticket sorts as if re-created and another client picks it up;
  * when no fresh tickets remain, distributed-but-unfinished tickets are
    *redistributed* in ascending last-distribution order, but never more
    often than ``redistribute_min`` (paper: ten seconds) per ticket — this
    prevents the last ticket from stampeding to every idle client.

The first result submitted for a ticket wins; duplicates are dropped.

Beyond the paper (Distributor v2 substrate), the queue also supports:

  * **lease batches** (`lease` / `submit_batch` / `release`): a client
    checks out up to N tickets in one round-trip.  Each batch gets a lease
    id; releasing a lease (client died, watchdog fired) resets its
    unfinished tickets so they sort as freshly created — *proactive*
    redistribution instead of waiting out the full timeout.
  * **client-speed metadata** (`ClientStats`): an EWMA of completed work
    per second per client, updated on every batch submit.  The scheduler
    uses it to size the next lease (slow clients get smaller shards).

Thread-safe; the clock is injectable so tests can run timeouts in
milliseconds (see ``docs/ARCHITECTURE.md`` §Injectable clock).
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
import collections
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.core.wire import ProtocolError


class _Cancelled:
    """Sentinel result for tickets force-completed by a barrier fold (the
    K-of-N straggler path): the round closed without them, so they must
    drain from the queue's bookkeeping without a real result."""

    def __repr__(self):
        return "<cancelled>"


#: The result recorded for a cancelled ticket (see TicketQueue.cancel).
CANCELLED = _Cancelled()


@dataclass
class Ticket:
    """One unit of distributable work (paper §2.1.1: a slice of a Task's
    arguments).  ``work`` is the nominal size of the slice in abstract work
    units; the adaptive scheduler uses it to meter client throughput."""

    ticket_id: int
    task_name: str
    args: Any
    created_at: float
    work: float = 1.0
    distribute_count: int = 0
    last_distributed_at: float = -float("inf")
    completed: bool = False
    result: Any = None
    completed_by: Optional[str] = None
    error_reports: list = field(default_factory=list)
    lease_id: Optional[int] = None
    # registry coherence version pinned at creation (see
    # HttpServerBase.task_version): a client executing this ticket must
    # hold task code + statics validated at >= this version, or
    # revalidate its cache first.  0 = unversioned (queue used directly,
    # without a registry — the seed behaviour).
    task_version: int = 0

    def virtual_created_time(self, timeout: float) -> float:
        """The paper's ordering key: creation time while fresh, then
        ``last_distributed_at + timeout`` once handed out."""
        if self.distribute_count == 0:
            return self.created_at
        return self.last_distributed_at + timeout

    def _copy_for_client(self) -> "Ticket":
        return Ticket(self.ticket_id, self.task_name, self.args,
                      self.created_at, self.work, self.distribute_count,
                      self.last_distributed_at, lease_id=self.lease_id,
                      task_version=self.task_version)

    # -- wire codec (docs/PROTOCOL.md) ---------------------------------------
    # Scheduling state (created_at / last_distributed_at / distribute_count)
    # is meaningful only on the distributor's clock and never crosses the
    # wire; a remote client needs exactly what it takes to execute the
    # ticket and submit its result.

    def to_wire(self, encode_args: Callable[[Any], Any]) -> dict:
        """The ticket as a JSON-safe dict for the transport layer.
        ``encode_args`` serialises ``args`` (opaque payload codec)."""
        return {"ticket_id": self.ticket_id, "task_name": self.task_name,
                "args": encode_args(self.args), "work": self.work,
                "task_version": self.task_version,
                "lease_id": self.lease_id}

    @classmethod
    def from_wire(cls, d: dict,
                  decode_args: Callable[[Any], Any]) -> "Ticket":
        """Rebuild a client-side ticket from its wire dict (inverse of
        :meth:`to_wire`; server-only scheduling fields default to zero).

        Wire dicts come from an untrusted peer: missing or mistyped
        fields raise ``ProtocolError("bad-message")`` instead of leaking
        KeyError/TypeError into the request loop."""
        if not isinstance(d, dict):
            raise ProtocolError("bad-message", "ticket must be an object")
        ticket_id = d.get("ticket_id")
        task_name = d.get("task_name")
        work = d.get("work")
        task_version = d.get("task_version", 0)
        if (not isinstance(ticket_id, int) or isinstance(ticket_id, bool)
                or not isinstance(task_name, str)
                or not isinstance(work, (int, float))
                or isinstance(work, bool)
                or not isinstance(task_version, int)
                or isinstance(task_version, bool)
                or "args" not in d):
            raise ProtocolError("bad-message",
                                f"malformed ticket fields: "
                                f"{sorted(d.keys())}")
        return cls(ticket_id, task_name, decode_args(d["args"]),
                   created_at=0.0, work=float(work),
                   lease_id=d.get("lease_id"),
                   task_version=task_version)


@dataclass
class ClientStats:
    """Per-client throughput metadata (Distributor v2).

    ``rate`` is an exponentially-weighted moving average of completed work
    units per second.  ``rate is None`` until the first observation — the
    scheduler treats unknown clients conservatively (probe lease).
    """

    name: str
    rate: Optional[float] = None      # EWMA work units / second
    alpha: float = 0.3                # EWMA smoothing factor
    completed_work: float = 0.0
    completed_tickets: int = 0
    leases: int = 0
    failures: int = 0

    def observe(self, work: float, duration: float, tickets: int = 1):
        """Fold one completed lease (``tickets`` tickets totalling ``work``
        units, finished in ``duration`` s) into the EWMA."""
        duration = max(duration, 1e-9)
        sample = work / duration
        self.rate = (sample if self.rate is None
                     else self.alpha * sample + (1 - self.alpha) * self.rate)
        self.completed_work += work
        self.completed_tickets += tickets

    @property
    def mean_ticket_work(self) -> float:
        """Average work units per completed ticket (1.0 until measured);
        converts the work-rate EWMA into ticket counts and back."""
        if self.completed_tickets <= 0:
            return 1.0
        return self.completed_work / self.completed_tickets


@dataclass
class LeaseBatch:
    """A batch of tickets checked out by one client in one round-trip."""

    lease_id: int
    client: str
    tickets: list                     # list[Ticket] (client-side copies)
    issued_at: float
    expected_duration: Optional[float] = None   # scheduler's ETA (watchdog)
    # shards the grant actually touched (set by ShardedTicketQueue.lease;
    # None for a plain TicketQueue).  A federation member uses it to count
    # a steal only when the batch really contains foreign-shard tickets.
    shards: Optional[list] = None

    @property
    def work(self) -> float:
        """Total work units in the batch (EWMA denominator)."""
        return sum(t.work for t in self.tickets)

    @property
    def ticket_ids(self) -> list:
        """Ids of the batched tickets, in lease order."""
        return [t.ticket_id for t in self.tickets]

    # -- wire codec (docs/PROTOCOL.md) ---------------------------------------

    def to_wire(self, encode_args) -> dict:
        """The lease as a JSON-safe ``lease_grant`` body: lease id, client,
        and the tickets' wire dicts.  ``issued_at``, ``expected_duration``
        and ``shards`` are distributor-side scheduling state and stay off
        the wire."""
        return {"lease_id": self.lease_id, "client": self.client,
                "tickets": [t.to_wire(encode_args) for t in self.tickets]}

    @classmethod
    def from_wire(cls, d: dict, decode_args) -> "LeaseBatch":
        """Rebuild a client-side lease from its wire dict (inverse of
        :meth:`to_wire`).  Malformed grants from an untrusted peer raise
        ``ProtocolError("bad-message")``, not bare KeyError/TypeError."""
        if not isinstance(d, dict):
            raise ProtocolError("bad-message",
                                "lease grant must be an object")
        lease_id = d.get("lease_id")
        client = d.get("client")
        tickets = d.get("tickets")
        if (not isinstance(lease_id, int) or isinstance(lease_id, bool)
                or not isinstance(client, str)
                or not isinstance(tickets, list)):
            raise ProtocolError("bad-message",
                                f"malformed lease grant fields: "
                                f"{sorted(d.keys())}")
        return cls(lease_id, client,
                   [Ticket.from_wire(t, decode_args) for t in tickets],
                   issued_at=0.0)


class TicketQueue:
    """Thread-safe VCT-ordered ticket store shared by Distributor v1/v2.

    Producer side: :meth:`add` / :meth:`add_many`.
    Client side (v1): :meth:`request` / :meth:`submit`.
    Client side (v2): :meth:`lease` / :meth:`submit_batch` / :meth:`release`.
    """

    def __init__(self, *, timeout: float = 300.0,
                 redistribute_min: float = 10.0,
                 clock: Callable[[], float] = time.monotonic,
                 tracer=None):
        self.timeout = timeout
        self.redistribute_min = redistribute_min
        self.clock = clock
        # optional repro.obs Tracer; every site below guards on
        # ``is not None`` so the disabled path costs one attribute check
        self.tracer = tracer
        self._ticket_spans: dict[int, int] = {}   # ticket_id -> span id
        self._lease_spans: dict[int, int] = {}    # lease_id -> span id
        self._lock = threading.Lock()
        self._tickets: dict[int, Ticket] = {}
        self._ids = itertools.count()
        self._lease_ids = itertools.count()
        self._leases: dict[int, LeaseBatch] = {}
        self._lease_outstanding: dict[int, set] = {}
        self._ticket_leases: dict[int, set] = {}   # reverse index
        # released leases kept (bounded) so a LATE submit from a
        # slower-than-expected client still calibrates its EWMA
        self._released_leases: "collections.OrderedDict[int, LeaseBatch]" = \
            collections.OrderedDict()
        self.stats: dict[str, ClientStats] = {}
        self.releases = 0
        # submits for an already-completed ticket (racing redistributed
        # leases; first result won) — dropped, but counted so the SLO
        # "zero duplicated results reach training math" is checkable
        self.duplicates = 0
        self._incomplete = 0      # live not-yet-completed ticket count
        self._done = threading.Event()
        self._done.set()

    # -- producer side ------------------------------------------------------

    def add(self, task_name: str, args: Any, *, work: float = 1.0,
            task_version: int = 0) -> int:
        """Enqueue one ticket; returns its id.  ``task_version`` pins the
        registry coherence version the ticket was created against (0 when
        the queue is used without a registry)."""
        with self._lock:
            now = self.clock()
            tid = next(self._ids)
            self._tickets[tid] = Ticket(tid, task_name, args, now,
                                        work=work, task_version=task_version)
            self._incomplete += 1
            self._done.clear()
            if self.tracer is not None:
                self._ticket_spans[tid] = self.tracer.begin(
                    "ticket", track="queue", cat="ticket", ts=now,
                    args={"ticket": tid, "task": task_name})
            return tid

    def add_many(self, task_name: str, args_list, *,
                 work=1.0, task_version: int = 0) -> list[int]:
        """Enqueue one ticket per element of ``args_list``; ``work`` is a
        scalar applied to all, or a per-ticket sequence.

        One locked bulk insert — the whole batch lands atomically, so a
        consumer can never lease the front of a batch while a producer is
        still appending its tail."""
        args_list = list(args_list)
        works = (list(work) if isinstance(work, (list, tuple))
                 else [work] * len(args_list))
        if not args_list:
            return []
        with self._lock:
            now = self.clock()
            tids = []
            for a, w in zip(args_list, works):
                tid = next(self._ids)
                self._tickets[tid] = Ticket(tid, task_name, a, now, work=w,
                                            task_version=task_version)
                tids.append(tid)
            if self.tracer is not None:
                self._ticket_spans.update(zip(tids, self.tracer.begin_many(
                    "ticket", [{"ticket": t, "task": task_name}
                               for t in tids],
                    track="queue", cat="ticket", ts=now)))
            self._incomplete += len(tids)
            self._done.clear()
            return tids

    # -- selection core ------------------------------------------------------

    def _eligible_sorted(self, now: float, limit: int) -> list[Ticket]:
        """Up to ``limit`` eligible tickets in ascending-VCT order.

        Caller must hold the lock.  Eligibility follows the paper: not
        completed, and either never distributed or last distributed at least
        ``redistribute_min`` seconds ago."""
        eligible = (
            (t.virtual_created_time(self.timeout), t.ticket_id, t)
            for t in self._tickets.values()
            if not t.completed
            and (t.distribute_count == 0
                 or now - t.last_distributed_at >= self.redistribute_min))
        if limit == 1:                       # v1 hot path: single min scan
            best = min(eligible, default=None)
            return [best[2]] if best is not None else []
        return [t for _, _, t in heapq.nsmallest(limit, eligible)]

    def peek_eligible(self, limit: int,
                      now: Optional[float] = None) -> list[tuple]:
        """Up to ``limit`` eligible tickets as ``(vct, ticket_id)`` pairs in
        ascending-VCT order, *without* checking anything out.

        The queue-of-queues merge (``ShardedTicketQueue``) peeks every
        shard's head, merges globally, and then checks out the winners with
        :meth:`lease_tickets` — the two-step protocol that preserves the
        paper's global ascending-VCT rule across shards."""
        if now is None:
            now = self.clock()
        with self._lock:
            return [(t.virtual_created_time(self.timeout), t.ticket_id)
                    for t in self._eligible_sorted(now, limit)]

    # -- distributor side, v1 single-ticket API ------------------------------

    def request(self) -> Optional[Ticket]:
        """Hand out the next ticket by ascending VCT (the paper's SQL
        query).  Returns a client-side copy, or None if nothing is
        currently eligible."""
        now = self.clock()
        with self._lock:
            best = next(iter(self._eligible_sorted(now, 1)), None)
            if best is None:
                return None
            best.distribute_count += 1
            best.last_distributed_at = now
            return best._copy_for_client()

    def submit(self, ticket_id: int, result: Any, client: str = "?") -> bool:
        """Record a result; returns False for duplicates/unknown tickets."""
        with self._lock:
            return self._submit_locked(ticket_id, result, client)

    def _submit_locked(self, ticket_id: int, result: Any,
                       client: str) -> bool:
        t = self._tickets.get(ticket_id)
        if t is None or t.completed:
            if t is not None:
                self.duplicates += 1
            return False
        t.completed = True
        t.result = result
        t.completed_by = client
        # Drop the ticket from every lease still tracking it (a ticket can
        # sit in several leases after redistribution; the reverse index
        # makes this O(leases holding THIS ticket), almost always 1); GC
        # drained leases so the watchdog never "releases" a lease of
        # completed tickets.
        drained = []
        for lid in self._ticket_leases.pop(ticket_id, ()):
            outstanding = self._lease_outstanding.get(lid)
            if outstanding is None:
                continue
            outstanding.discard(ticket_id)
            if not outstanding:
                self._lease_outstanding.pop(lid, None)
                self._leases.pop(lid, None)
                drained.append(lid)
        self._incomplete -= 1      # O(1) done check (no full-queue scan)
        if self._incomplete == 0:
            self._done.set()
        if self.tracer is not None:
            now = self.clock()
            self.tracer.end(
                self._ticket_spans.pop(ticket_id, None), ts=now,
                args={"status": ("cancelled" if result is CANCELLED
                                 else "ok"),
                      "client": client})
            for lid in drained:
                self.tracer.end(self._lease_spans.pop(lid, None), ts=now,
                                args={"status": "drained"})
        return True

    # -- distributor side, v2 batched-lease API ------------------------------

    def lease(self, client: str, max_tickets: int = 1,
              *, expected_duration: Optional[float] = None
              ) -> Optional[LeaseBatch]:
        """Check out up to ``max_tickets`` tickets (ascending VCT) as one
        lease.  Returns None when nothing is eligible right now."""
        now = self.clock()
        with self._lock:
            picked = self._eligible_sorted(now, max_tickets)
            if not picked:
                return None
            return self._checkout_locked(picked, client,
                                         next(self._lease_ids), now,
                                         expected_duration, observe=True)

    def _checkout_locked(self, picked: list[Ticket], client: str,
                         lease_id: int, now: float,
                         expected_duration: Optional[float],
                         observe: bool) -> LeaseBatch:
        """Hand out ``picked`` tickets as one lease (caller holds the lock).
        ``observe=False`` skips the per-client lease counter — the sharded
        queue books stats once globally, not once per member shard."""
        copies = []
        for t in picked:
            t.distribute_count += 1
            t.last_distributed_at = now
            t.lease_id = lease_id
            self._ticket_leases.setdefault(t.ticket_id, set()).add(lease_id)
            copies.append(t._copy_for_client())
        batch = LeaseBatch(lease_id, client, copies, now,
                           expected_duration=expected_duration)
        self._leases[lease_id] = batch
        self._lease_outstanding[lease_id] = {t.ticket_id for t in picked}
        if observe:
            self.stats.setdefault(client, ClientStats(client)).leases += 1
            # the sharded store (observe=False per member shard) traces
            # its cross-shard lease once at store level instead
            if self.tracer is not None:
                self._lease_spans[lease_id] = self.tracer.begin(
                    "lease", track="queue", cat="lease", ts=now,
                    args={"lease": lease_id, "client": client,
                          "tickets": len(picked)})
        return batch

    def lease_tickets(self, client: str, ticket_ids, *, lease_id: int,
                      now: Optional[float] = None,
                      expected_duration: Optional[float] = None,
                      observe: bool = True) -> Optional[LeaseBatch]:
        """Check out *specific* tickets (by id) under an externally supplied
        ``lease_id`` — the sharded queue's half of the peek/checkout
        protocol.  Tickets that have meanwhile completed or slipped back
        into their cool-down are silently skipped (another client raced us
        between peek and checkout); returns None when nothing survives."""
        if now is None:
            now = self.clock()
        with self._lock:
            picked = []
            for tid in ticket_ids:
                t = self._tickets.get(tid)
                if (t is not None and not t.completed
                        and (t.distribute_count == 0
                             or now - t.last_distributed_at
                             >= self.redistribute_min)):
                    picked.append(t)
            if not picked:
                return None
            return self._checkout_locked(picked, client, lease_id, now,
                                         expected_duration, observe)

    def submit_batch(self, lease_id: int, results: dict,
                     client: str = "?") -> int:
        """Record results for a lease ({ticket_id: result}); updates the
        client's EWMA throughput.  Returns how many results were accepted
        (duplicates from racing redistributed leases are dropped)."""
        return self.submit_batch_ex(lease_id, results, client)[0]

    def submit_batch_ex(self, lease_id: int, results: dict,
                        client: str = "?", *,
                        observe: bool = True) -> tuple[int, float]:
        """:meth:`submit_batch` returning ``(accepted, accepted_work)``.
        ``observe=False`` skips the EWMA update — the sharded queue submits
        a lease's results shard by shard but must fold exactly ONE
        (full-work, full-duration) sample into the client's rate."""
        now = self.clock()
        with self._lock:
            # grab the batch first: _submit_locked GCs drained leases; a
            # watchdog-released lease is still good for the EWMA sample
            batch = (self._leases.get(lease_id)
                     or self._released_leases.pop(lease_id, None))
            accepted_work = 0.0
            accepted = 0
            for tid, result in results.items():
                t = self._tickets.get(tid)
                if t is not None and not t.completed:
                    accepted_work += t.work
                    accepted += self._submit_locked(tid, result, client)
            if observe:
                stats = self.stats.setdefault(client, ClientStats(client))
                if batch is not None and accepted:
                    stats.observe(accepted_work, now - batch.issued_at,
                                  tickets=accepted)
            return accepted, accepted_work

    def release(self, lease_id: int, *, client_failed: bool = False,
                reset_vct: bool = True) -> int:
        """Return a lease's unfinished tickets to the queue *now*.

        Used when a client dies mid-lease or the watchdog deems the lease
        overdue (proactive redistribution).  With ``reset_vct`` (default)
        the tickets sort as freshly created rather than waiting out the
        full timeout; pass ``reset_vct=False`` to drop only the lease
        bookkeeping and keep the paper's redistribute_min cool-down (the
        error-retry path, so a deterministically failing task can't hot-
        loop).  Tickets meanwhile re-leased to ANOTHER client are left
        untouched.  Returns the number of tickets returned to the queue."""
        with self._lock:
            outstanding = self._lease_outstanding.pop(lease_id, set())
            batch = self._leases.pop(lease_id, None)
            released = 0
            for tid in outstanding:
                held_by = self._ticket_leases.get(tid)
                if held_by is not None:
                    held_by.discard(lease_id)
                    if not held_by:
                        self._ticket_leases.pop(tid, None)
                t = self._tickets.get(tid)
                if t is None or t.completed:
                    continue
                if t.lease_id is not None and t.lease_id != lease_id:
                    continue  # an active newer lease owns it now
                if reset_vct:
                    # VCT = last_distributed_at + timeout == created_at
                    t.last_distributed_at = t.created_at - self.timeout
                t.lease_id = None
                released += 1
            if released:
                self.releases += 1
            if self.tracer is not None and batch is not None:
                self.tracer.end(
                    self._lease_spans.pop(lease_id, None), ts=self.clock(),
                    args={"status": "released", "released": released,
                          "client_failed": client_failed,
                          "reset_vct": reset_vct})
            if batch is not None:
                self._released_leases[lease_id] = batch
                while len(self._released_leases) > 256:
                    self._released_leases.popitem(last=False)
                if client_failed:
                    self.stats.setdefault(
                        batch.client, ClientStats(batch.client)).failures += 1
            return released

    def cancel(self, ticket_ids) -> int:
        """Force-complete tickets with the :data:`CANCELLED` sentinel (the
        K-of-N barrier's fold path: a round closed without its stragglers).

        The tickets drain from every lease and from the done-accounting
        exactly as a real submit would, so watchdogs stop patrolling them
        and ``all_done`` can flip; a straggler's own submit arriving later
        is dropped as a duplicate.  Already-completed or unknown ids are
        skipped.  Returns how many tickets were cancelled."""
        with self._lock:
            return sum(self._submit_locked(tid, CANCELLED, "cancelled")
                       for tid in ticket_ids)

    def completed_results(self, ticket_ids) -> dict:
        """{ticket_id: result} for the subset of ``ticket_ids`` already
        completed — the partial-progress probe a K-of-N round barrier
        polls (contrast :meth:`results_for`, which is all-or-nothing)."""
        with self._lock:
            out = {}
            for tid in ticket_ids:
                t = self._tickets.get(tid)
                if t is not None and t.completed:
                    out[tid] = t.result
            return out

    def seconds_until_eligible(self) -> Optional[float]:
        """Time until the next in-cool-down ticket becomes leasable, or
        None when no unfinished distributed ticket is cooling down.  Lets
        an idle client park for exactly the remaining cool-down instead of
        a full redistribute_min."""
        now = self.clock()
        with self._lock:
            best = None
            for t in self._tickets.values():
                if t.completed or t.distribute_count == 0:
                    continue
                remaining = self.redistribute_min - (
                    now - t.last_distributed_at)
                if remaining <= 0:
                    return 0.0
                if best is None or remaining < best:
                    best = remaining
            return best

    def outstanding_leases(self) -> list[LeaseBatch]:
        """Leases with at least one unfinished ticket (watchdog input)."""
        with self._lock:
            return [b for lid, b in self._leases.items()
                    if self._lease_outstanding.get(lid)]

    def lease_is_outstanding(self, lease_id: int) -> bool:
        """True while the lease still has unfinished, unreleased tickets
        in THIS queue (the sharded queue polls its member shards to decide
        when a cross-shard lease has fully drained)."""
        with self._lock:
            return bool(self._lease_outstanding.get(lease_id))

    def results_for(self, ticket_ids) -> Optional[list]:
        """Results for exactly ``ticket_ids`` (in order), or None if any is
        still unfinished.  O(len(ticket_ids)) — use instead of copying the
        whole :meth:`results` dict when polling a round."""
        with self._lock:
            out = []
            for tid in ticket_ids:
                t = self._tickets.get(tid)
                if t is None or not t.completed:
                    return None
                out.append(t.result)
            return out

    def prune(self, ticket_ids) -> int:
        """Forget completed tickets (long-running producers: drop finished
        rounds so lease scans and memory don't grow with history).
        Unfinished tickets are left alone; returns how many were pruned."""
        return len(self.prune_ex(ticket_ids))

    def prune_ex(self, ticket_ids) -> list:
        """:meth:`prune` returning the ids actually pruned — the sharded
        store needs them to batch its routing-table cleanup into one
        ``_meta_lock`` acquisition instead of one per ticket."""
        with self._lock:
            pruned = []
            for tid in ticket_ids:
                t = self._tickets.get(tid)
                if t is not None and t.completed:
                    del self._tickets[tid]
                    self._ticket_leases.pop(tid, None)
                    pruned.append(tid)
            return pruned

    def report_error(self, ticket_id: int, error: str, client: str = "?"):
        """Paper: error report incl. stack trace is sent, browser reloads."""
        with self._lock:
            t = self._tickets.get(ticket_id)
            if t is not None:
                t.error_reports.append((client, error))

    # -- introspection -------------------------------------------------------

    def wait_all(self, timeout: Optional[float] = None) -> bool:
        """Block until every ticket has a result (or ``timeout`` elapses)."""
        return self._done.wait(timeout)

    def results(self) -> dict[int, Any]:
        """{ticket_id: result} for every completed ticket."""
        with self._lock:
            return {tid: t.result for tid, t in self._tickets.items()
                    if t.completed}

    def snapshot(self) -> dict:
        """The paper's control-console counters."""
        with self._lock:
            ts = list(self._tickets.values())
            return {
                "tickets": len(ts),
                "waiting": sum(1 for t in ts if not t.completed
                               and t.distribute_count == 0),
                "in_flight": sum(1 for t in ts if not t.completed
                                 and t.distribute_count > 0),
                "executed": sum(1 for t in ts if t.completed),
                "errors": sum(len(t.error_reports) for t in ts),
                "redistributions": sum(max(t.distribute_count - 1, 0)
                                       for t in ts),
                "lease_releases": self.releases,
                "duplicates": self.duplicates,
                "clients": {
                    name: {"rate": s.rate, "leases": s.leases,
                           "completed": s.completed_tickets,
                           "failures": s.failures}
                    for name, s in self.stats.items()},
            }

    def all_done(self) -> bool:
        """True when every ticket has a result."""
        return self._done.is_set()
