"""Protocol fuzzing: adversarial bytes against every v2 decoder.

The distributor listens for anonymous browsers, so the frame reader, the
chunk state machine, the binary-manifest decoder and the ticket codecs
are all adversarial-input territory.  The contract under fuzz:

  * every malformed input raises :class:`ProtocolError` with a code from
    the documented table (docs/PROTOCOL.md) — never a bare ValueError,
    never a hang (each case runs under a hard ``asyncio.wait_for``);
  * no decoder allocates based on an unchecked size field: oversized
    declarations are rejected from the header alone, before any payload
    bytes are read or buffered.

Runs under real `hypothesis` (CI) or the deterministic shim.
"""
import asyncio
import json
import struct

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.tickets import LeaseBatch, Ticket
from repro.core.transport import (CHUNK_FLAG, MAX_BLOB_CHUNKS,
                                  ProtocolError, build_blob_frames,
                                  encode_chunk, encode_frame, read_frame_ex,
                                  read_message)
from repro.core.wire import decode_binary, encode_binary

#: every code a *decoder* (reader / manifest / ticket codec) may raise.
#: Keep in sync with the error table in docs/PROTOCOL.md — the docs test
#: checks the reverse direction (each code in the source is documented).
DECODER_CODES = {
    "bad-json", "bad-message", "truncated-frame", "frame-too-large",
    "unexpected-chunk", "chunk-mismatch", "bad-blob", "blob-too-large",
    "bad-manifest",
}


def _reader(*chunks: bytes) -> asyncio.StreamReader:
    # must be constructed inside a running loop (asyncio.StreamReader
    # binds the current event loop) — call only from within _decode
    r = asyncio.StreamReader()
    for c in chunks:
        r.feed_data(c)
    r.feed_eof()
    return r


def _decode(make_coro):
    """Run ``make_coro()`` (a thunk building the reader + coroutine inside
    the loop) under a hard deadline: garbage must produce a ProtocolError
    (or clean EOF), never a hang or another exception."""
    async def go():
        return await asyncio.wait_for(make_coro(), timeout=5.0)
    return asyncio.run(go())


def expect_code(make_coro, codes):
    with pytest.raises(ProtocolError) as ei:
        _decode(make_coro)
    assert ei.value.code in codes, ei.value
    return ei.value.code


# ---------------------------------------------------------------------------
# random garbage against the frame reader
# ---------------------------------------------------------------------------


@settings(max_examples=200, deadline=None)
@given(st.binary(min_size=0, max_size=200))
def test_fuzz_read_message_never_hangs_or_leaks_exceptions(data):
    async def go():
        return await asyncio.wait_for(
            read_message(_reader(data), max_bytes=1 << 16,
                         max_blob_bytes=1 << 16), timeout=5.0)
    try:
        msg, n = asyncio.run(go())
    except ProtocolError as e:
        assert e.code in DECODER_CODES, e
    else:
        # random bytes that happen to parse must be a legal message
        assert msg is None or (isinstance(msg, dict)
                               and isinstance(msg["type"], str))


@settings(max_examples=120, deadline=None)
@given(st.binary(min_size=4, max_size=64),
       st.integers(min_value=0, max_value=0xFFFFFFFF))
def test_fuzz_header_prefix_with_random_length(tail, length):
    """A syntactically valid 4-byte length header followed by arbitrary
    bytes: rejected from the header alone when oversized, else either a
    decode error or truncation — never a hang."""
    raw = struct.pack(">I", length) + tail
    max_bytes = 1 << 12
    async def go():
        return await asyncio.wait_for(
            read_message(_reader(raw), max_bytes=max_bytes,
                         max_blob_bytes=1 << 16), timeout=5.0)
    try:
        asyncio.run(go())
    except ProtocolError as e:
        assert e.code in DECODER_CODES, e
        if (length & (CHUNK_FLAG - 1)) > max_bytes:
            assert e.code == "frame-too-large"


def test_length_field_overflow_rejected_before_read():
    """All-ones length header: the chunk flag is masked out first, and
    the remaining 2^31-1 length still exceeds max_bytes — rejected
    without buffering anything."""
    code = expect_code(
        lambda: read_message(_reader(b"\xff\xff\xff\xff" + b"x" * 64),
                             max_bytes=1024), {"frame-too-large"})
    assert code == "frame-too-large"


@pytest.mark.parametrize("raw", [
    b"\x00",                                  # EOF inside length header
    b"\x00\x00\x00\x10{\"ty",                 # EOF inside JSON body
    struct.pack(">I", CHUNK_FLAG | 8) + b"abc",   # EOF inside chunk body
])
def test_truncated_frames_raise(raw):
    expect_code(lambda: read_frame_ex(_reader(raw), allow_chunk=True),
                {"truncated-frame"})


def test_chunk_frame_outside_blob_rejected():
    expect_code(lambda: read_message(_reader(encode_chunk(b"orphan"))),
                {"unexpected-chunk"})


# ---------------------------------------------------------------------------
# the chunk state machine
# ---------------------------------------------------------------------------


def _blob_msg(**over):
    msg = {"type": "submit", "seq": 1, "chunks": 2, "blob_bytes": 8}
    msg.update(over)
    return msg


def test_blob_roundtrip_through_reader():
    frames = build_blob_frames({"type": "submit", "seq": 9}, b"x" * 100,
                               chunk_bytes=7)
    msg, n = _decode(lambda: read_message(_reader(*frames)))
    assert msg["_blob"] == b"x" * 100
    assert msg["chunks"] == -(-100 // 7)
    assert n == sum(len(f) for f in frames)


def test_blob_too_large_rejected_before_chunks_read():
    # header alone: no chunk frames are even fed, yet the error is the
    # cap violation, not a truncation — proof nothing was buffered first
    expect_code(
        lambda: read_message(_reader(encode_frame(
            _blob_msg(blob_bytes=1 << 20))), max_blob_bytes=1 << 10),
        {"blob-too-large"})


@pytest.mark.parametrize("decl", [
    {"chunks": 0}, {"chunks": -1}, {"chunks": True},
    {"chunks": MAX_BLOB_CHUNKS + 1}, {"chunks": "2"},
    {"blob_bytes": -1}, {"blob_bytes": "8"}, {"blob_bytes": None},
    {"chunks": None, "blob_bytes": None},
])
def test_bad_chunk_declarations_rejected(decl):
    expect_code(lambda: read_message(_reader(encode_frame(
        _blob_msg(**decl)))), {"bad-blob"})


def test_eof_mid_blob_is_truncation():
    expect_code(lambda: read_message(_reader(
        encode_frame(_blob_msg()), encode_chunk(b"1234"))),
        {"truncated-frame"})


def test_json_frame_where_chunk_expected():
    expect_code(lambda: read_message(_reader(
        encode_frame(_blob_msg()), encode_frame({"type": "sneak"}))),
        {"chunk-mismatch"})


def test_chunk_overrun_rejected():
    expect_code(lambda: read_message(_reader(
        encode_frame(_blob_msg()), encode_chunk(b"123456"),
        encode_chunk(b"123456"))), {"bad-blob"})


def test_chunk_underrun_rejected():
    expect_code(lambda: read_message(_reader(
        encode_frame(_blob_msg()), encode_chunk(b"12"),
        encode_chunk(b"34"))), {"bad-blob"})


def test_chunked_message_rejected_on_v1_connection():
    frames = build_blob_frames({"type": "submit", "seq": 1}, b"x" * 8)
    expect_code(lambda: read_message(_reader(*frames),
                                     allow_chunks=False), {"bad-blob"})


@settings(max_examples=60, deadline=None)
@given(st.binary(min_size=0, max_size=128), st.integers(1, 5))
def test_fuzz_blob_reassembly_identity(payload, chunk_bytes):
    """Well-formed chunked messages always reassemble exactly."""
    frames = build_blob_frames({"type": "t", "seq": 1}, payload,
                               chunk_bytes=chunk_bytes)
    msg, _ = _decode(lambda: read_message(_reader(*frames)))
    assert msg.get("_blob", b"") == payload


# ---------------------------------------------------------------------------
# binary-manifest decoding
# ---------------------------------------------------------------------------


def _good_manifest():
    manifest, buffer = encode_binary({"a": __import__("numpy")
                                      .zeros((2, 3), "float32")})
    return json.loads(json.dumps(manifest)), buffer


@pytest.mark.parametrize("mutate", [
    lambda m: "not a dict",
    lambda m: {},
    lambda m: {**m, "arrays": "nope"},
    lambda m: {**m, "rest": 42},
    lambda m: {**m, "rest": "!!! not base64 !!!"},
    lambda m: {**m, "rest": "YWJj"},                  # b"abc": not a pickle
    lambda m: {**m, "arrays": [{}]},
    lambda m: {**m, "arrays": ["x"]},
    lambda m: {**m, "arrays": [{**m["arrays"][0], "dtype": "object"}]},
    lambda m: {**m, "arrays": [{**m["arrays"][0], "dtype": "no-such"}]},
    lambda m: {**m, "arrays": [{**m["arrays"][0], "dtype": 7}]},
    lambda m: {**m, "arrays": [{**m["arrays"][0], "shape": [-1, 6]}]},
    lambda m: {**m, "arrays": [{**m["arrays"][0], "shape": [2, True]}]},
    lambda m: {**m, "arrays": [{**m["arrays"][0], "shape": [1] * 64}]},
    lambda m: {**m, "arrays": [{**m["arrays"][0], "nbytes": 999}]},
    lambda m: {**m, "arrays": [{**m["arrays"][0], "nbytes": True}]},
    lambda m: {**m, "arrays": m["arrays"] * 2},       # extent overrun
    lambda m: {**m, "arrays": []},                    # trailing bytes
])
def test_manifest_mutations_rejected(mutate):
    manifest, buffer = _good_manifest()
    with pytest.raises(ProtocolError) as ei:
        decode_binary(mutate(manifest), buffer)
    assert ei.value.code == "bad-manifest"


def test_manifest_huge_nbytes_rejected_without_allocation():
    """A declared extent of ~2^40 bytes must be rejected by arithmetic
    comparison against the actual buffer, never allocated."""
    n = 1 << 40
    manifest = {"arrays": [{"dtype": "float64", "shape": [n // 8],
                            "nbytes": n}],
                "rest": _good_manifest()[0]["rest"]}
    with pytest.raises(ProtocolError) as ei:
        decode_binary(manifest, b"tiny")
    assert ei.value.code == "bad-manifest"


def test_manifest_array_count_cap():
    manifest, buffer = _good_manifest()
    entry = {"dtype": "float32", "shape": [0], "nbytes": 0}
    manifest["arrays"] = [entry] * ((1 << 16) + 1)
    with pytest.raises(ProtocolError) as ei:
        decode_binary(manifest, b"")
    assert ei.value.code == "bad-manifest"


@settings(max_examples=100, deadline=None)
@given(st.binary(min_size=0, max_size=64))
def test_fuzz_manifest_buffer_mismatch(junk):
    """A valid manifest over the wrong buffer either decodes (exact size
    match by construction) or raises bad-manifest — never crashes."""
    manifest, buffer = _good_manifest()
    if len(junk) == len(buffer):
        return                                         # would be valid
    with pytest.raises(ProtocolError) as ei:
        decode_binary(manifest, junk)
    assert ei.value.code == "bad-manifest"


# ---------------------------------------------------------------------------
# ticket / lease codecs
# ---------------------------------------------------------------------------


def _noop_decode(s):
    return s


@pytest.mark.parametrize("d", [
    {},
    {"ticket_id": "7", "task_name": "t", "work": 1, "task_version": 0,
     "args": "x"},
    {"ticket_id": True, "task_name": "t", "work": 1, "task_version": 0,
     "args": "x"},
    {"ticket_id": 7, "task_name": 3, "work": 1, "task_version": 0,
     "args": "x"},
    {"ticket_id": 7, "task_name": "t", "work": "fast", "task_version": 0,
     "args": "x"},
    {"ticket_id": 7, "task_name": "t", "work": 1, "task_version": "0",
     "args": "x"},
    {"ticket_id": 7, "task_name": "t", "work": 1, "task_version": 0},
])
def test_ticket_from_wire_rejects_malformed(d):
    with pytest.raises(ProtocolError) as ei:
        Ticket.from_wire(d, _noop_decode)
    assert ei.value.code == "bad-message"


@pytest.mark.parametrize("d", [
    {},
    {"lease_id": "9", "client": "c", "tickets": []},
    {"lease_id": 9, "client": 0, "tickets": []},
    {"lease_id": 9, "client": "c", "tickets": "nope"},
    {"lease_id": 9, "client": "c", "tickets": [{"ticket_id": "bad"}]},
])
def test_lease_batch_from_wire_rejects_malformed(d):
    with pytest.raises(ProtocolError) as ei:
        LeaseBatch.from_wire(d, _noop_decode)
    assert ei.value.code == "bad-message"


@settings(max_examples=100, deadline=None)
@given(st.lists(st.one_of(st.integers(-5, 5), st.booleans(),
                          st.just(None), st.binary(max_size=8)),
                min_size=0, max_size=4))
def test_fuzz_ticket_codec_random_field_soup(soup):
    """Random JSON-ish values thrown at every ticket field: the codec
    either builds a Ticket (all fields happened to be well-typed) or
    raises bad-message — nothing else escapes."""
    keys = ["ticket_id", "task_name", "work", "task_version", "args"]
    d = dict(zip(keys, soup))
    try:
        t = Ticket.from_wire(d, _noop_decode)
    except ProtocolError as e:
        assert e.code == "bad-message"
    else:
        assert isinstance(t.ticket_id, int)


# ---------------------------------------------------------------------------
# heartbeat / busy (browser-scale churn messages)
# ---------------------------------------------------------------------------


def _square(x, static):
    return x * x


def _live_server(**server_kw):
    """An AsyncDistributor with one leasable ticket behind a
    TransportServer, for raw-socket pokes at the stateful handlers the
    decoder-level fuzz above can't reach."""
    from repro.core.distributor import (AsyncDistributor, FixedSizer,
                                        TaskDef)
    from repro.core.transport import TransportServer
    d = AsyncDistributor(timeout=20.0, redistribute_min=0.0,
                         sizer=FixedSizer(1), watchdog_interval=5.0,
                         grace=1000.0)
    d.register_task(TaskDef("sq", _square))
    d.add_work("sq", [3])
    return d, TransportServer(d, **server_kw)


async def _dial(addr, *msgs):
    """Open a raw connection, write ``msgs`` as frames, return
    (reader, writer)."""
    reader, writer = await asyncio.open_connection(*addr)
    for m in msgs:
        writer.write(encode_frame(m))
    await writer.drain()
    return reader, writer


def test_heartbeat_before_hello_rejected():
    """A heartbeat is NOT a handshake: pre-hello it gets the same
    bad-handshake error as any other premature frame."""
    from repro.core.transport import read_frame

    async def go():
        d, server = _live_server()
        addr = await server.start()
        reader, writer = await _dial(addr, {"type": "heartbeat", "seq": 1})
        reply = await asyncio.wait_for(read_frame(reader), timeout=5.0)
        writer.close()
        await server.stop()
        return reply

    reply = asyncio.run(go())
    assert reply["type"] == "error" and reply["code"] == "bad-handshake"


def test_heartbeat_garbage_fields_still_heartbeat_ok():
    """Heartbeats are liveness-only: junk lease ids, wrong-typed extras
    and unknown fields never error a connection — every variant answers
    ``heartbeat_ok`` with the seq echoed."""
    from repro.core.transport import PROTOCOL_VERSION, read_frame
    variants = [
        {},                                 # bare
        {"lease_id": 999999},               # unknown lease
        {"lease_id": "not-an-int"},         # mistyped lease
        {"lease_id": None, "junk": [1, 2]},
        {"client": True, "proto": -9},      # handshake fields replayed
        {"results": {"1": "stale"}},        # submit fields smuggled in
    ]

    async def go():
        d, server = _live_server()
        addr = await server.start()
        reader, writer = await _dial(
            addr, {"type": "hello", "seq": 1, "client": "hb",
                   "proto": PROTOCOL_VERSION})
        hello = await asyncio.wait_for(read_frame(reader), timeout=5.0)
        assert hello["type"] == "hello_ok"
        replies = []
        for seq, extra in enumerate(variants, start=2):
            writer.write(encode_frame(
                {"type": "heartbeat", "seq": seq, **extra}))
            await writer.drain()
            replies.append(await asyncio.wait_for(read_frame(reader),
                                                  timeout=5.0))
        writer.close()
        stats = server.stats()
        await server.stop()
        return replies, stats

    replies, stats = asyncio.run(go())
    for seq, reply in enumerate(replies, start=2):
        assert reply == {"type": "heartbeat_ok", "seq": seq}
    assert stats["heartbeats"] == len(replies)


def test_replayed_heartbeat_after_eviction_is_inert():
    """An evicted client reconnecting and replaying heartbeats for its
    force-released lease gets ``heartbeat_ok`` (liveness for the NEW
    connection) but the old lease stays released — a heartbeat can never
    resurrect evicted work."""
    from repro.core.transport import PROTOCOL_VERSION, read_frame

    async def go():
        d, server = _live_server(heartbeat_timeout=5.0)
        addr = await server.start()
        reader, writer = await _dial(
            addr, {"type": "hello", "seq": 1, "client": "zombie",
                   "proto": PROTOCOL_VERSION},
            {"type": "lease_request", "seq": 2})
        await asyncio.wait_for(read_frame(reader), timeout=5.0)
        grant = await asyncio.wait_for(read_frame(reader), timeout=5.0)
        lease_id = grant["lease_id"]
        released = await server.evict_client("zombie")
        writer.close()
        r2, w2 = await _dial(
            addr, {"type": "hello", "seq": 5, "client": "zombie",
                   "proto": PROTOCOL_VERSION},
            {"type": "heartbeat", "seq": 6, "lease_id": lease_id},
            {"type": "heartbeat", "seq": 7, "lease_id": lease_id})
        replies = [await asyncio.wait_for(read_frame(r2), timeout=5.0)
                   for _ in range(3)]
        w2.close()
        outstanding = d.queue.lease_is_outstanding(lease_id)
        await server.stop()
        return released, replies, outstanding

    released, replies, outstanding = asyncio.run(go())
    assert released == 1
    assert replies[0]["type"] == "hello_ok"
    assert replies[1] == {"type": "heartbeat_ok", "seq": 6}
    assert replies[2] == {"type": "heartbeat_ok", "seq": 7}
    assert not outstanding                 # the lease stayed evicted


@settings(max_examples=150, deadline=None)
@given(st.one_of(st.integers(-10, 10), st.booleans(), st.just(None),
                 st.just(float("nan")), st.just(float("inf")),
                 st.floats(min_value=-5.0, max_value=200.0),
                 st.binary(max_size=8),
                 st.lists(st.integers(0, 3), max_size=2)))
def test_fuzz_parse_retry_after_total(value):
    """``parse_retry_after`` over junk: always a finite float in
    [0, cap]; non-numeric / bool / NaN / negative fall back to the
    caller's default, numeric values clamp to the cap."""
    from repro.core.wire import MAX_RETRY_AFTER_S, parse_retry_after
    got = parse_retry_after(value, 0.25)
    assert isinstance(got, float)
    assert 0.0 <= got <= MAX_RETRY_AFTER_S
    if isinstance(value, bool) or not isinstance(value, (int, float)) \
            or value != value or value < 0.0:
        assert got == 0.25
    else:
        assert got == min(float(value), MAX_RETRY_AFTER_S)


def test_busy_reply_with_junk_retry_after_still_clean_refusal():
    """A hostile server answering hello with ``busy`` and a garbage
    ``retry_after`` must produce a clean :class:`ServerBusy` whose hint
    is clamped/defaulted — never a crash or an unbounded sleep."""
    from repro.core.distributor import ClientProfile
    from repro.core.transport import (RemoteBrowserClient, ServerBusy,
                                      read_frame)

    async def handle(reader, writer):
        msg = await read_frame(reader)
        writer.write(encode_frame({"type": "busy", "seq": msg["seq"],
                                   "retry_after": [1e18, "soon", None]}))
        await writer.drain()
        writer.close()

    async def go():
        srv = await asyncio.start_server(handle, "127.0.0.1", 0)
        host, port = srv.sockets[0].getsockname()[:2]
        client = RemoteBrowserClient(host, port,
                                     ClientProfile(name="hopeful"),
                                     reconnect_delay=0.25)
        try:
            await asyncio.wait_for(client._connect(), timeout=5.0)
        except ServerBusy as e:
            return e.retry_after, client.busy_refusals
        finally:
            srv.close()
            await srv.wait_closed()
        raise AssertionError("busy reply did not raise ServerBusy")

    retry_after, refusals = asyncio.run(go())
    assert retry_after == 0.25             # junk -> client's own default
    assert refusals == 1


# ---------------------------------------------------------------------------
# telemetry / clock-echo (fleet plane, ISSUE 10)
# ---------------------------------------------------------------------------


def test_telemetry_on_fleetless_server_dropped_counted_connection_lives():
    """A fleetless server answers ``telemetry`` with accepted=false —
    a drop, not an error — and the connection stays fully usable, with
    heartbeat replies still byte-identical to pre-fleet builds."""
    from repro.core.transport import PROTOCOL_VERSION, read_frame
    from repro.core.wire import make_telemetry

    async def go():
        d, server = _live_server()            # no fleet= wired
        addr = await server.start()
        reader, writer = await _dial(
            addr, {"type": "hello", "seq": 1, "client": "optimist",
                   "proto": 1, "max_proto": PROTOCOL_VERSION},
            {"type": "telemetry", "seq": 2,
             "telemetry": make_telemetry(
                 None, [{"name": "client.execute", "ph": "X",
                         "cat": "client", "track": "client:optimist",
                         "ts": 1.0, "dur": 0.5}])},
            {"type": "heartbeat", "seq": 3})
        replies = [await asyncio.wait_for(read_frame(reader), timeout=5.0)
                   for _ in range(3)]
        writer.close()
        stats = server.stats()
        await server.stop()
        return replies, stats

    replies, stats = asyncio.run(go())
    assert replies[0]["type"] == "hello_ok"
    assert replies[1] == {"type": "telemetry_ok", "seq": 2,
                          "accepted": False}
    assert replies[2] == {"type": "heartbeat_ok", "seq": 3}
    assert stats["telemetry_dropped"] == 1
    assert stats["telemetry_accepted"] == 0


def test_garbage_telemetry_inert_on_fleet_server():
    """Adversarial telemetry bodies against an armed fleet plane: junk
    costs the sender its batch (counted), never the server its
    connection — a lease_request afterwards still leases work."""
    from repro.obs import FleetAggregator
    from repro.core.transport import PROTOCOL_VERSION, read_frame
    from repro.core.wire import MAX_TELEMETRY_SPANS

    hostile = [
        {},                                       # no telemetry field
        {"telemetry": None},
        {"telemetry": 7},
        {"telemetry": "snapshots"},
        {"telemetry": [1, 2, 3]},
        {"telemetry": {"metrics": "nope", "spans": 12, "dropped": "x"}},
        {"telemetry": {"spans": [{"name": "evil", "ph": "X",
                                  "track": "t", "ts": float("nan")},
                                 {"ph": "??"}, "span?", 9]}},
        {"telemetry": {"spans": [
            {"name": f"flood{i}", "ph": "i", "track": "t",
             "ts": float(i)} for i in range(MAX_TELEMETRY_SPANS + 64)]}},
        {"telemetry": {"metrics": {"m": {"kind": "pie", "values": []},
                                   7: "not-a-series"}}},
    ]

    async def go():
        fleet = FleetAggregator()
        d, server = _live_server(fleet=fleet)
        addr = await server.start()
        reader, writer = await _dial(
            addr, {"type": "hello", "seq": 1, "client": "hostile",
                   "proto": PROTOCOL_VERSION})
        hello = await asyncio.wait_for(read_frame(reader), timeout=5.0)
        assert hello["type"] == "hello_ok"
        replies = []
        for seq, body in enumerate(hostile, start=2):
            writer.write(encode_frame(
                {"type": "telemetry", "seq": seq, **body}))
            await writer.drain()
            replies.append(await asyncio.wait_for(read_frame(reader),
                                                  timeout=5.0))
        writer.write(encode_frame({"type": "lease_request",
                                   "seq": 99}))
        await writer.drain()
        grant = await asyncio.wait_for(read_frame(reader), timeout=5.0)
        writer.close()
        stats = server.stats()
        await server.stop()
        return replies, grant, stats, fleet

    replies, grant, stats, fleet = asyncio.run(go())
    for seq, reply in enumerate(replies, start=2):
        assert reply["type"] == "telemetry_ok" and reply["seq"] == seq
        assert isinstance(reply["accepted"], bool)
    assert grant["type"] == "lease_grant"          # connection survived
    assert stats["telemetry_accepted"] + stats["telemetry_dropped"] \
        == len(hostile)
    # non-dict payloads are parse drops; dict payloads ingest with their
    # junk rows stripped (the oversize flood lands capped)
    assert stats["telemetry_dropped"] >= 5
    s = fleet.stats()
    assert s["batches_dropped"] == stats["telemetry_dropped"]
    assert s["parse_dropped"] >= 64        # the flood's span overflow
    assert s["spans_total"] <= MAX_TELEMETRY_SPANS + 2


def test_telemetry_replay_after_eviction_is_idempotent():
    """An evicted client reconnecting and replaying its last telemetry
    batch re-ingests cleanly: last-write-wins per series, no doubled
    rows, no resurrection of the evicted lease state."""
    from repro.obs import FleetAggregator
    from repro.core.transport import PROTOCOL_VERSION, read_frame
    from repro.core.wire import make_telemetry

    batch = make_telemetry(
        {"client.executed_total": {
            "kind": "counter", "help": "Tickets executed",
            "values": [{"labels": {}, "value": 5}]}},
        [{"name": "client.execute", "ph": "X", "cat": "client",
          "track": "client:zombie", "ts": 2.0, "dur": 0.25}])

    async def go():
        fleet = FleetAggregator()
        d, server = _live_server(fleet=fleet, heartbeat_timeout=5.0)
        addr = await server.start()
        reader, writer = await _dial(
            addr, {"type": "hello", "seq": 1, "client": "zombie",
                   "proto": PROTOCOL_VERSION},
            {"type": "telemetry", "seq": 2, "telemetry": batch})
        for _ in range(2):
            await asyncio.wait_for(read_frame(reader), timeout=5.0)
        await server.evict_client("zombie")
        writer.close()
        r2, w2 = await _dial(
            addr, {"type": "hello", "seq": 5, "client": "zombie",
                   "proto": PROTOCOL_VERSION},
            {"type": "telemetry", "seq": 6, "telemetry": batch})
        replies = [await asyncio.wait_for(read_frame(r2), timeout=5.0)
                   for _ in range(2)]
        w2.close()
        stats = server.stats()
        await server.stop()
        return replies, stats, fleet

    replies, stats, fleet = asyncio.run(go())
    assert replies[1] == {"type": "telemetry_ok", "seq": 6,
                          "accepted": True}
    assert stats["telemetry_accepted"] == 2
    rows = fleet.snapshot()["client.executed_total"]["values"]
    assert [(r["labels"]["client"], r["value"]) for r in rows] == \
        [("zombie", 5)]                            # one row, not two
    spans = [e for e in fleet.remote_events()
             if e["name"] == "client.execute"]
    assert len(spans) == 2                         # replay appends spans


def test_junk_heartbeat_echo_ignored_but_server_ts_still_stamped():
    """Garbage ``echo`` riding a heartbeat on an armed fleet server:
    no clock-skew sample is recorded, yet every reply still carries a
    finite ``server_ts`` so the echo protocol can restart."""
    from repro.obs import FleetAggregator
    from repro.core.transport import PROTOCOL_VERSION, read_frame

    echoes = [7, "soon", [1.0, 2.0, 3.0], {},
              {"t0": "a", "server_ts": 1.0, "t1": 2.0},
              {"t0": 2.0, "server_ts": 1.0, "t1": 1.0},     # rtt < 0
              {"t0": float("nan"), "server_ts": 1.0, "t1": 2.0},
              {"t0": 1.0, "server_ts": float("inf"), "t1": 2.0},
              {"t0": True, "server_ts": 1.0, "t1": 2.0}]

    async def go():
        fleet = FleetAggregator()
        d, server = _live_server(fleet=fleet)
        addr = await server.start()
        reader, writer = await _dial(
            addr, {"type": "hello", "seq": 1, "client": "noisy",
                   "proto": PROTOCOL_VERSION})
        await asyncio.wait_for(read_frame(reader), timeout=5.0)
        replies = []
        for seq, echo in enumerate(echoes, start=2):
            writer.write(encode_frame({"type": "heartbeat", "seq": seq,
                                       "echo": echo}))
            await writer.drain()
            replies.append(await asyncio.wait_for(read_frame(reader),
                                                  timeout=5.0))
        writer.close()
        await server.stop()
        return replies, fleet

    replies, fleet = asyncio.run(go())
    for seq, reply in enumerate(replies, start=2):
        assert reply["type"] == "heartbeat_ok" and reply["seq"] == seq
        assert isinstance(reply["server_ts"], float)
        assert reply["server_ts"] == reply["server_ts"]    # not NaN
    assert fleet.skew("noisy") is None
    assert fleet.offset("noisy") == 0.0


def test_v1_peer_on_fleet_server_gets_prefleet_bytes():
    """Arming the fleet plane must not leak into v1 conversations: a
    proto-1 heartbeat reply stays byte-identical to pre-fleet builds
    (no ``server_ts``), and v1 telemetry is dropped, not ingested."""
    from repro.obs import FleetAggregator
    from repro.core.transport import read_frame
    from repro.core.wire import make_telemetry

    async def go():
        fleet = FleetAggregator()
        d, server = _live_server(fleet=fleet)
        addr = await server.start()
        reader, writer = await _dial(
            addr, {"type": "hello", "seq": 1, "client": "legacy",
                   "proto": 1, "max_proto": 1},
            {"type": "heartbeat", "seq": 2,
             "echo": {"t0": 1.0, "server_ts": 2.0, "t1": 3.0}},
            {"type": "telemetry", "seq": 3,
             "telemetry": make_telemetry(None, [
                 {"name": "x", "ph": "i", "track": "t", "ts": 1.0}])})
        replies = [await asyncio.wait_for(read_frame(reader), timeout=5.0)
                   for _ in range(3)]
        writer.close()
        stats = server.stats()
        await server.stop()
        return replies, stats, fleet

    replies, stats, fleet = asyncio.run(go())
    assert replies[0]["proto"] == 1
    assert replies[1] == {"type": "heartbeat_ok", "seq": 2}
    assert replies[2] == {"type": "telemetry_ok", "seq": 3,
                          "accepted": False}
    assert stats["telemetry_dropped"] == 1
    assert fleet.clients() == [] and fleet.skew("legacy") is None


# -- codec totality fuzz ----------------------------------------------------

_TELEMETRY_KEYS = ["type", "name", "ph", "ts", "dur", "track", "cat",
                   "id", "args", "metrics", "spans", "dropped", "kind",
                   "values", "help", "t0", "server_ts", "t1"]
_SCALAR = st.one_of(
    st.just(None), st.booleans(), st.integers(-9, 1 << 40),
    st.floats(min_value=-1e9, max_value=1e9),
    st.just(float("nan")), st.just(float("inf")),
    st.just(float("-inf")), st.binary(max_size=6),
    st.sampled_from(["", "x", "client.execute", "X", "b", "e", "i",
                     "counter", "gauge", "histogram"]))
_FLAT_DICT = st.lists(
    st.tuples(st.sampled_from(_TELEMETRY_KEYS), _SCALAR),
    max_size=6).map(dict)
_SOUP = st.one_of(
    _SCALAR, st.lists(_SCALAR, max_size=4), _FLAT_DICT,
    _FLAT_DICT.map(lambda d: {"metrics": d, "spans": [d], "dropped": d}),
    st.lists(_FLAT_DICT, max_size=3).map(
        lambda rows: {"spans": rows,
                      "metrics": {f"m{i}.x_total": r
                                  for i, r in enumerate(rows)}}))


@settings(max_examples=300, deadline=None)
@given(_SOUP)
def test_fuzz_parse_telemetry_total(soup):
    """parse_telemetry over arbitrary JSON-ish soup: returns None or a
    normalized batch — never raises, never exceeds its caps, and every
    surviving span is replayable (known phase, finite ts)."""
    import math
    from repro.core.wire import (MAX_TELEMETRY_SERIES,
                                 MAX_TELEMETRY_SPANS, parse_telemetry)
    parsed = parse_telemetry(soup)
    if parsed is None:
        assert not isinstance(soup, dict)
        return
    assert set(parsed) == {"metrics", "spans", "dropped", "local_drops"}
    assert len(parsed["spans"]) <= MAX_TELEMETRY_SPANS
    assert len(parsed["metrics"]) <= MAX_TELEMETRY_SERIES
    assert parsed["dropped"] >= 0 and parsed["local_drops"] >= 0
    for ev in parsed["spans"]:
        assert ev["ph"] in ("X", "b", "e", "i")
        assert math.isfinite(ev["ts"])
        assert isinstance(ev["name"], str)
        if ev["ph"] == "X":
            assert ev["dur"] >= 0.0
    for name, series in parsed["metrics"].items():
        assert isinstance(name, str)
        assert series["kind"] in ("counter", "gauge", "histogram")


@settings(max_examples=300, deadline=None)
@given(_SOUP)
def test_fuzz_parse_clock_echo_total(soup):
    """parse_clock_echo over the same soup: None or a finite
    ``(t0, server_ts, t1)`` with non-negative round-trip."""
    import math
    from repro.core.wire import parse_clock_echo
    got = parse_clock_echo(soup)
    if got is None:
        return
    t0, sts, t1 = got
    assert all(isinstance(v, float) and math.isfinite(v)
               for v in (t0, sts, t1))
    assert t1 >= t0


@settings(max_examples=200, deadline=None)
@given(st.lists(_FLAT_DICT, max_size=3), _SOUP)
def test_fuzz_fleet_ingest_total(rows, extra):
    """FleetAggregator.ingest over parse_telemetry's output for
    arbitrary soup: never raises, and the aggregator's own exports
    (snapshot / merged_events / to_json) stay well-formed after."""
    import json as _json
    from repro.obs import FleetAggregator
    from repro.core.wire import parse_telemetry
    fl = FleetAggregator(max_spans_per_client=8)
    fl.ingest("c0", parse_telemetry({"spans": rows, "metrics": extra}))
    fl.ingest("c0", parse_telemetry(extra))
    fl.clock_sample("c0", offset=1.0, rtt=0.01)
    snap = fl.snapshot()
    assert isinstance(snap, dict)
    for ev in fl.merged_events():
        assert isinstance(ev["ts"], float) or isinstance(ev["ts"], int)
    _json.loads(fl.to_json())                      # serializes cleanly
