"""Protocol fuzzing: adversarial bytes against every v2 decoder.

The distributor listens for anonymous browsers, so the frame reader, the
chunk state machine, the binary-manifest decoder and the ticket codecs
are all adversarial-input territory.  The contract under fuzz:

  * every malformed input raises :class:`ProtocolError` with a code from
    the documented table (docs/PROTOCOL.md) — never a bare ValueError,
    never a hang (each case runs under a hard ``asyncio.wait_for``);
  * no decoder allocates based on an unchecked size field: oversized
    declarations are rejected from the header alone, before any payload
    bytes are read or buffered.

Runs under real `hypothesis` (CI) or the deterministic shim.
"""
import asyncio
import json
import struct

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.tickets import LeaseBatch, Ticket
from repro.core.transport import (CHUNK_FLAG, MAX_BLOB_CHUNKS,
                                  ProtocolError, build_blob_frames,
                                  encode_chunk, encode_frame, read_frame_ex,
                                  read_message)
from repro.core.wire import decode_binary, encode_binary

#: every code a *decoder* (reader / manifest / ticket codec) may raise.
#: Keep in sync with the error table in docs/PROTOCOL.md — the docs test
#: checks the reverse direction (each code in the source is documented).
DECODER_CODES = {
    "bad-json", "bad-message", "truncated-frame", "frame-too-large",
    "unexpected-chunk", "chunk-mismatch", "bad-blob", "blob-too-large",
    "bad-manifest",
}


def _reader(*chunks: bytes) -> asyncio.StreamReader:
    # must be constructed inside a running loop (asyncio.StreamReader
    # binds the current event loop) — call only from within _decode
    r = asyncio.StreamReader()
    for c in chunks:
        r.feed_data(c)
    r.feed_eof()
    return r


def _decode(make_coro):
    """Run ``make_coro()`` (a thunk building the reader + coroutine inside
    the loop) under a hard deadline: garbage must produce a ProtocolError
    (or clean EOF), never a hang or another exception."""
    async def go():
        return await asyncio.wait_for(make_coro(), timeout=5.0)
    return asyncio.run(go())


def expect_code(make_coro, codes):
    with pytest.raises(ProtocolError) as ei:
        _decode(make_coro)
    assert ei.value.code in codes, ei.value
    return ei.value.code


# ---------------------------------------------------------------------------
# random garbage against the frame reader
# ---------------------------------------------------------------------------


@settings(max_examples=200, deadline=None)
@given(st.binary(min_size=0, max_size=200))
def test_fuzz_read_message_never_hangs_or_leaks_exceptions(data):
    async def go():
        return await asyncio.wait_for(
            read_message(_reader(data), max_bytes=1 << 16,
                         max_blob_bytes=1 << 16), timeout=5.0)
    try:
        msg, n = asyncio.run(go())
    except ProtocolError as e:
        assert e.code in DECODER_CODES, e
    else:
        # random bytes that happen to parse must be a legal message
        assert msg is None or (isinstance(msg, dict)
                               and isinstance(msg["type"], str))


@settings(max_examples=120, deadline=None)
@given(st.binary(min_size=4, max_size=64),
       st.integers(min_value=0, max_value=0xFFFFFFFF))
def test_fuzz_header_prefix_with_random_length(tail, length):
    """A syntactically valid 4-byte length header followed by arbitrary
    bytes: rejected from the header alone when oversized, else either a
    decode error or truncation — never a hang."""
    raw = struct.pack(">I", length) + tail
    max_bytes = 1 << 12
    async def go():
        return await asyncio.wait_for(
            read_message(_reader(raw), max_bytes=max_bytes,
                         max_blob_bytes=1 << 16), timeout=5.0)
    try:
        asyncio.run(go())
    except ProtocolError as e:
        assert e.code in DECODER_CODES, e
        if (length & (CHUNK_FLAG - 1)) > max_bytes:
            assert e.code == "frame-too-large"


def test_length_field_overflow_rejected_before_read():
    """All-ones length header: the chunk flag is masked out first, and
    the remaining 2^31-1 length still exceeds max_bytes — rejected
    without buffering anything."""
    code = expect_code(
        lambda: read_message(_reader(b"\xff\xff\xff\xff" + b"x" * 64),
                             max_bytes=1024), {"frame-too-large"})
    assert code == "frame-too-large"


@pytest.mark.parametrize("raw", [
    b"\x00",                                  # EOF inside length header
    b"\x00\x00\x00\x10{\"ty",                 # EOF inside JSON body
    struct.pack(">I", CHUNK_FLAG | 8) + b"abc",   # EOF inside chunk body
])
def test_truncated_frames_raise(raw):
    expect_code(lambda: read_frame_ex(_reader(raw), allow_chunk=True),
                {"truncated-frame"})


def test_chunk_frame_outside_blob_rejected():
    expect_code(lambda: read_message(_reader(encode_chunk(b"orphan"))),
                {"unexpected-chunk"})


# ---------------------------------------------------------------------------
# the chunk state machine
# ---------------------------------------------------------------------------


def _blob_msg(**over):
    msg = {"type": "submit", "seq": 1, "chunks": 2, "blob_bytes": 8}
    msg.update(over)
    return msg


def test_blob_roundtrip_through_reader():
    frames = build_blob_frames({"type": "submit", "seq": 9}, b"x" * 100,
                               chunk_bytes=7)
    msg, n = _decode(lambda: read_message(_reader(*frames)))
    assert msg["_blob"] == b"x" * 100
    assert msg["chunks"] == -(-100 // 7)
    assert n == sum(len(f) for f in frames)


def test_blob_too_large_rejected_before_chunks_read():
    # header alone: no chunk frames are even fed, yet the error is the
    # cap violation, not a truncation — proof nothing was buffered first
    expect_code(
        lambda: read_message(_reader(encode_frame(
            _blob_msg(blob_bytes=1 << 20))), max_blob_bytes=1 << 10),
        {"blob-too-large"})


@pytest.mark.parametrize("decl", [
    {"chunks": 0}, {"chunks": -1}, {"chunks": True},
    {"chunks": MAX_BLOB_CHUNKS + 1}, {"chunks": "2"},
    {"blob_bytes": -1}, {"blob_bytes": "8"}, {"blob_bytes": None},
    {"chunks": None, "blob_bytes": None},
])
def test_bad_chunk_declarations_rejected(decl):
    expect_code(lambda: read_message(_reader(encode_frame(
        _blob_msg(**decl)))), {"bad-blob"})


def test_eof_mid_blob_is_truncation():
    expect_code(lambda: read_message(_reader(
        encode_frame(_blob_msg()), encode_chunk(b"1234"))),
        {"truncated-frame"})


def test_json_frame_where_chunk_expected():
    expect_code(lambda: read_message(_reader(
        encode_frame(_blob_msg()), encode_frame({"type": "sneak"}))),
        {"chunk-mismatch"})


def test_chunk_overrun_rejected():
    expect_code(lambda: read_message(_reader(
        encode_frame(_blob_msg()), encode_chunk(b"123456"),
        encode_chunk(b"123456"))), {"bad-blob"})


def test_chunk_underrun_rejected():
    expect_code(lambda: read_message(_reader(
        encode_frame(_blob_msg()), encode_chunk(b"12"),
        encode_chunk(b"34"))), {"bad-blob"})


def test_chunked_message_rejected_on_v1_connection():
    frames = build_blob_frames({"type": "submit", "seq": 1}, b"x" * 8)
    expect_code(lambda: read_message(_reader(*frames),
                                     allow_chunks=False), {"bad-blob"})


@settings(max_examples=60, deadline=None)
@given(st.binary(min_size=0, max_size=128), st.integers(1, 5))
def test_fuzz_blob_reassembly_identity(payload, chunk_bytes):
    """Well-formed chunked messages always reassemble exactly."""
    frames = build_blob_frames({"type": "t", "seq": 1}, payload,
                               chunk_bytes=chunk_bytes)
    msg, _ = _decode(lambda: read_message(_reader(*frames)))
    assert msg.get("_blob", b"") == payload


# ---------------------------------------------------------------------------
# binary-manifest decoding
# ---------------------------------------------------------------------------


def _good_manifest():
    manifest, buffer = encode_binary({"a": __import__("numpy")
                                      .zeros((2, 3), "float32")})
    return json.loads(json.dumps(manifest)), buffer


@pytest.mark.parametrize("mutate", [
    lambda m: "not a dict",
    lambda m: {},
    lambda m: {**m, "arrays": "nope"},
    lambda m: {**m, "rest": 42},
    lambda m: {**m, "rest": "!!! not base64 !!!"},
    lambda m: {**m, "rest": "YWJj"},                  # b"abc": not a pickle
    lambda m: {**m, "arrays": [{}]},
    lambda m: {**m, "arrays": ["x"]},
    lambda m: {**m, "arrays": [{**m["arrays"][0], "dtype": "object"}]},
    lambda m: {**m, "arrays": [{**m["arrays"][0], "dtype": "no-such"}]},
    lambda m: {**m, "arrays": [{**m["arrays"][0], "dtype": 7}]},
    lambda m: {**m, "arrays": [{**m["arrays"][0], "shape": [-1, 6]}]},
    lambda m: {**m, "arrays": [{**m["arrays"][0], "shape": [2, True]}]},
    lambda m: {**m, "arrays": [{**m["arrays"][0], "shape": [1] * 64}]},
    lambda m: {**m, "arrays": [{**m["arrays"][0], "nbytes": 999}]},
    lambda m: {**m, "arrays": [{**m["arrays"][0], "nbytes": True}]},
    lambda m: {**m, "arrays": m["arrays"] * 2},       # extent overrun
    lambda m: {**m, "arrays": []},                    # trailing bytes
])
def test_manifest_mutations_rejected(mutate):
    manifest, buffer = _good_manifest()
    with pytest.raises(ProtocolError) as ei:
        decode_binary(mutate(manifest), buffer)
    assert ei.value.code == "bad-manifest"


def test_manifest_huge_nbytes_rejected_without_allocation():
    """A declared extent of ~2^40 bytes must be rejected by arithmetic
    comparison against the actual buffer, never allocated."""
    n = 1 << 40
    manifest = {"arrays": [{"dtype": "float64", "shape": [n // 8],
                            "nbytes": n}],
                "rest": _good_manifest()[0]["rest"]}
    with pytest.raises(ProtocolError) as ei:
        decode_binary(manifest, b"tiny")
    assert ei.value.code == "bad-manifest"


def test_manifest_array_count_cap():
    manifest, buffer = _good_manifest()
    entry = {"dtype": "float32", "shape": [0], "nbytes": 0}
    manifest["arrays"] = [entry] * ((1 << 16) + 1)
    with pytest.raises(ProtocolError) as ei:
        decode_binary(manifest, b"")
    assert ei.value.code == "bad-manifest"


@settings(max_examples=100, deadline=None)
@given(st.binary(min_size=0, max_size=64))
def test_fuzz_manifest_buffer_mismatch(junk):
    """A valid manifest over the wrong buffer either decodes (exact size
    match by construction) or raises bad-manifest — never crashes."""
    manifest, buffer = _good_manifest()
    if len(junk) == len(buffer):
        return                                         # would be valid
    with pytest.raises(ProtocolError) as ei:
        decode_binary(manifest, junk)
    assert ei.value.code == "bad-manifest"


# ---------------------------------------------------------------------------
# ticket / lease codecs
# ---------------------------------------------------------------------------


def _noop_decode(s):
    return s


@pytest.mark.parametrize("d", [
    {},
    {"ticket_id": "7", "task_name": "t", "work": 1, "task_version": 0,
     "args": "x"},
    {"ticket_id": True, "task_name": "t", "work": 1, "task_version": 0,
     "args": "x"},
    {"ticket_id": 7, "task_name": 3, "work": 1, "task_version": 0,
     "args": "x"},
    {"ticket_id": 7, "task_name": "t", "work": "fast", "task_version": 0,
     "args": "x"},
    {"ticket_id": 7, "task_name": "t", "work": 1, "task_version": "0",
     "args": "x"},
    {"ticket_id": 7, "task_name": "t", "work": 1, "task_version": 0},
])
def test_ticket_from_wire_rejects_malformed(d):
    with pytest.raises(ProtocolError) as ei:
        Ticket.from_wire(d, _noop_decode)
    assert ei.value.code == "bad-message"


@pytest.mark.parametrize("d", [
    {},
    {"lease_id": "9", "client": "c", "tickets": []},
    {"lease_id": 9, "client": 0, "tickets": []},
    {"lease_id": 9, "client": "c", "tickets": "nope"},
    {"lease_id": 9, "client": "c", "tickets": [{"ticket_id": "bad"}]},
])
def test_lease_batch_from_wire_rejects_malformed(d):
    with pytest.raises(ProtocolError) as ei:
        LeaseBatch.from_wire(d, _noop_decode)
    assert ei.value.code == "bad-message"


@settings(max_examples=100, deadline=None)
@given(st.lists(st.one_of(st.integers(-5, 5), st.booleans(),
                          st.just(None), st.binary(max_size=8)),
                min_size=0, max_size=4))
def test_fuzz_ticket_codec_random_field_soup(soup):
    """Random JSON-ish values thrown at every ticket field: the codec
    either builds a Ticket (all fields happened to be well-typed) or
    raises bad-message — nothing else escapes."""
    keys = ["ticket_id", "task_name", "work", "task_version", "args"]
    d = dict(zip(keys, soup))
    try:
        t = Ticket.from_wire(d, _noop_decode)
    except ProtocolError as e:
        assert e.code == "bad-message"
    else:
        assert isinstance(t.ticket_id, int)
