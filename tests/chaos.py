"""Chaos harness: churning remote fleets against the live transport.

The reusable half of the browser-scale story (the 10k-client version
runs on the virtual clock in ``benchmarks/churn_scale.py``; this module
is real sockets).  :class:`ChurningFleet` manages a population of
``RemoteBrowserClient``\\ s whose device parameters come from
``core/profiles.py`` and can **abruptly kill** any fraction of them —
task cancelled, socket dropped, no release frame, exactly a closed tab —
then backfill with fresh devices.  The tests drive ``FederatedTrainer``
rounds where *every* client is remote, under per-round churn, and assert
the fabric's churn contract:

  * no round stalls (``FederatedTrainer.stalls == 0`` with a stall
    detector armed far below the round timeout);
  * no ticket is lost (every round closes complete) and none
    double-completes (first result wins; eviction cannot re-run a
    finished ticket into a second accept);
  * admission refusals are retryable — refused clients back off and the
    work still finishes.

Run in tier-1 via pytest; everything uses loopback sockets, tiny
workloads, and generous wall deadlines.
"""
import asyncio

from repro.core.distributor import (AdaptiveSizer, AsyncDistributor,
                                    ClientProfile, FixedSizer, TaskDef)
from repro.core.federation import FederatedDistributor
from repro.core.profiles import draw_fleet, scale_hazard
from repro.core.transport import (PROTOCOL_VERSION, RemoteBrowserClient,
                                  TransportServer, encode_frame,
                                  encode_payload, read_frame,
                                  reconnect_backoff, spawn_remote_clients)
from repro.obs.trace import Tracer
from repro.train_fabric.round_engine import FederatedTrainer

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                        # conftest registers the shim
    from tests._hypothesis_shim import given, settings, strategies as st


# module-level so they pickle across the wire
def _square(x, static):
    return x * x


def _grad(x, static):
    w = static["weights"]
    return {"grad": x * 2, "loss": float(x), "round": w["round"]}


def chaos_profiles(n: int, *, seed: int = 0, speed_scale: float = 50.0,
                   churn_target: float = 0.2) -> list:
    """``n`` ClientProfiles drawn from the device-tier mix
    (``core/profiles.py``), speeds scaled up so wall-clock tests finish
    fast, latencies capped so a Pareto tail draw can't eat the test
    deadline."""
    fleet = scale_hazard(draw_fleet(n, seed=seed), churn_target)
    return [d.client_profile(speed=d.speed * speed_scale,
                             latency=min(d.latency, 0.05))
            for d in fleet]


class ChurningFleet:
    """A population of remote clients with a tab-close lever.

    ``spawn(profiles)`` dials clients at the server; ``kill(frac)``
    abruptly cancels that fraction of the *live* clients (socket dropped
    mid-whatever, no release — the server only finds out via eviction or
    the watchdog) and returns how many died.  ``backfill()`` replaces
    the dead with fresh devices drawn from the same tier mix, like new
    visitors opening the page."""

    def __init__(self, address, *, seed: int = 0, client_kw=None):
        self.address = address
        self.seed = seed
        self.client_kw = dict(client_kw or {})
        self.clients: list = []
        self.tasks: list = []
        self.killed = 0
        self._generation = 0

    def spawn(self, profiles):
        clients, tasks = spawn_remote_clients(self.address, profiles,
                                              **self.client_kw)
        self.clients.extend(clients)
        self.tasks.extend(tasks)
        return clients

    def live(self) -> list:
        return [(c, t) for c, t in zip(self.clients, self.tasks)
                if not c.done and not t.done()]

    def kill(self, frac: float) -> int:
        """Close tabs: every k-th live client dies abruptly (cancel +
        socket drop, nothing released)."""
        live = self.live()
        n = max(1, int(len(live) * frac)) if live else 0
        for c, t in live[:n]:
            t.cancel()
            c._disconnect()
            self.killed += 1
        return n

    def backfill(self, n: int, *, speed_scale: float = 50.0):
        """``n`` fresh devices join (a later page-load generation, so
        names never collide with the dead)."""
        self._generation += 1
        profiles = chaos_profiles(
            n, seed=self.seed + 1000 * self._generation,
            speed_scale=speed_scale)
        profiles = [ClientProfile(
            name=f"g{self._generation}-{p.name}", speed=p.speed,
            latency=p.latency) for p in profiles]
        return self.spawn(profiles)

    async def join(self):
        """Stop survivors and await every client task (cancelled tasks
        are absorbed)."""
        for c, _ in self.live():
            await c.stop()
        await asyncio.gather(*self.tasks, return_exceptions=True)


# ---------------------------------------------------------------------------
# Tentpole: all-remote FederatedTrainer rounds under per-round churn
# ---------------------------------------------------------------------------


def test_all_remote_trainer_rounds_survive_per_round_churn():
    """Every client is a RemoteBrowserClient; ~a third of the fleet is
    abruptly killed EVERY round and backfilled.  Heartbeat eviction (not
    the watchdog: grace is set prohibitively high) must bring the dead
    tabs' leases back fast enough that no round stalls and every round
    closes with all shards arrived."""
    ROUNDS, SHARDS, FLEET = 4, 8, 10

    async def go():
        fed = FederatedDistributor(
            2, n_shards=4, timeout=30.0, redistribute_min=0.02,
            sizer=FixedSizer(1), watchdog_interval=5.0, grace=1000.0)
        fed.register_task(TaskDef("backbone_shard", _grad,
                                  static_files=("weights",)))
        server = TransportServer(fed, heartbeat_timeout=0.25,
                                 eviction_interval=0.05)
        addr = await server.start()
        fleet = ChurningFleet(
            addr, client_kw=dict(reconnect_delay=0.02, backoff_cap=0.2,
                                 heartbeat_interval=0.05))
        fleet.spawn(chaos_profiles(FLEET))
        results = []
        async with FederatedTrainer(fed, timeout=25.0,
                                    stall_after=5.0) as trainer:
            for r in range(ROUNDS):
                fleet.kill(0.34)           # tabs close mid-round setup
                fleet.backfill(4)
                res = await trainer.run_round(
                    list(range(SHARDS)),
                    statics={"weights": {"round": r}})
                results.append(res)
            stalls = trainer.stalls
        await fleet.join()
        await fed.shutdown()
        stats = server.stats()
        await server.stop()
        return results, stalls, stats, fleet.killed

    results, stalls, stats, killed = asyncio.run(go())
    assert len(results) == 4 and killed >= 4
    for res in results:
        # no ticket lost: every round closed with every shard arrived,
        # and each shard's gradient is the exactly-once first result
        assert res.complete, (res.index, res.stragglers)
        assert [g["grad"] for g in res.results] == [2 * i for i in range(8)]
    assert stalls == 0
    # the recovery path was exercised: dead tabs were evicted (watchdog
    # grace is 1000x ETA, so eviction is the only way this passed)
    assert stats["evictions"] >= 1
    assert stats["evicted_leases"] >= 0


def test_heartbeats_keep_slow_client_alive_under_eviction():
    """Slow is not gone: an execute several times longer than the
    heartbeat timeout survives because the client heartbeats between
    compute chunks — zero evictions, work completes first try."""
    async def go():
        d = AsyncDistributor(timeout=20.0, redistribute_min=0.02,
                             sizer=FixedSizer(1), watchdog_interval=5.0,
                             grace=1000.0)
        d.register_task(TaskDef("sq", _square))
        tids = d.add_work("sq", [3])       # one ticket, work=1.0
        server = TransportServer(d, heartbeat_timeout=0.2,
                                 eviction_interval=0.04)
        addr = await server.start()
        # speed 1.25 -> ~0.8s execute, 4x the heartbeat timeout
        clients, tasks = spawn_remote_clients(
            addr, [ClientProfile(name="slowpoke", speed=1.25)],
            heartbeat_interval=0.05)
        ok = await d.run_until_done(timeout=15.0)
        await asyncio.gather(*tasks)
        stats = server.stats()
        await server.stop()
        return ok, d.queue.results(), tids, stats, clients[0]

    ok, res, tids, stats, client = asyncio.run(go())
    assert ok and res[tids[0]] == 9
    assert stats["evictions"] == 0
    assert client.heartbeats_sent >= 3
    assert stats["heartbeats"] == client.heartbeats_sent
    assert client.reconnects == 0


def test_eviction_releases_silent_lease_long_before_watchdog():
    """A raw-socket puppet takes a lease and goes silent.  With the
    watchdog effectively disabled (grace 1000x), only heartbeat eviction
    can recover the ticket — and it must do so in well under a second so
    a real client finishes the round."""
    async def go():
        d = AsyncDistributor(timeout=20.0, redistribute_min=0.0,
                             sizer=FixedSizer(1), watchdog_interval=5.0,
                             grace=1000.0)
        d.register_task(TaskDef("sq", _square))
        tids = d.add_work("sq", [7])
        server = TransportServer(d, heartbeat_timeout=0.15,
                                 eviction_interval=0.03)
        addr = await server.start()
        reader, writer = await asyncio.open_connection(*addr)
        writer.write(encode_frame({"type": "hello", "seq": 1,
                                   "client": "ghost",
                                   "proto": PROTOCOL_VERSION}))
        writer.write(encode_frame({"type": "lease_request", "seq": 2}))
        await writer.drain()
        hello = await asyncio.wait_for(read_frame(reader), timeout=5.0)
        grant = await asyncio.wait_for(read_frame(reader), timeout=5.0)
        assert hello["type"] == "hello_ok"
        assert grant["type"] == "lease_grant" and not grant["done"]
        lease_id = grant["lease_id"]
        assert d.queue.lease_is_outstanding(lease_id)
        # ... and now the ghost says nothing.  Eviction must fire within
        # ~timeout + sweep interval; poll with a hard 2s cap.
        t0 = asyncio.get_running_loop().time()
        while d.queue.lease_is_outstanding(lease_id):
            assert asyncio.get_running_loop().time() - t0 < 2.0, \
                "eviction never released the silent lease"
            await asyncio.sleep(0.01)
        took = asyncio.get_running_loop().time() - t0
        # a live client picks the freed ticket up and finishes the round
        clients, tasks = spawn_remote_clients(
            addr, [ClientProfile(name="r0", speed=200.0)])
        ok = await d.run_until_done(timeout=15.0)
        await asyncio.gather(*tasks)
        stats = server.stats()
        writer.close()
        await server.stop()
        return ok, d.queue.results(), tids, stats, took

    ok, res, tids, stats, took = asyncio.run(go())
    assert ok and res[tids[0]] == 49
    assert stats["evictions"] == 1 and stats["evicted_leases"] == 1
    assert took < 1.0                      # vs grace x ETA ~ minutes


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------


def test_admission_cap_refuses_overflow_and_work_still_completes():
    """Six clients dial a server capped at two accepted connections per
    endpoint: the overflow is refused with ``busy`` (not an error),
    retries with jittered backoff, and every ticket still completes —
    backpressure sheds load without shedding work."""
    async def go():
        d = AsyncDistributor(timeout=20.0, redistribute_min=0.02,
                             sizer=AdaptiveSizer(target_lease_time=0.05,
                                                 max_size=8),
                             watchdog_interval=0.01)
        d.register_task(TaskDef("sq", _square))
        tids = d.add_work("sq", list(range(40)))
        server = TransportServer(d, max_conns_per_member=2,
                                 retry_after=0.05)
        addr = await server.start()
        clients, tasks = spawn_remote_clients(
            addr, [ClientProfile(name=f"c{i}", speed=500.0)
                   for i in range(6)],
            reconnect_delay=0.02, backoff_cap=0.2, max_reconnects=200)
        ok = await d.run_until_done(timeout=20.0)
        await asyncio.gather(*tasks)
        stats = server.stats()
        await server.stop()
        return ok, d.queue.results(), tids, stats, clients

    ok, res, tids, stats, clients = asyncio.run(go())
    assert ok
    assert [res[t] for t in tids] == [i * i for i in range(40)]
    # the cap actually bit, server- and client-side views agree
    assert stats["busy_refusals"] >= 1
    assert sum(c.busy_refusals for c in clients) == stats["busy_refusals"]
    assert stats["by_type"]["frames_out"].get("busy", 0) \
        == stats["busy_refusals"]


# ---------------------------------------------------------------------------
# Satellite: reconnect-during-eviction race (no double-complete)
# ---------------------------------------------------------------------------


def test_evicted_client_inflight_submit_cannot_double_complete():
    """The lease-bookkeeping pin-down: a client evicted while its submit
    is in flight re-submits after reconnect under the OLD lease id,
    *after* another client already completed the ticket.  The late
    submit must be accepted 0 times and the first result must stand —
    the ticket never double-completes."""
    async def go():
        d = AsyncDistributor(timeout=20.0, redistribute_min=0.0,
                             sizer=FixedSizer(1), watchdog_interval=5.0,
                             grace=1000.0)
        d.register_task(TaskDef("sq", _square))
        tids = d.add_work("sq", [7])
        server = TransportServer(d, heartbeat_timeout=5.0)
        addr = await server.start()
        # puppet takes the lease...
        reader, writer = await asyncio.open_connection(*addr)
        writer.write(encode_frame({"type": "hello", "seq": 1,
                                   "client": "pup",
                                   "proto": PROTOCOL_VERSION}))
        writer.write(encode_frame({"type": "lease_request", "seq": 2}))
        await writer.drain()
        await asyncio.wait_for(read_frame(reader), timeout=5.0)
        grant = await asyncio.wait_for(read_frame(reader), timeout=5.0)
        lease_id = grant["lease_id"]
        # ...fires its submit into the socket (in flight, not awaited)
        # and is evicted in the same breath — either arrival order must
        # be safe
        writer.write(encode_frame(
            {"type": "submit", "seq": 3, "lease_id": lease_id,
             "results": {str(tids[0]): encode_payload(999)}}))
        released = await server.evict_client("pup")
        # eviction redistributes the ticket; a live client computes the
        # real answer
        clients, tasks = spawn_remote_clients(
            addr, [ClientProfile(name="r0", speed=200.0)])
        ok = await d.run_until_done(timeout=15.0)
        await asyncio.gather(*tasks)
        writer.close()
        # puppet reconnects and replays the SAME submit under the old
        # lease id (reconnect-resume path), plus a stale heartbeat
        r2, w2 = await asyncio.open_connection(*addr)
        w2.write(encode_frame({"type": "hello", "seq": 10,
                               "client": "pup",
                               "proto": PROTOCOL_VERSION}))
        w2.write(encode_frame(
            {"type": "submit", "seq": 11, "lease_id": lease_id,
             "results": {str(tids[0]): encode_payload(999)}}))
        w2.write(encode_frame({"type": "heartbeat", "seq": 12,
                               "lease_id": lease_id}))
        await w2.drain()
        replies = [await asyncio.wait_for(read_frame(r2), timeout=5.0)
                   for _ in range(3)]
        w2.close()
        snap = d.queue.snapshot()
        await server.stop()
        return ok, released, d.queue.results(), tids, replies, snap

    ok, released, res, tids, replies, snap = asyncio.run(go())
    assert ok and released >= 0
    hello2, submit2, beat2 = replies
    assert hello2["type"] == "hello_ok"
    # the replayed submit is politely accepted as a frame but completes
    # NOTHING: the ticket already has its first result
    assert submit2["type"] == "submit_ok" and submit2["accepted"] == 0
    assert beat2["type"] == "heartbeat_ok"
    assert res[tids[0]] == 49              # first result stood
    assert snap["executed"] == 1           # exactly one completion


# ---------------------------------------------------------------------------
# Satellite: capped exponential reconnect backoff
# ---------------------------------------------------------------------------


def test_reconnect_backoff_schedule_is_capped_exponential():
    """The pure schedule: doubles from ``base``, saturates at ``cap``,
    and jitter only scales the span into [0.5x, 1.0x] — never above."""
    full = [reconnect_backoff(k, base=0.05, cap=2.0, rand=lambda: 1.0)
            for k in range(1, 10)]
    assert full == [0.05, 0.1, 0.2, 0.4, 0.8, 1.6, 2.0, 2.0, 2.0]
    half = [reconnect_backoff(k, base=0.05, cap=2.0, rand=lambda: 0.0)
            for k in range(1, 10)]
    assert half == [x * 0.5 for x in full]
    import random as _random
    rng = _random.Random(1)
    for k in range(1, 12):
        span = min(2.0, 0.05 * 2 ** (k - 1))
        d = reconnect_backoff(k, base=0.05, cap=2.0, rand=rng.random)
        assert span * 0.5 <= d <= span


def test_client_reconnect_backoff_observed_with_injected_clock():
    """A client dialing a dead address sleeps the exact capped-
    exponential schedule (injected ``_sleep`` records, injected rand
    pins jitter at 1.0) and gives up after ``max_reconnects``."""
    import types

    async def go():
        d = AsyncDistributor(timeout=5.0, redistribute_min=0.02,
                             sizer=FixedSizer(1), watchdog_interval=0.01)
        d.register_task(TaskDef("sq", _square))
        server = TransportServer(d)
        addr = await server.start()
        await server.stop()                # port is now refused
        client = RemoteBrowserClient(*addr, ClientProfile(name="lonely"),
                                     reconnect_delay=0.05, backoff_cap=0.4,
                                     max_reconnects=5)
        sleeps = []

        async def fake_sleep(s):
            sleeps.append(s)

        client._sleep = fake_sleep
        client._backoff_rand = types.SimpleNamespace(random=lambda: 1.0)
        try:
            await client.run()
        except ConnectionError:
            return sleeps, True
        return sleeps, False

    sleeps, gave_up = asyncio.run(go())
    assert gave_up
    assert sleeps == [0.05, 0.1, 0.2, 0.4, 0.4]


# ---------------------------------------------------------------------------
# Satellite: property test — exactly-once under random interleavings
# ---------------------------------------------------------------------------


class _SimClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@settings(max_examples=20)
@given(st.lists(st.sampled_from(
    ["connect", "lease", "compute", "submit", "heartbeat", "evict",
     "reconnect", "tick"]), min_size=8, max_size=60),
    st.integers(min_value=2, max_value=7))
def test_property_interleavings_exactly_once_and_spans_balance(ops, n):
    """Random interleavings of connect/lease/compute/submit/heartbeat/
    evict/reconnect over the server's lease-bookkeeping discipline (the
    same queue calls ``TransportServer`` makes, including eviction's
    drain-then-release and reconnect's late submit): every ticket is
    accepted EXACTLY once across all submits — duplicates, evictions and
    replays included — and the ticket/lease trace from ``test_obs``'s
    balance property stays balanced under eviction."""
    from repro.core.tickets import TicketQueue

    clock = _SimClock()
    tr = Tracer(clock=clock)
    q = TicketQueue(timeout=1e9, redistribute_min=0.0, clock=clock,
                    tracer=tr)
    tids = q.add_many("t", list(range(n)))
    accepted_total = 0
    # per client: live flag, server-held leases, in-flight submits that
    # were cut off by an eviction (replayed on reconnect)
    clients = {c: {"live": False, "leases": {}, "cut": []}
               for c in ("a", "b")}
    which = 0
    for op in ops:
        c = ("a", "b")[which % 2]
        which += 1
        st_c = clients[c]
        clock.t += 0.01
        if op == "connect":
            st_c["live"] = True
        elif op == "tick" or op == "heartbeat":
            clock.t += 0.05                # liveness only; queue untouched
        elif op == "lease" and st_c["live"]:
            batch = q.lease(c, 2)
            if batch is not None:
                st_c["leases"][batch.lease_id] = batch
        elif op == "compute" and st_c["leases"]:
            # finish the oldest lease and submit it (the common path)
            lid, batch = next(iter(st_c["leases"].items()))
            del st_c["leases"][lid]
            results = {t.ticket_id: t.args * 10 for t in batch.tickets}
            accepted_total += q.submit_batch(lid, results, c)
        elif op == "submit" and st_c["cut"]:
            # an in-flight submit from BEFORE an eviction finally lands
            lid, results = st_c["cut"].pop(0)
            accepted_total += q.submit_batch(lid, results, c)
        elif op == "evict" and st_c["live"]:
            # server drains bookkeeping first, then force-releases; any
            # lease mid-submit becomes a cut-off (replayed later)
            st_c["live"] = False
            for lid, batch in list(st_c["leases"].items()):
                st_c["cut"].append(
                    (lid, {t.ticket_id: t.args * 10
                           for t in batch.tickets}))
                q.release(lid, client_failed=True)
            st_c["leases"].clear()
        elif op == "reconnect":
            st_c["live"] = True
            while st_c["cut"]:             # resume: replay cut submits
                lid, results = st_c["cut"].pop(0)
                accepted_total += q.submit_batch(lid, results, c)
    # drain: both clients reconnect and finish everything outstanding
    for c, st_c in clients.items():
        st_c["live"] = True
        while st_c["cut"]:
            lid, results = st_c["cut"].pop(0)
            accepted_total += q.submit_batch(lid, results, c)
        for lid, batch in list(st_c["leases"].items()):
            del st_c["leases"][lid]
            results = {t.ticket_id: t.args * 10 for t in batch.tickets}
            accepted_total += q.submit_batch(lid, results, c)
    while not q.all_done():
        clock.t += 0.1
        batch = q.lease("drain", 4)
        if batch is None:
            continue
        results = {t.ticket_id: t.args * 10 for t in batch.tickets}
        accepted_total += q.submit_batch(batch.lease_id, results, "drain")
    # exactly-once: across every submit (first, duplicate, replayed,
    # post-eviction) each ticket was accepted precisely one time
    assert accepted_total == n
    res = q.results()
    assert [res[t] for t in tids] == [i * 10 for i in range(n)]
    # and the span ledger balanced under eviction (test_obs invariant)
    assert tr.balanced(), tr.open_spans()
    assert tr.spans_opened == tr.spans_closed
