"""Layer-level tests: RoPE, norms, attention semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.models import layers as L
from repro.sharding.spec import values_tree


def _cfg(**kw):
    cfg = get_smoke_config("qwen3-4b")
    return dataclasses.replace(cfg, **kw) if kw else cfg


def test_rope_preserves_norm_and_relative_positions():
    cfg = _cfg()
    hd = cfg.resolved_head_dim
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, hd))
    cos, sin = L.rope_cos_sin(jnp.arange(8), hd, 10000.0)
    xr = L.apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(xr), axis=-1), rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, hd))
    def dot_at(p, d):
        cq, sq = L.rope_cos_sin(jnp.asarray([p]), hd, 10000.0)
        ck, sk = L.rope_cos_sin(jnp.asarray([p + d]), hd, 10000.0)
        return float(jnp.sum(L.apply_rope(q, cq, sq)
                             * L.apply_rope(k, ck, sk)))
    assert dot_at(3, 5) == pytest.approx(dot_at(10, 5), rel=1e-4)
    assert dot_at(3, 5) != pytest.approx(dot_at(3, 6), rel=1e-3)


def test_rmsnorm_and_layernorm_statistics():
    cfg_r = _cfg(norm="rmsnorm")
    cfg_l = _cfg(norm="layernorm")
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, cfg_r.d_model)) * 5
    pr = {"scale": jnp.ones((cfg_r.d_model,))}
    pl_ = {"scale": jnp.ones((cfg_l.d_model,)),
           "bias": jnp.zeros((cfg_l.d_model,))}
    yr = L.apply_norm(pr, cfg_r, x)
    yl = L.apply_norm(pl_, cfg_l, x)
    np.testing.assert_allclose(
        np.sqrt((np.asarray(yr) ** 2).mean(-1)), 1.0, atol=1e-3)
    np.testing.assert_allclose(np.asarray(yl).mean(-1), 0.0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(yl).std(-1), 1.0, atol=1e-2)


def test_attention_is_causal():
    """Changing a future token must not change past outputs."""
    cfg = _cfg()
    p = values_tree(L.init_attention(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, cfg.d_model))
    pos = jnp.arange(12)
    y1, _ = L.attention(p, cfg, x, positions=pos)
    x2 = x.at[:, 9].set(13.0)
    y2, _ = L.attention(p, cfg, x2, positions=pos)
    np.testing.assert_allclose(np.asarray(y1[:, :9]), np.asarray(y2[:, :9]),
                               atol=1e-5)
    assert not np.allclose(np.asarray(y1[:, 9:]), np.asarray(y2[:, 9:]))


def test_sliding_window_attention_limits_receptive_field():
    cfg = _cfg()
    p = values_tree(L.init_attention(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))
    pos = jnp.arange(16)
    y1, _ = L.attention(p, cfg, x, positions=pos, window=4)
    x2 = x.at[:, 0].set(7.0)          # outside the window of position >= 4
    y2, _ = L.attention(p, cfg, x2, positions=pos, window=4)
    np.testing.assert_allclose(np.asarray(y1[:, 6:]), np.asarray(y2[:, 6:]),
                               atol=1e-5)


def test_chunked_attention_equals_unchunked():
    cfg = _cfg()
    p = values_tree(L.init_attention(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    pos = jnp.arange(64)
    old = L.ATTN_QUERY_CHUNK
    try:
        L.ATTN_QUERY_CHUNK = 16
        y_chunked, _ = L.attention(p, cfg, x, positions=pos)
        L.ATTN_QUERY_CHUNK = 4096
        y_full, _ = L.attention(p, cfg, x, positions=pos)
    finally:
        L.ATTN_QUERY_CHUNK = old
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_full),
                               atol=1e-5)


def test_gqa_grouped_decode_matches_full_attention():
    """Decode with ring cache must agree with full-sequence attention."""
    cfg = _cfg()
    p = values_tree(L.init_attention(jax.random.PRNGKey(0), cfg))
    s = 10
    x = jax.random.normal(jax.random.PRNGKey(1), (2, s, cfg.d_model))
    pos = jnp.arange(s)
    y_full, (k, v) = L.attention(p, cfg, x, positions=pos)

    # replay through the decode path one token at a time
    kv_ = cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    cache = (jnp.zeros((2, s, kv_, hd)), jnp.zeros((2, s, kv_, hd)),
             jnp.full((s,), -1, jnp.int32))
    outs = []
    for t in range(s):
        y_t, cache = L.attention(p, cfg, x[:, t:t + 1], positions=None,
                                 cache=cache, cache_index=jnp.int32(t))
        outs.append(y_t)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_dec),
                               atol=1e-4)


def test_qkv_bias_and_qk_norm_paths():
    cfg_b = _cfg(qkv_bias=True, qk_norm=False)
    cfg_n = _cfg(qkv_bias=False, qk_norm=True)
    for cfg in (cfg_b, cfg_n):
        p = values_tree(L.init_attention(jax.random.PRNGKey(0), cfg))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
        y, _ = L.attention(p, cfg, x, positions=jnp.arange(8))
        assert np.isfinite(np.asarray(y)).all()
    assert "bq" in values_tree(L.init_attention(jax.random.PRNGKey(0), cfg_b))
    assert "q_norm" in values_tree(
        L.init_attention(jax.random.PRNGKey(0), cfg_n))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(2, 24), st.sampled_from([32, 64]))
def test_mlp_shapes_and_finiteness(b, s, d_ff):
    cfg = dataclasses.replace(_cfg(), d_ff=d_ff)
    p = values_tree(L.init_mlp(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model))
    y = L.apply_mlp(p, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
