"""Tests for the paper's split-parallel training strategies."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.split_parallel import (init_prev_features, make_train_step,
                                       merge_params, split_params)
from repro.data import make_lm_batch
from repro.models.model import build_model
from repro.optim import get_optimizer, sgd
from repro.sharding.spec import values_tree


def _setup(arch="qwen3-4b", lr=0.05, opt_name="adagrad"):
    cfg = dataclasses.replace(get_smoke_config(arch), tie_embeddings=False)
    api = build_model(cfg, compute_dtype=jnp.float32)
    opt = get_optimizer(opt_name, lr)
    return cfg, api, opt


def _batches(cfg, n, b=4, s=32, seed=0):
    rng = np.random.default_rng(seed)
    return [{k: jnp.asarray(v)
             for k, v in make_lm_batch(rng, b, s, cfg.vocab_size).items()}
            for _ in range(n)]


@pytest.mark.parametrize("strategy", ["dp_full", "split_sequential",
                                      "split_concurrent"])
def test_strategies_learn(strategy):
    cfg, api, opt = _setup()
    init_state, step = make_train_step(api, opt, strategy=strategy)
    state = init_state(jax.random.PRNGKey(0))
    batches = _batches(cfg, 10)
    if strategy == "split_concurrent":
        state = init_prev_features(state, api, batches[0],
                                   dtype=jnp.float32)
    jstep = jax.jit(step)
    losses = []
    for b in batches:
        state, m = jstep(state, b)
        losses.append(float(m["total"]))
    assert losses[-1] < losses[0], (strategy, losses)


def test_split_sequential_equals_dp_full_gradients():
    """He-et-al split is mathematically identical to full DP (same grads,
    different placement) — one SGD step must produce identical params."""
    cfg, api, _ = _setup()
    batch = _batches(cfg, 1)[0]
    opt = sgd(0.1)

    init_dp, step_dp = make_train_step(api, opt, strategy="dp_full")
    init_sp, step_sp = make_train_step(api, opt, strategy="split_sequential")
    s_dp = init_dp(jax.random.PRNGKey(0))
    s_sp = init_sp(jax.random.PRNGKey(0))

    s_dp, _ = jax.jit(step_dp)(s_dp, batch)
    s_sp, _ = jax.jit(step_sp)(s_sp, batch)

    merged_sp = merge_params(s_sp.params, s_sp.head)
    for (k1, a), (k2, b) in zip(
            sorted(jax.tree_util.tree_leaves_with_path(s_dp.params),
                   key=lambda kv: str(kv[0])),
            sorted(jax.tree_util.tree_leaves_with_path(merged_sp),
                   key=lambda kv: str(kv[0]))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                                   err_msg=str(k1))


def test_split_concurrent_head_trains_on_previous_features():
    """Step 0 must not update the head (no previous features yet); step 1
    must."""
    cfg, api, opt = _setup()
    init_state, step = make_train_step(api, opt, strategy="split_concurrent")
    state = init_state(jax.random.PRNGKey(0))
    batches = _batches(cfg, 2)
    state = init_prev_features(state, api, batches[0], dtype=jnp.float32)
    head0 = jax.tree_util.tree_map(np.asarray, state.head)

    jstep = jax.jit(step)
    state, _ = jstep(state, batches[0])
    head1 = jax.tree_util.tree_map(np.asarray, state.head)
    d01 = max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda a, b: float(np.abs(a - b).max()), head0, head1)))
    assert d01 == 0.0, "head must not move before features exist"

    state, _ = jstep(state, batches[1])
    head2 = jax.tree_util.tree_map(np.asarray, state.head)
    d12 = max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda a, b: float(np.abs(a - b).max()), head1, head2)))
    assert d12 > 0.0, "head must train once features are available"


def test_split_concurrent_stale_head_sync_period():
    """head_stale refreshes only every K steps."""
    cfg, api, opt = _setup()
    K = 3
    init_state, step = make_train_step(api, opt, strategy="split_concurrent",
                                       head_sync_period=K)
    state = init_state(jax.random.PRNGKey(0))
    batches = _batches(cfg, 2 * K)
    state = init_prev_features(state, api, batches[0], dtype=jnp.float32)
    jstep = jax.jit(step)
    stale_syncs = []
    for i, b in enumerate(batches):
        prev_stale = jax.tree_util.tree_map(np.asarray, state.head_stale)
        state, _ = jstep(state, b)
        cur_stale = jax.tree_util.tree_map(np.asarray, state.head_stale)
        moved = max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
            lambda a, b_: float(np.abs(a - b_).max()), prev_stale,
            cur_stale))) > 0
        stale_syncs.append(moved)
    # syncs happen exactly at steps where (step+1) % K == 0 (and head moved)
    expected = [((i + 1) % K == 0) and i >= 1 for i in range(2 * K)]
    assert stale_syncs == expected, (stale_syncs, expected)


def test_split_params_roundtrip():
    cfg, api, _ = _setup()
    params = values_tree(api.init(jax.random.PRNGKey(0)))
    backbone, head = split_params(params)
    assert "head" in head and "head" not in backbone
    merged = merge_params(backbone, head)
    assert set(merged.keys()) == set(params.keys())


def test_split_requires_untied_head():
    cfg = get_smoke_config("qwen1.5-0.5b")   # tied embeddings
    assert cfg.tie_embeddings
    api = build_model(cfg, compute_dtype=jnp.float32)
    opt = get_optimizer("adagrad", 0.05)
    init_state, _ = make_train_step(api, opt, strategy="split_concurrent")
    with pytest.raises(ValueError, match="untied head"):
        init_state(jax.random.PRNGKey(0))
