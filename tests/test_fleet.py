"""Fleet telemetry plane tests: the ``telemetry``/clock-echo wire
codecs, the FleetAggregator (label injection, skew remapping, bounded
buffers, deterministic merged export), the Tracer's ring-buffer /
flight-recorder modes, the SLO monitor (including the injected-breach
direction the CI gate relies on), collector edge cases around dead and
evicted connections, and the end-to-end acceptance bar: an all-remote
federated round whose merged Perfetto export shows the server round
lane and the remote client execute lanes on one skew-corrected
timeline."""
import asyncio
import json
import math
import time

import numpy as np
import pytest

from repro.core.distributor import (AdaptiveSizer, AsyncDistributor,
                                    ClientProfile, TaskDef)
from repro.core.federation import FederatedDistributor
from repro.core.transport import (RemoteBrowserClient, TransportServer,
                                  spawn_remote_clients)
from repro.core.wire import (MAX_TELEMETRY_SERIES, MAX_TELEMETRY_SPANS,
                             make_clock_echo, make_telemetry,
                             parse_clock_echo, parse_telemetry)
from repro.obs import (DEFAULT_ROUND_SLOS, FleetAggregator,
                       MetricsRegistry, Slo, SloMonitor, Tracer,
                       collect_fabric, collect_fleet)
from repro.obs.fleet import _REMOTE_ID_BASE
from repro.train_fabric import FederatedTrainer


def _run(coro):
    return asyncio.run(coro)


class SimClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def _span(name="client.execute", ph="X", ts=1.0, **over):
    ev = {"name": name, "ph": ph, "cat": "client",
          "track": "client:c0", "ts": ts}
    if ph == "X":
        ev["dur"] = 0.5
    ev.update(over)
    return ev


# ---------------------------------------------------------------------------
# wire codecs: strict builder, tolerant parser
# ---------------------------------------------------------------------------


def test_telemetry_roundtrip_through_parser():
    reg = MetricsRegistry()
    reg.counter("client.executed_total", "Tickets executed").inc(4)
    batch = make_telemetry(reg.snapshot(), [_span()], dropped=2)
    parsed = parse_telemetry(batch)
    assert parsed["dropped"] == 2 and parsed["local_drops"] == 0
    assert parsed["metrics"]["client.executed_total"]["kind"] == "counter"
    assert parsed["spans"] == [_span(args=None) | {}] or parsed["spans"]
    assert parsed["spans"][0]["name"] == "client.execute"
    assert parsed["spans"][0]["dur"] == 0.5
    # empty flushes build to just the drop count
    assert make_telemetry(None, []) == {"dropped": 0}


def test_parse_telemetry_never_raises_on_junk():
    for junk in (None, 7, "x", [1], True, b"\x00"):
        assert parse_telemetry(junk) is None
    # junk *inside* an object costs rows, never the batch
    parsed = parse_telemetry({
        "metrics": {"ok.series_total": {"kind": "counter", "help": "h",
                                        "values": []},
                    "bad-kind": {"kind": "pie", "values": []},
                    "bad-body": 12},
        "spans": [_span(),
                  {"ph": "X"},                        # no name/track/ts
                  _span(ts=float("nan")),             # non-finite ts
                  _span(ph="q"),                      # unknown phase
                  _span(ph="b", id=True),             # bool async id
                  _span(ph="b", id="seven"),          # non-int async id
                  "not-a-span"],
        "dropped": -3,                                # junk self-report
    })
    assert list(parsed["metrics"]) == ["ok.series_total"]
    assert [e["name"] for e in parsed["spans"]] == ["client.execute"]
    assert parsed["local_drops"] == 8
    assert parsed["dropped"] == 0


def test_parse_telemetry_enforces_size_caps():
    spans = [_span(ts=float(i)) for i in range(MAX_TELEMETRY_SPANS + 40)]
    series = {f"spam.s{i}_total": {"kind": "counter", "values": []}
              for i in range(MAX_TELEMETRY_SERIES + 10)}
    parsed = parse_telemetry({"spans": spans, "metrics": series})
    assert len(parsed["spans"]) == MAX_TELEMETRY_SPANS
    assert len(parsed["metrics"]) == MAX_TELEMETRY_SERIES
    assert parsed["local_drops"] == 50
    # caps are parameters (the server could tighten them per-connection)
    tight = parse_telemetry({"spans": spans}, max_spans=3)
    assert len(tight["spans"]) == 3


def test_parse_telemetry_sanitizes_span_fields():
    parsed = parse_telemetry({"spans": [
        _span(dur=-5.0),                       # negative dur clamps
        _span(dur="long"),                     # junk dur clamps
        _span(ph="i", cat=7, args=[1, 2]),     # junk cat/args dropped
        _span(ph="b", id=11, args={"k": 1}),
    ]})
    assert parsed["spans"][0]["dur"] == 0.0
    assert parsed["spans"][1]["dur"] == 0.0
    assert parsed["spans"][2]["cat"] == "client"
    assert "args" not in parsed["spans"][2]
    assert parsed["spans"][3]["id"] == 11
    assert parsed["spans"][3]["args"] == {"k": 1}


def test_clock_echo_roundtrip_and_tolerance():
    echo = make_clock_echo(1.0, 500.25, 1.5)
    assert parse_clock_echo(echo) == (1.0, 500.25, 1.5)
    for junk in (None, [], "echo", 3,
                 {"t0": 1.0, "server_ts": 2.0},            # missing t1
                 {"t0": 2.0, "server_ts": 5.0, "t1": 1.0},  # rtt < 0
                 {"t0": float("nan"), "server_ts": 1.0, "t1": 2.0},
                 {"t0": 1.0, "server_ts": float("inf"), "t1": 2.0},
                 {"t0": True, "server_ts": 1.0, "t1": 2.0}):
        assert parse_clock_echo(junk) is None, junk


# ---------------------------------------------------------------------------
# FleetAggregator
# ---------------------------------------------------------------------------


def _client_batch(executed=3, ts=1.0, client_track="client:c0"):
    reg = MetricsRegistry()
    reg.counter("client.executed_total", "Tickets executed").inc(executed)
    return parse_telemetry(make_telemetry(
        reg.snapshot(), [_span(ts=ts, track=client_track)]))


def test_ingest_injects_client_label_and_merges():
    fl = FleetAggregator()
    assert fl.ingest("c0", _client_batch(executed=3))
    assert fl.ingest("c1", _client_batch(executed=5))
    snap = fl.snapshot()
    rows = snap["client.executed_total"]["values"]
    assert {(r["labels"]["client"], r["value"]) for r in rows} == \
        {("c0", 3), ("c1", 5)}
    assert fl.clients() == ["c0", "c1"]


def test_reingest_is_idempotent_last_write_wins():
    fl = FleetAggregator()
    fl.ingest("c0", _client_batch(executed=3))
    fl.ingest("c0", _client_batch(executed=9))   # cumulative re-snapshot
    rows = fl.snapshot()["client.executed_total"]["values"]
    assert [(r["labels"]["client"], r["value"]) for r in rows] == \
        [("c0", 9)]


def test_ingest_bounds_and_drop_accounting():
    fl = FleetAggregator(max_spans_per_client=2, max_clients=2)
    assert not fl.ingest("c0", None)             # unparseable batch
    assert not fl.ingest("", _client_batch())    # nameless client
    assert fl.ingest("c0", _client_batch(ts=1.0))
    assert fl.ingest("c0", parse_telemetry(
        {"spans": [_span(ts=2.0), _span(ts=3.0)], "dropped": 4}))
    assert fl.ingest("c1", _client_batch(client_track="client:c1"))
    assert not fl.ingest("c2", _client_batch())  # over max_clients
    s = fl.stats()
    assert s["clients"] == 2
    assert s["batches_dropped"] == 3
    assert s["spans_dropped"] == 1               # c0's ring evicted one
    assert s["remote_dropped"] == 4              # peer's own report
    # the surviving buffer holds the newest spans
    ts = [e["ts"] for e in fl.remote_events() if e["track"] == "client:c0"]
    assert ts == [2.0, 3.0]


def test_clock_skew_min_rtt_sample_wins():
    fl = FleetAggregator()
    assert fl.offset("c0") == 0.0                # no samples yet
    fl.clock_sample("c0", offset=-99.0, rtt=0.5)
    fl.clock_sample("c0", offset=-100.0, rtt=0.01)   # tighter: wins
    fl.clock_sample("c0", offset=-42.0, rtt=0.2)     # looser: ignored
    fl.clock_sample("c0", offset=1.0, rtt=-0.1)      # negative rtt: junk
    sk = fl.skew("c0")
    assert sk.offset == -100.0 and sk.rtt == 0.01 and sk.samples == 3


def test_remote_events_skew_corrected_and_ids_renumbered():
    fl = FleetAggregator()
    fl.clock_sample("c0", offset=2.0, rtt=0.01)
    fl.ingest("c0", parse_telemetry({"spans": [
        _span(ts=1.0),
        _span(name="client.lease", ph="b", id=7, ts=1.5),
        _span(name="client.lease", ph="e", id=7, ts=2.5),
    ]}))
    corrected = fl.remote_events()
    assert [e["ts"] for e in corrected] == [3.0, 3.5, 4.5]
    raw = fl.remote_events(corrected=False)
    assert [e["ts"] for e in raw] == [1.0, 1.5, 2.5]
    # async pair keeps one (renumbered) id clear of server span ids
    ids = {e["id"] for e in corrected if "id" in e}
    assert len(ids) == 1 and ids.pop() >= _REMOTE_ID_BASE


def test_merged_export_is_deterministic_and_loads_as_chrome_trace():
    def build():
        clock = SimClock()
        tr = Tracer(clock=clock)
        sid = tr.begin("round", track="trainer", cat="round", lane=True)
        clock.t = 4.0
        tr.end(sid)
        fl = FleetAggregator(tracer=tr)
        fl.clock_sample("c0", offset=0.5, rtt=0.02)
        fl.ingest("c0", _client_batch(ts=1.25))
        return fl
    a, b = build().to_json(), build().to_json()
    assert a == b
    doc = json.loads(a)
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"round", "client.execute"} <= names
    lanes = {e["args"]["name"] for e in doc["traceEvents"]
             if e["name"] == "thread_name"}
    assert {"trainer", "client:c0"} <= lanes


# ---------------------------------------------------------------------------
# Tracer: ring buffer + flight recorder
# ---------------------------------------------------------------------------


def test_ring_buffer_bounds_events_and_counts_drops():
    clock = SimClock()
    tr = Tracer(clock=clock, max_events=4)
    for i in range(7):
        clock.t = float(i)
        tr.instant(f"tick{i}", track="t")
    evs = tr.events()
    assert [e["name"] for e in evs] == ["tick3", "tick4", "tick5", "tick6"]
    assert tr.events_dropped == 3
    # the default tracer stays unbounded and drop-free
    tr2 = Tracer(clock=clock)
    for i in range(7):
        tr2.instant(f"tick{i}", track="t")
    assert tr2.event_count() == 7 and tr2.events_dropped == 0


def test_drain_pops_buffer_without_touching_open_spans():
    clock = SimClock()
    tr = Tracer(clock=clock, max_events=16)
    sid = tr.begin("lease", track="queue")      # stays open across drain
    tr.instant("ticket.route", track="queue")
    got = tr.drain()
    assert [e["name"] for e in got] == ["ticket.route"]
    assert tr.events() == [] and tr.drain() == []
    clock.t = 1.0
    tr.end(sid)
    assert [e["name"] for e in tr.drain()] == ["lease", "lease"]  # b/e pair
    assert tr.balanced()


def test_flight_recorder_dumps_on_trigger(tmp_path):
    clock = SimClock()
    tr = Tracer(clock=clock, max_events=8)
    path = str(tmp_path / "dump.json")
    tr.dump_on("transport.evict", path, after=2, limit=1)
    tr.instant("transport.evict", track="wire")      # 1st: below after
    assert not tr.dumps_written
    for i in range(10):                              # context in the ring
        clock.t = float(i)
        tr.instant("ticket.route", track="queue")
    tr.instant("transport.evict", track="wire")      # 2nd: fires
    assert tr.dumps_written == [path]
    doc = json.loads(open(path).read())
    names = [e["name"] for e in doc["traceEvents"] if e.get("ph") == "i"]
    assert names[-1] == "transport.evict"
    assert len([n for n in names if n == "ticket.route"]) <= 8
    # limit=1: a third occurrence (even x2 past `after`) stays silent
    tr.instant("transport.evict", track="wire")
    tr.instant("transport.evict", track="wire")
    assert tr.dumps_written == [path]


def test_flight_recorder_validates_arguments(tmp_path):
    tr = Tracer()
    with pytest.raises(ValueError):
        tr.dump_on("x", str(tmp_path / "d.json"), after=0)
    with pytest.raises(ValueError):
        tr.dump_on("x", str(tmp_path / "d.json"), limit=0)


def test_slo_breach_instant_can_trigger_flight_dump(tmp_path):
    """The monitors and the recorder compose: a breach instant is a
    trigger like any other failure signal."""
    clock = SimClock()
    tr = Tracer(clock=clock, max_events=32)
    path = str(tmp_path / "slo_dump.json")
    tr.dump_on("slo.breach", path)
    reg = MetricsRegistry()
    reg.counter("round.lost_tickets_total", "Lost").inc(3)
    mon = SloMonitor(reg, DEFAULT_ROUND_SLOS, tracer=tr)
    assert not mon.ok()
    assert tr.dumps_written == [path]


# ---------------------------------------------------------------------------
# SLO monitor
# ---------------------------------------------------------------------------


def test_slo_validation():
    with pytest.raises(ValueError):
        Slo("bad-op", "m.x_total", "!=", 0.0)
    with pytest.raises(ValueError):
        Slo("bad-stat", "m.x_total", "<=", 0.0, stat="median")
    Slo("ok", "m.x_total", "<=", 1.0, stat="p99")       # fine


def test_slo_monitor_clean_registry_passes():
    reg = MetricsRegistry()
    h = reg.histogram("round.duration_seconds", "durations")
    for d in (0.2, 0.4, 0.9):
        h.observe(d)
    mon = SloMonitor(reg, DEFAULT_ROUND_SLOS)
    results = mon.evaluate()
    assert len(results) == len(DEFAULT_ROUND_SLOS)
    assert all(r.ok for r in results), [r.as_dict() for r in results]
    assert mon.breaches_total == 0
    # a metric nothing registered evaluates as 0.0, not an error
    assert all(r.value == 0.0 for r in results
               if r.slo.metric != "round.duration_seconds")


def test_slo_p95_past_last_bucket_reads_inf_and_trips():
    """Observations beyond the histogram's finite range must FAIL a
    latency gate — clamping them back under the threshold would make
    the gate untrippable."""
    reg = MetricsRegistry()
    h = reg.histogram("round.duration_seconds", "durations")
    for _ in range(20):
        h.observe(120.0)                       # all past the 60 s edge
    mon = SloMonitor(reg, DEFAULT_ROUND_SLOS)
    bad = [r for r in mon.evaluate() if not r.ok]
    assert [r.slo.name for r in bad] == ["round-latency-p95"]
    assert math.isinf(bad[0].value)
    assert mon.breaches_total == 1


def test_slo_counter_and_labelled_gauge_stats():
    reg = MetricsRegistry()
    reg.counter("queue.duplicate_results_total", "dups").inc(2)
    g = reg.gauge("fleet.clients_count", "clients", labels=("pool",))
    g.set(3, pool="a")
    g.set(4, pool="b")
    mon = SloMonitor(reg, [
        Slo("no-dups", "queue.duplicate_results_total", "==", 0.0),
        Slo("fleet-size", "fleet.clients_count", "<=", 10.0),
    ])
    res = {r.slo.name: r for r in mon.evaluate()}
    assert not res["no-dups"].ok and res["no-dups"].value == 2.0
    assert res["fleet-size"].ok and res["fleet-size"].value == 7.0


def test_trainer_round_result_carries_slo_verdicts():
    def _grad_task():
        def run(args, static):
            return {"grad": {"w": np.full(2, float(args), np.float32)},
                    "loss": float(args)}
        return TaskDef("backbone_shard", run, static_files=("weights",))

    async def body():
        fed = FederatedDistributor(
            2, timeout=5.0, redistribute_min=0.02,
            sizer=AdaptiveSizer(target_lease_time=0.02, max_size=8),
            watchdog_interval=0.005, grace=2.0)
        fed.register_task(_grad_task())
        fed.spawn_clients([ClientProfile(name=f"c{i}", speed=400.0)
                           for i in range(3)])
        reg = MetricsRegistry()
        async with FederatedTrainer(fed, timeout=20.0, metrics=reg,
                                    slos=DEFAULT_ROUND_SLOS) as tr:
            res = await tr.run_round([1.0, 2.0], shard_work=[1.0, 1.0],
                                     statics={"weights": {"round": 0}})
        await fed.shutdown()
        return res

    res = _run(body())
    assert res.slos is not None and len(res.slos) == len(DEFAULT_ROUND_SLOS)
    assert res.slo_ok, res.slos
    assert {s["name"] for s in res.slos} == \
        {s.name for s in DEFAULT_ROUND_SLOS}


def test_trainer_slos_require_metrics():
    fed = FederatedDistributor(2, timeout=5.0)
    with pytest.raises(ValueError):
        FederatedTrainer(fed, slos=DEFAULT_ROUND_SLOS)
    fed.keep_alive = False


# ---------------------------------------------------------------------------
# collector edge cases (zero-connection, mid-eviction, re-collection)
# ---------------------------------------------------------------------------


def _square(x, static):
    return x * x


def test_collect_transport_with_zero_post_handshake_connections():
    async def go():
        d = AsyncDistributor(timeout=5.0)
        server = TransportServer(d, fleet=FleetAggregator())
        await server.start()
        reg = MetricsRegistry()
        collect_fabric(reg, transport=server)     # fleet auto-discovered
        await server.stop()
        return reg

    reg = _run(go())
    assert reg.get("transport.connections_count").value() == 0
    assert reg.get("fleet.clients_count").value() == 0
    assert reg.get("transport.telemetry_frames_total").value() == 0


def test_collect_during_eviction_sweep_and_after():
    """Collection races the eviction sweep without error, and the
    post-sweep snapshot reflects the eviction exactly once."""
    async def go():
        d = AsyncDistributor(timeout=20.0, redistribute_min=0.0,
                             watchdog_interval=5.0, grace=1000.0)
        d.register_task(TaskDef("sq", _square))
        d.add_work("sq", [3])
        server = TransportServer(d, heartbeat_timeout=600.0)
        addr = await server.start()
        clients, tasks = spawn_remote_clients(
            addr, [ClientProfile(name="gone", speed=1.0)],
            heartbeat_interval=None)
        while server.stats()["connections"] == 0:
            await asyncio.sleep(0.01)
        reg = MetricsRegistry()
        collect_fabric(reg, transport=server)     # mid-life collection
        live = reg.get("transport.connections_count").value()
        await server.evict_client("gone")         # the sweep's eager path
        collect_fabric(reg, transport=server)
        first = reg.snapshot()
        collect_fabric(reg, transport=server)     # idempotent re-collect
        for c in clients:
            await c.stop()
        await asyncio.gather(*tasks, return_exceptions=True)
        await server.stop()
        return live, first, reg.snapshot()

    live, first, second = _run(go())
    assert live == 1
    assert first == second
    evic = second["transport.evictions_total"]["values"][0]["value"]
    assert evic == 1


def test_recollection_idempotent_after_member_kill():
    async def go():
        fed = FederatedDistributor(
            2, timeout=5.0, redistribute_min=0.02,
            sizer=AdaptiveSizer(target_lease_time=0.02, max_size=8),
            watchdog_interval=0.005, grace=2.0)
        fed.register_task(TaskDef("sq", _square))
        fed.add_work("sq", list(range(8)))
        fed.spawn_clients([ClientProfile(name=f"c{i}", speed=400.0)
                           for i in range(3)])
        ok = await fed.run_until_done(timeout=20.0)
        await fed.kill_member(0)
        reg = MetricsRegistry()
        collect_fabric(reg, distributor=fed)
        first = reg.snapshot()
        collect_fabric(reg, distributor=fed)      # re-collect: no drift
        await fed.shutdown()
        return ok, first, reg.snapshot()

    ok, first, second = _run(go())
    assert ok
    assert first == second
    assert first["federation.alive_count"]["values"][0]["value"] == 1


def test_collect_fleet_drop_reasons():
    fl = FleetAggregator(max_clients=1)
    fl.ingest("c0", _client_batch())
    fl.ingest("c1", _client_batch())              # over max_clients
    fl.ingest("c0", None)                         # parse failure upstream
    reg = MetricsRegistry()
    collect_fleet(reg, fl)
    drops = {r["labels"]["reason"]: r["value"]
             for r in reg.snapshot()["fleet.drops_total"]["values"]}
    assert drops["batch"] == 2
    assert reg.get("fleet.batches_total").value() == 1
    collect_fleet(reg, fl)                        # set_total: idempotent
    assert reg.get("fleet.batches_total").value() == 1


# ---------------------------------------------------------------------------
# end-to-end: all-remote federated round on one skew-corrected timeline
# ---------------------------------------------------------------------------


def _grad_run(args, static):
    return {"grad": {"w": np.full(2, float(args), np.float32)},
            "loss": float(args)}


def _grad_task_remote():
    return TaskDef("backbone_shard", _grad_run, static_files=("weights",))


def test_all_remote_round_exports_one_skew_corrected_timeline():
    """The PR's acceptance bar: server round lanes and remote client
    execute lanes land in ONE merged Perfetto export on a common
    timeline, with the remote clients' (deliberately skewed) clocks
    corrected by the heartbeat-echo estimate."""
    SKEW = 1000.0                 # client clocks run 1000 s ahead

    async def go():
        server_tr = Tracer()
        d = AsyncDistributor(
            timeout=10.0, redistribute_min=0.02,
            sizer=AdaptiveSizer(target_lease_time=0.05, max_size=4),
            watchdog_interval=0.01, tracer=server_tr)
        server_tr.clock = d.queue.clock
        fleet = FleetAggregator(tracer=server_tr)
        d.register_task(_grad_task_remote())
        server = TransportServer(d, fleet=fleet)
        addr = await server.start()

        loop = asyncio.get_running_loop()
        clients, tasks = [], []
        for i in range(2):
            skewed = (lambda off=SKEW: time.monotonic() + off)
            ctr = Tracer(clock=skewed, max_events=512)
            c = RemoteBrowserClient(
                addr[0], addr[1],
                ClientProfile(name=f"r{i}", speed=100.0, latency=0.05),
                heartbeat_interval=0.01, tracer=ctr,
                metrics=MetricsRegistry(), telemetry=True, clock=skewed)
            clients.append(c)
            tasks.append(loop.create_task(c.run()))

        reg = MetricsRegistry()
        async with FederatedTrainer(d, timeout=30.0, metrics=reg,
                                    slos=DEFAULT_ROUND_SLOS) as tr:
            res = await tr.run_round([1.0, 2.0, 3.0, 4.0],
                                     shard_work=[1.0] * 4,
                                     statics={"weights": {"round": 0}})
        for c in clients:
            await c.stop()
        await asyncio.gather(*tasks, return_exceptions=True)
        stats = server.stats()
        await server.stop()
        return res, fleet, server_tr, clients, stats

    res, fleet, server_tr, clients, stats = _run(go())
    assert res.complete and res.slo_ok

    # the wire carried telemetry and the server accepted it
    assert stats["telemetry_accepted"] > 0
    assert all(c.telemetry_sent > 0 for c in clients)
    assert fleet.clients() == ["r0", "r1"]

    # skew estimation recovered the injected offset (error <= a few RTTs)
    for name in ("r0", "r1"):
        sk = fleet.skew(name)
        assert sk is not None and sk.samples >= 1
        assert abs(sk.offset + SKEW) < 1.0, (name, sk)

    # remote metrics merged under client labels
    rows = fleet.snapshot()["client.executed_total"]["values"]
    by_client = {r["labels"]["client"]: r["value"] for r in rows}
    assert set(by_client) == {"r0", "r1"}
    assert sum(by_client.values()) == sum(c.executed for c in clients) > 0

    # ONE merged timeline: the server's round lane plus remote execute
    # lanes, with corrected remote timestamps inside the round window
    merged = fleet.merged_events()
    rounds = [e for e in merged if e["name"] == "round" and e["ph"] == "X"]
    execs = [e for e in merged
             if e["name"] == "client.execute" and e["ph"] == "X"
             and e["track"].startswith("client:r")]
    assert rounds and execs
    r0, r1 = rounds[0]["ts"], rounds[0]["ts"] + rounds[0]["dur"]
    for e in execs:
        assert r0 - 1.0 <= e["ts"] <= r1 + 1.0, (e, r0, r1)

    # without correction the same spans sit ~SKEW beyond the round window
    raw = [e for e in fleet.remote_events(corrected=False)
           if e["name"] == "client.execute"]
    assert raw and all(e["ts"] > r1 + 0.5 * SKEW for e in raw)

    # the export renders with every lane present
    doc = fleet.chrome_trace()
    lanes = {e["args"]["name"] for e in doc["traceEvents"]
             if e["name"] == "thread_name"}
    assert {"trainer", "client:r0", "client:r1"} <= lanes
    assert fleet.to_json() == fleet.to_json()      # stable serialization
