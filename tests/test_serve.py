"""Serving-path tests: batched greedy generation via prefill + decode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.serve import generate
from repro.models.model import build_model
from repro.sharding.spec import values_tree


@pytest.mark.parametrize("arch", ["qwen3-4b", "rwkv6-1.6b"])
def test_generate_matches_teacher_forced_forward(arch):
    """Greedy generation must agree with argmax over a teacher-forced full
    forward on the same (generated) sequence."""
    cfg = get_smoke_config(arch)
    api = build_model(cfg, compute_dtype=jnp.float32, remat=False)
    params = values_tree(api.init(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(0)
    b, s, gen = 2, 12, 4
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)

    toks = generate(api, params, prompts, gen=gen)
    assert toks.shape == (b, gen)
    assert (np.asarray(toks) < cfg.vocab_size).all()

    # teacher-forced check for the FIRST generated token: argmax of the
    # full forward at the last prompt position
    batch = {"tokens": prompts, "labels": prompts,
             "mask": jnp.ones((b, s), jnp.float32)}
    logits, _, _ = api.forward_features(params, batch)
    first = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
    np.testing.assert_array_equal(np.asarray(first), np.asarray(toks[:, 0]))


def test_generate_sliding_window_arch():
    """Generation through a ring-buffer (windowed) cache stays finite and
    in-vocab."""
    cfg = dataclasses.replace(get_smoke_config("minitron-4b"),
                              sliding_window=8)
    api = build_model(cfg, compute_dtype=jnp.float32, remat=False)
    params = values_tree(api.init(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 16)),
                          jnp.int32)
    toks = generate(api, params, prompts, gen=6)
    assert toks.shape == (1, 6)
    assert (np.asarray(toks) < cfg.vocab_size).all()
