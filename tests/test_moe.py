"""MoE routing/dispatch tests."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.models.moe import (_positions_within_expert, apply_moe, init_moe,
                              router_topk)
from repro.sharding.spec import values_tree


def dense_moe_oracle(p, cfg, x):
    """Per-token dense computation: every expert evaluated, top-k combined."""
    from repro.models.layers import mlp_act

    b, s, d = x.shape
    n = b * s
    xf = x.reshape(n, d)
    logits = xf @ p["router"]
    probs, weights, idx = router_topk(logits, cfg.moe.num_experts_per_tok)
    outs = []
    for e in range(cfg.moe.num_experts):
        h = xf @ p["w_up"][e]
        g = xf @ p["w_gate"][e] if "w_gate" in p else None
        h = mlp_act(cfg, h, g)
        outs.append(h @ p["w_down"][e])
    outs = jnp.stack(outs, 1)             # (n, E, d)
    y = jnp.zeros_like(xf)
    for j in range(cfg.moe.num_experts_per_tok):
        y += outs[jnp.arange(n), idx[:, j]] * weights[:, j:j + 1]
    return y.reshape(b, s, d)


def test_moe_matches_dense_oracle_with_ample_capacity():
    cfg = get_smoke_config("dbrx-132b")
    p = values_tree(init_moe(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, _ = apply_moe(p, cfg, x, capacity_factor=16.0)  # no drops
    y_ref = dense_moe_oracle(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5)


def test_moe_capacity_drops_tokens_but_stays_finite():
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    p = values_tree(init_moe(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y, aux = apply_moe(p, cfg, x, capacity_factor=0.25)  # heavy drops
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0


def test_router_topk_weights_normalised():
    logits = jax.random.normal(jax.random.PRNGKey(0), (32, 8))
    probs, w, idx = router_topk(logits, 3)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert (np.asarray(idx) < 8).all()
    # top-1 has the max prob
    np.testing.assert_array_equal(np.asarray(idx[:, 0]),
                                  np.asarray(probs.argmax(-1)))


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 7), min_size=1, max_size=128))
def test_positions_within_expert_are_dense_ranks(assignments):
    """Property: within each expert, positions are 0..count-1 exactly once,
    in arrival order."""
    e_flat = jnp.asarray(assignments, jnp.int32)
    pos = np.asarray(_positions_within_expert(e_flat, 8))
    seen = {}
    for e, p in zip(assignments, pos):
        assert p == seen.get(e, 0)
        seen[e] = p + 1


def test_sharded_moe_matches_local_subprocess():
    """The expert-parallel shard_map path must equal the local path.
    Runs in a subprocess so the forced 8-device CPU flag doesn't leak."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models.moe import apply_moe, init_moe
        from repro.sharding.spec import values_tree, ShardCtx, use_shard_ctx
        from repro.sharding.rules import rules_for_strategy
        from repro.launch.mesh import make_local_mesh
        cfg = get_smoke_config("dbrx-132b")
        p = values_tree(init_moe(jax.random.PRNGKey(0), cfg))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
        y_ref, _ = jax.jit(lambda p, x: apply_moe(p, cfg, x,
                                                  capacity_factor=8.0))(p, x)
        mesh = make_local_mesh(data=2, model=4)
        rules = rules_for_strategy("fsdp_tp", mesh.axis_names)
        with use_shard_ctx(ShardCtx(mesh, rules)):
            y_sh, _ = jax.jit(lambda p, x: apply_moe(
                p, cfg, x, capacity_factor=8.0))(p, x)
        assert np.allclose(np.asarray(y_ref), np.asarray(y_sh), atol=2e-5), \\
            float(jnp.abs(y_ref - y_sh).max())
        print("SHARDED_OK")
    """)
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=__file__.rsplit("/tests/", 1)[0], timeout=300)
    assert "SHARDED_OK" in r.stdout, r.stderr[-2000:]
