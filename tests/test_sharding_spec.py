"""Property tests for logical-axis -> PartitionSpec resolution."""
import jax
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import AXIS_RULES, rules_for_strategy
from repro.sharding.spec import Param, axes_tree, to_pspec, values_tree


class FakeMesh:
    def __init__(self, sizes):
        self.shape = sizes
        self.axis_names = tuple(sizes)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})

LOGICAL = [None, "batch", "embed", "mlp", "heads", "kv_heads", "vocab",
           "expert", "layers", "seq", "mamba", "rwkv_head"]


@settings(max_examples=200, deadline=None)
@given(st.lists(st.sampled_from(LOGICAL), min_size=1, max_size=5),
       st.sampled_from(list(AXIS_RULES)),
       st.lists(st.sampled_from([1, 2, 8, 16, 48, 64, 128, 256, 151936]),
                min_size=1, max_size=5))
def test_to_pspec_never_produces_invalid_sharding(axes, strategy, dims):
    """For ANY combination of logical axes / rule table / tensor shape:
    (1) no mesh axis is used twice, (2) every sharded dim is divisible by
    its mesh-axis product."""
    axes = tuple(axes)
    dims = tuple((dims * 5)[: len(axes)])
    for mesh in (MESH, MESH3):
        rules = rules_for_strategy(strategy, mesh.axis_names)
        spec = to_pspec(axes, rules, mesh=mesh, shape=dims)
        used = []
        for dim, entry in zip(dims, tuple(spec)):
            if entry is None:
                continue
            flat = entry if isinstance(entry, tuple) else (entry,)
            n = 1
            for a in flat:
                assert a not in used, (spec, axes)
                used.append(a)
                n *= mesh.shape[a]
            assert dim % n == 0, (spec, axes, dims)


def test_rules_filter_drops_missing_axes():
    rules = rules_for_strategy("fsdp_tp", ("data", "model"))
    assert rules["batch"] == "data"  # 'pod' dropped
    rules3 = rules_for_strategy("fsdp_tp", ("pod", "data", "model"))
    assert rules3["batch"] == ("pod", "data")


def test_unknown_strategy_raises():
    with pytest.raises(KeyError):
        rules_for_strategy("nope", ("data",))


def test_values_and_axes_trees_align():
    import jax.numpy as jnp
    tree = {"a": Param(jnp.ones((2, 3)), ("embed", "mlp")),
            "b": {"c": Param(jnp.zeros((4,)), (None,))}}
    vals = values_tree(tree)
    axes = axes_tree(tree)
    assert vals["a"].shape == (2, 3)
    assert axes["a"] == ("embed", "mlp")
    assert axes["b"]["c"] == (None,)
