"""Per-architecture smoke tests (REQUIRED by the assignment): for each of
the 10 assigned architectures, instantiate the reduced same-family config
(2 layers, d_model<=512, <=4 experts), run one forward/train step on CPU,
assert output shapes and the absence of NaNs.  Also checks decode/prefill
consistency per family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core.split_parallel import make_train_step
from repro.models.model import build_model
from repro.optim import get_optimizer
from repro.sharding.spec import values_tree


def _batch(cfg, b=2, s=24, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    s_text = s - (cfg.num_patches if cfg.family == "vlm" else 0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s_text)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s_text)),
                              jnp.int32),
        "mask": jnp.ones((b, s_text), jnp.float32),
    }
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(0, 0.02, (b, cfg.num_patches, cfg.d_model)),
            jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 0.02, (b, cfg.encoder_seq_len, cfg.d_model)),
            jnp.float32)
    return batch, s_text


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_reduced_config_constraints(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    api = build_model(cfg, compute_dtype=jnp.float32)
    params = values_tree(api.init(jax.random.PRNGKey(0)))
    batch, s_text = _batch(cfg)

    logits, aux, feats = api.forward_features(params, batch)
    b = batch["tokens"].shape[0]
    s_total = s_text + (cfg.num_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (b, s_total, cfg.padded_vocab)
    assert feats.shape == (b, s_total, cfg.d_model)
    assert np.isfinite(np.asarray(logits)).all(), "NaN/Inf in logits"

    opt = get_optimizer("adagrad", 0.05)
    init_state, step = make_train_step(api, opt, strategy="dp_full")
    state = init_state(jax.random.PRNGKey(0))
    state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["total"])), "NaN loss"
    # params actually changed
    before = values_tree(api.init(jax.random.PRNGKey(0)))
    diffs = jax.tree_util.tree_map(
        lambda a, b_: float(jnp.abs(a - b_).max()), before, state.params)
    assert max(jax.tree_util.tree_leaves(diffs)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_consistency(arch):
    """decode_step at position s must reproduce the full-forward logits at
    position s (KV cache / recurrent state correctness).  MoE archs use an
    ample capacity factor so token-choice drops (which legitimately differ
    between a 15- and 16-token dispatch) don't mask cache bugs."""
    cfg = get_smoke_config(arch)
    if cfg.is_moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    api = build_model(cfg, compute_dtype=jnp.float32)
    params = values_tree(api.init(jax.random.PRNGKey(0)))
    b, s = 2, 16 + (cfg.num_patches if cfg.family == "vlm" else 0)
    batch, s_text = _batch(cfg, b=b, s=s)
    total = s_text + (cfg.num_patches if cfg.family == "vlm" else 0)

    # full forward over all tokens
    logits_full, _, _ = api.forward_features(params, batch)

    # prefill on the prefix, then decode the last token
    prefix = dict(batch)
    prefix["tokens"] = batch["tokens"][:, :-1]
    logits_pre, cache = api.prefill(params, prefix, cache_len=total)
    last_tok = batch["tokens"][:, -1:]
    logits_dec, _ = api.decode_step(params, cache, last_tok,
                                    jnp.int32(total - 1))

    # prefill's last-position logits == full forward at position -2
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, 0]), np.asarray(logits_full[:, -2]),
        atol=2e-3, rtol=1e-3)
    # decode at the final position == full forward at the final position
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(logits_full[:, -1]),
        atol=2e-3, rtol=1e-3)


@pytest.mark.parametrize("arch", ["qwen3-4b", "rwkv6-1.6b",
                                  "jamba-1.5-large-398b"])
def test_smoke_training_reduces_loss(arch):
    """A few steps on the Markov synthetic stream must reduce loss."""
    from repro.data import make_lm_batch

    cfg = get_smoke_config(arch)
    api = build_model(cfg, compute_dtype=jnp.float32)
    opt = get_optimizer("adagrad", 0.1)
    init_state, step = make_train_step(api, opt, strategy="dp_full")
    state = init_state(jax.random.PRNGKey(0))
    jstep = jax.jit(step)
    rng = np.random.default_rng(0)
    losses = []
    for _ in range(10):
        batch = {k: jnp.asarray(v)
                 for k, v in make_lm_batch(rng, 4, 32,
                                           cfg.vocab_size).items()}
        state, m = jstep(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_analytic_param_count_matches_init():
    from repro.models.model import count_params_analytic

    from repro.sharding.spec import values_tree as vt

    for arch in ("qwen1.5-0.5b", "dbrx-132b", "rwkv6-1.6b"):
        cfg = get_smoke_config(arch)
        api = build_model(cfg)
        tree = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
        n_manual = sum(int(np.prod(x.shape))
                       for x in jax.tree_util.tree_leaves(vt(tree)))
        assert count_params_analytic(cfg) == n_manual
        if cfg.is_moe:
            assert count_params_analytic(cfg, active_only=True) < n_manual
