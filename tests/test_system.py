"""End-to-end behaviour tests for the paper's system: Sashimi distributing
real work (kNN classification, the Table-2 workload) and Sukiyaki's CNN
training with the modified AdaGrad — plus the data pipeline driven by the
ticket scheduler."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_cnn import smoke_config
from repro.core.distributor import ClientProfile, Distributor, TaskDef
from repro.data import TicketDataLoader, clustered_images, make_lm_batch
from repro.data.synthetic import InlineWorker
from repro.models import cnn
from repro.optim import adagrad
from repro.sharding.spec import values_tree


def test_distributed_knn_correctness():
    """The Table-2 workload: nearest-neighbour classification distributed
    over browser clients must equal the local result."""
    train_x, train_y = clustered_images(200, image_size=8, channels=1,
                                        seed=0)
    test_x, test_y = clustered_images(40, image_size=8, channels=1, seed=1)
    tr = train_x.reshape(len(train_x), -1)
    te = test_x.reshape(len(test_x), -1)

    def knn_local(q):
        d = ((tr - q[None]) ** 2).sum(-1)
        return int(train_y[np.argmin(d)])

    expected = [knn_local(q) for q in te]

    d = Distributor(timeout=5.0, redistribute_min=0.01,
                    project_name="knn")
    d.static_store["train"] = (tr, train_y)

    def knn_task(args, static):
        tr_x, tr_y = static["train"]
        q = te[args]
        dist = ((tr_x - q[None]) ** 2).sum(-1)
        return int(tr_y[np.argmin(dist)])

    d.register_task(TaskDef("knn", knn_task, static_files=("train",)))
    tids = d.queue.add_many("knn", list(range(len(te))))
    d.spawn_clients([ClientProfile(name=f"c{i}") for i in range(4)])
    assert d.queue.wait_all(timeout=30)
    d.shutdown()
    res = d.queue.results()
    assert [res[t] for t in tids] == expected
    # the synthetic clusters are separable: kNN should be accurate
    acc = np.mean([r == y for r, y in zip(expected, test_y)])
    assert acc > 0.9


def test_paper_cnn_trains_on_clustered_images():
    """Sukiyaki's deep CNN + modified AdaGrad reduces loss / error rate."""
    ccfg = smoke_config()
    params = values_tree(cnn.init_cnn(jax.random.PRNGKey(0), ccfg))
    opt = adagrad(0.02, beta=1.0)
    opt_state = opt.init(params)
    images, labels = clustered_images(
        256, num_classes=ccfg.num_classes, image_size=ccfg.image_size,
        channels=ccfg.in_channels, seed=0)

    @jax.jit
    def step(params, opt_state, x, y):
        def loss_fn(p):
            return cnn.nll_loss(cnn.forward(p, ccfg, x), y)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    losses = []
    bs = ccfg.batch_size
    for i in range(30):
        j = (i * bs) % (len(images) - bs)
        x = jnp.asarray(images[j:j + bs])
        y = jnp.asarray(labels[j:j + bs])
        params, opt_state, loss = step(params, opt_state, x, y)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, losses

    logits = cnn.forward(params, ccfg, jnp.asarray(images[:128]))
    err = float(cnn.error_rate(logits, jnp.asarray(labels[:128])))
    assert err < 0.5


def test_cnn_split_halves_compose():
    ccfg = smoke_config()
    params = values_tree(cnn.init_cnn(jax.random.PRNGKey(0), ccfg))
    x = jnp.asarray(clustered_images(4, image_size=ccfg.image_size,
                                     channels=ccfg.in_channels)[0])
    feats = cnn.conv_features(params, ccfg, x)
    assert feats.shape == (4, ccfg.feature_dim)
    logits = cnn.fc_logits(params, ccfg, feats)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(cnn.forward(params, ccfg, x)),
                               atol=1e-6)


def test_ticket_data_loader_exactly_once():
    """The ticket-driven input pipeline assembles each global batch from
    microbatch tickets exactly once, in order."""

    def make_mb(step, i):
        return {"tokens": np.full((2, 4), step * 10 + i, np.int32)}

    loader = TicketDataLoader(make_mb, num_microbatches=4)
    gb = loader.global_batch(3, [InlineWorker()])
    assert gb["tokens"].shape == (8, 4)
    np.testing.assert_array_equal(gb["tokens"][:, 0],
                                  [30, 30, 31, 31, 32, 32, 33, 33])


def test_lm_batch_is_learnable_markov_stream():
    rng = np.random.default_rng(0)
    b = make_lm_batch(rng, 8, 64, 997, noise=0.0)
    # noise-free stream follows labels = (5*tokens + 17) % V exactly
    np.testing.assert_array_equal(b["labels"],
                                  (5 * b["tokens"] + 17) % 997)
