"""Property tests for the protocol-v2 wire codecs (repro.core.wire).

Two invariants carry the whole binary protocol:

  * **Codec identity** — ``decode_binary(*encode_binary(x))`` is
    bit-exact for every pytree of arrays (any dtype including bfloat16,
    empty arrays, 0-d shapes, nested dicts/lists/tuples/dataclasses).
  * **Delta identity** — for ANY publish history, a client that applies
    the registry's changed-leaves delta to its cached full payload ends
    up bit-for-bit identical to a client that downloaded the full
    payload.  Deltas are an optimisation, never an approximation.

Runs under real `hypothesis` (CI) or the deterministic shim
(tests/_hypothesis_shim.py) — only the shared API subset is used.
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra.numpy import array_shapes, arrays

from repro.core.distributor import (DELTA_HISTORY, HttpServerBase,
                                    build_delta_fetched)
from repro.core.split_parallel import TrainState
from repro.core.wire import (DeltaApplyError, ProtocolError, apply_delta,
                             decode_binary, encode_binary, flatten_tree,
                             leaf_equal)

try:
    import ml_dtypes
    BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:          # pragma: no cover - jax always ships ml_dtypes
    ml_dtypes = None
    BF16 = None


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def assert_trees_bitequal(a, b):
    """Structural + bit-exact leaf equality (NaN == NaN)."""
    fa, fb = flatten_tree(a), flatten_tree(b)
    assert fa.keys() == fb.keys()
    for path in fa:
        assert leaf_equal(fa[path], fb[path]), path


def roundtrip(obj):
    manifest, buffer = encode_binary(obj)
    # the manifest must survive a JSON hop (it rides in the header frame)
    import json
    manifest = json.loads(json.dumps(manifest))
    return decode_binary(manifest, buffer)


# ---------------------------------------------------------------------------
# codec identity
# ---------------------------------------------------------------------------


NUMERIC_DTYPES = ["float32", "float64", "float16", "int8", "int32",
                  "int64", "uint8", "uint16"]


@settings(max_examples=60, deadline=None)
@given(arrays(dtype=st.sampled_from(NUMERIC_DTYPES),
              shape=array_shapes(min_dims=0, max_dims=4, min_side=0,
                                 max_side=5)))
def test_roundtrip_single_array(arr):
    out = roundtrip(arr)
    assert out.dtype == arr.dtype and out.shape == arr.shape
    assert out.tobytes() == arr.tobytes()


@settings(max_examples=40, deadline=None)
@given(st.lists(arrays(dtype=st.sampled_from(NUMERIC_DTYPES),
                       shape=array_shapes(min_dims=0, max_dims=3,
                                          min_side=0, max_side=4)),
                min_size=0, max_size=6),
       st.integers(min_value=-5, max_value=5))
def test_roundtrip_mixed_pytree(arrs, scalar):
    obj = {"arrays": arrs,
           "nested": {"t": tuple(arrs[:2]), "s": scalar, "none": None},
           "strings": ["alpha", "beta"], "flag": True}
    assert_trees_bitequal(roundtrip(obj), obj)


def test_roundtrip_bfloat16_bitexact():
    if BF16 is None:
        pytest.skip("ml_dtypes unavailable")
    rng = np.random.default_rng(7)
    arr = rng.standard_normal((17, 3)).astype(BF16)
    out = roundtrip(arr)
    assert out.dtype == BF16 and out.shape == arr.shape
    assert out.tobytes() == arr.tobytes()


def test_roundtrip_special_floats_bitexact():
    arr = np.array([np.nan, np.inf, -np.inf, -0.0, np.finfo(np.float32).tiny],
                   np.float32)
    out = roundtrip(arr)
    assert out.tobytes() == arr.tobytes()          # NaN payload preserved
    # -0.0 stays -0.0 (sign bit survives, which == comparison would hide)
    assert np.signbit(out[3])


def test_roundtrip_empty_and_zero_dim_arrays():
    for arr in (np.zeros((0,), np.float32), np.zeros((3, 0, 2), np.int64),
                np.float32(0).reshape(())):
        out = roundtrip(np.asarray(arr))
        assert out.dtype == arr.dtype and out.shape == np.shape(arr)


def test_roundtrip_train_state_dataclass():
    if BF16 is None:
        pytest.skip("ml_dtypes unavailable")
    rng = np.random.default_rng(3)
    params = {"conv1": {"w": rng.standard_normal((5, 5, 3, 16)).astype(BF16),
                        "b": np.zeros((16,), BF16)},
              "fc": {"w": rng.standard_normal((320, 10)).astype(BF16),
                     "b": np.zeros((10,), BF16)}}
    state = TrainState(params=params, head=None, head_stale=None,
                       opt_state={"m": [np.ones((4,), np.float32)]},
                       head_opt_state=None, prev_features=None,
                       prev_labels=None, prev_mask=None,
                       step=np.int32(11))
    out = roundtrip(state)
    assert isinstance(out, TrainState)
    assert_trees_bitequal(out, state)


def test_jax_arrays_decode_as_numpy():
    import jax.numpy as jnp
    obj = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}
    out = roundtrip(obj)
    assert isinstance(out["w"], np.ndarray)
    assert out["w"].tobytes() == np.asarray(obj["w"]).tobytes()


def test_encode_rejects_object_arrays():
    with pytest.raises((ProtocolError, Exception)):
        manifest, buffer = encode_binary(np.array([object()], dtype=object))
        decode_binary(manifest, buffer)


# ---------------------------------------------------------------------------
# flatten / apply_delta algebra
# ---------------------------------------------------------------------------


def _tree_strategy():
    leaf = st.one_of(st.integers(min_value=-99, max_value=99),
                     arrays(dtype=st.sampled_from(["float32", "int32"]),
                            shape=array_shapes(min_dims=1, max_dims=2,
                                               min_side=1, max_side=3)))
    return st.lists(leaf, min_size=1, max_size=5).map(
        lambda leaves: {"items": leaves,
                        "pair": (leaves[0], len(leaves)),
                        "meta": {"n": len(leaves)}})


@settings(max_examples=40, deadline=None)
@given(_tree_strategy())
def test_apply_full_delta_reconstructs_tree(tree):
    flat = flatten_tree(tree)
    rebuilt = apply_delta(tree, flat)          # splice every leaf onto itself
    assert_trees_bitequal(rebuilt, tree)


@settings(max_examples=40, deadline=None)
@given(_tree_strategy(), st.integers(min_value=0, max_value=1_000_000))
def test_apply_partial_delta_only_touches_changed_paths(tree, seed):
    rng = np.random.default_rng(seed)
    flat = flatten_tree(tree)
    paths = sorted(flat.keys())
    chosen = [p for p in paths if rng.random() < 0.5]
    delta = {p: (np.asarray(flat[p]) + 1 if hasattr(flat[p], "dtype")
                 else flat[p]) for p in chosen}
    out = flatten_tree(apply_delta(tree, delta))
    for p in paths:
        expect = delta[p] if p in delta else flat[p]
        assert leaf_equal(out[p], expect), p


def test_apply_delta_rejects_unknown_paths():
    with pytest.raises(DeltaApplyError):
        apply_delta({"a": 1}, {((0, "missing"),): 2})
    with pytest.raises(DeltaApplyError):
        apply_delta({"a": [1, 2]}, {((0, "a"), (1, 5)): 9})


def test_apply_delta_is_copy_on_write():
    base = {"hot": np.zeros((2,), np.float32), "cold": np.ones((2,),
                                                               np.float32)}
    out = apply_delta(base, {((0, "hot"),): np.full((2,), 7, np.float32)})
    assert out["cold"] is base["cold"]             # untouched leaf shared
    assert float(base["hot"][0]) == 0.0            # base never mutated


# ---------------------------------------------------------------------------
# delta-encode -> apply == full payload, over real publish histories
# ---------------------------------------------------------------------------


def _publish(rng, n_leaves):
    """A random full payload with n_leaves float32 leaf arrays."""
    return {"params": {f"l{i}": rng.standard_normal(4).astype(np.float32)
                       for i in range(n_leaves)},
            "round": int(rng.integers(0, 1000))}


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=2, max_value=DELTA_HISTORY + 3),
       st.integers(min_value=0, max_value=1_000_000))
def test_delta_vs_full_bitexact_over_random_history(n_publishes, seed):
    """For every (publish history, client base version) pair: applying the
    served delta to the base payload == the current full payload,
    bit-exact — or the registry refuses the delta (outside the horizon /
    structure change) and the client takes a full download."""
    rng = np.random.default_rng(seed)
    reg = HttpServerBase()
    history = []                               # [(version, full_value)]
    value = _publish(rng, n_leaves=4)
    for _ in range(n_publishes):
        # mutate a random subset of leaves (sometimes none -> pure re-tag)
        value = {"params": {k: (rng.standard_normal(4).astype(np.float32)
                                if rng.random() < 0.4 else v)
                            for k, v in value["params"].items()},
                 "round": int(rng.integers(0, 1000))}
        reg.add_static("w", value)
        history.append((reg.static_version("w"),
                        flatten_tree(value)))
    current_version, current_flat = history[-1]
    for base_version, base_flat in history[:-1]:
        got = reg.serve_static_versioned("w", base_version, delta=True)
        if got.delta_base is None:
            # horizon fallback: full payload, still the current value
            assert got.version == current_version
            assert flatten_tree(got.value).keys() == current_flat.keys()
            continue
        assert got.delta_base == base_version
        base_value = {"params": {}, "round": None}
        rebuilt = apply_delta(
            {"params": {k[-1][1]: v for k, v in base_flat.items()
                        if k[0] == (0, "params")},
             "round": base_flat[((0, "round"),)]},
            got.value)
        flat = flatten_tree(rebuilt)
        assert flat.keys() == current_flat.keys()
        for p in flat:
            assert leaf_equal(flat[p], current_flat[p]), p
        del base_value


def test_delta_refused_past_history_horizon():
    reg = HttpServerBase()
    reg.add_static("w", {"a": np.zeros(2, np.float32)})
    first = reg.static_version("w")
    for i in range(DELTA_HISTORY + 2):         # push `first` out the window
        reg.add_static("w", {"a": np.full(2, i, np.float32)})
    got = reg.serve_static_versioned("w", first, delta=True)
    assert got.delta_base is None and got.value is not None


def test_delta_refused_across_structure_change():
    reg = HttpServerBase()
    reg.add_static("w", {"a": np.zeros(2, np.float32)})
    v1 = reg.static_version("w")
    reg.add_static("w", {"a": np.zeros(2, np.float32),
                         "b": np.ones(2, np.float32)})   # new leaf: reset
    got = reg.serve_static_versioned("w", v1, delta=True)
    assert got.delta_base is None and set(got.value) == {"a", "b"}


def test_delta_skips_unchanged_leaves():
    reg = HttpServerBase()
    big = np.zeros((64,), np.float32)
    reg.add_static("w", {"frozen": big, "hot": np.zeros(2, np.float32)})
    v1 = reg.static_version("w")
    reg.add_static("w", {"frozen": big, "hot": np.ones(2, np.float32)})
    got = reg.serve_static_versioned("w", v1, delta=True)
    assert got.delta_base == v1
    assert set(got.value) == {((0, "hot"),)}   # only the changed leaf ships
    assert reg.delta_count["w"] == 1


def test_build_delta_fetched_none_cases():
    assert build_delta_fetched(None, 5, 3) is None          # no state
    reg = HttpServerBase()
    reg.add_static("w", {"a": 1})
    state = reg._static_delta["w"]
    v = reg.static_version("w")
    assert build_delta_fetched(state, v, None) is None      # unconditional
    assert build_delta_fetched(state, v, v) is None         # already current
    assert build_delta_fetched(state, v, v + 99) is None    # unknown base
