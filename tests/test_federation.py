"""Federation fabric tests: multi-distributor members over the shared
sharded store, work-stealing, member death/failover, the edge cache tier,
and SplitConcurrentDispatcher riding on a federation."""
import asyncio

import pytest

from repro.core.distributor import (AdaptiveSizer, ClientProfile,
                                    HttpServerBase, TaskDef)
from repro.core.federation import (EdgeCache, FederatedDistributor,
                                   FederationMember)
from repro.core.shards import shard_index
from repro.core.split_parallel import SplitConcurrentDispatcher


def _run(coro):
    return asyncio.run(coro)


def make_fed(n_members=2, **kw):
    kw.setdefault("timeout", 5.0)
    kw.setdefault("redistribute_min", 0.02)
    kw.setdefault("sizer", AdaptiveSizer(target_lease_time=0.02, max_size=8))
    kw.setdefault("watchdog_interval", 0.005)
    return FederatedDistributor(n_members, **kw)


# --- EdgeCache unit ---------------------------------------------------------


def test_edge_cache_read_through_and_hit_rate():
    origin = HttpServerBase()
    origin.add_static("ds", [1, 2, 3])
    origin.register_task(TaskDef("t", lambda x, _: x))
    edge = EdgeCache(origin, name="edge0", capacity=4)
    for _ in range(4):
        assert edge.serve_static("ds") == [1, 2, 3]
        assert edge.fetch_task("t").name == "t"
    # origin saw exactly ONE download per asset (the misses); the edge's
    # own ledger counts every client-facing request
    assert origin.download_count["ds"] == 1
    assert origin.download_count["task:t"] == 1
    assert edge.download_count["ds"] == 4
    s = edge.stats()
    assert s["requests"] == 8 and s["hits"] == 6 and s["misses"] == 2
    assert s["hit_rate"] == pytest.approx(6 / 8)


def test_edge_cache_task_and_static_namespaces_do_not_collide():
    """A static asset literally named 'task:<x>' must not poison task
    <x>'s cached code (and vice versa)."""
    origin = HttpServerBase()
    origin.add_static("task:t", "dataset-blob")
    origin.register_task(TaskDef("t", lambda x, _: x))
    edge = EdgeCache(origin, capacity=8)
    assert edge.serve_static("task:t") == "dataset-blob"
    assert edge.fetch_task("t").name == "t"
    assert edge.serve_static("task:t") == "dataset-blob"   # still the asset


def test_fewer_shards_than_members_rejected():
    with pytest.raises(ValueError):
        FederatedDistributor(4, n_shards=2)


def test_edge_cache_clear_rewarns_from_origin():
    origin = HttpServerBase()
    origin.add_static("ds", "blob")
    edge = EdgeCache(origin, capacity=4)
    edge.serve_static("ds")
    edge.clear()                       # edge node restart
    edge.serve_static("ds")
    assert origin.download_count["ds"] == 2


def test_edge_cache_lru_eviction_bounds_store():
    origin = HttpServerBase()
    for i in range(3):
        origin.add_static(f"k{i}", i)
    edge = EdgeCache(origin, capacity=2)
    for i in range(3):
        edge.serve_static(f"k{i}")     # k0 evicted when k2 lands
    edge.serve_static("k0")            # miss -> origin again
    assert origin.download_count["k0"] == 2
    assert edge.cache.evictions >= 1


# --- federation end-to-end --------------------------------------------------


def test_federated_end_to_end_multi_task_results_correct():
    async def main():
        fed = make_fed(2, n_shards=4)
        fed.register_task(TaskDef("square", lambda x, _: x * x))
        fed.register_task(TaskDef("neg", lambda x, _: -x))
        t_sq = fed.add_work("square", list(range(20)))
        t_ng = fed.add_work("neg", list(range(20)))
        fed.spawn_clients([ClientProfile(name=f"c{i}", speed=2000.0)
                           for i in range(4)])
        assert await fed.run_until_done(timeout=30.0)
        return fed, t_sq, t_ng

    fed, t_sq, t_ng = _run(main())
    res = fed.queue.results()
    assert [res[t] for t in t_sq] == [i * i for i in range(20)]
    assert [res[t] for t in t_ng] == [-i for i in range(20)]
    con = fed.console()
    assert con["executed"] == 40
    assert len(con["members"]) == 2


def test_least_loaded_spawn_balances_members():
    async def main():
        fed = make_fed(3)
        fed.register_task(TaskDef("echo", lambda x, _: x))
        fed.add_work("echo", list(range(6)))
        fed.spawn_clients([ClientProfile(name=f"c{i}", speed=2000.0)
                           for i in range(5)])
        counts = sorted(len(m.clients) for m in fed.members)
        assert counts == [1, 2, 2]
        assert await fed.run_until_done(timeout=30.0)

    _run(main())


def test_static_assets_served_through_member_edges():
    """Each member's edge fetches an asset from the origin at most once;
    every further client request is an edge hit."""
    async def main():
        fed = make_fed(2, n_shards=4)
        fed.add_static("dataset", [1, 2, 3])
        fed.register_task(TaskDef("use", lambda x, s: s["dataset"][x],
                                  static_files=("dataset",)))
        fed.add_work("use", [0, 1, 2] * 6)
        # two clients per member -> each edge serves two browsers
        fed.spawn_clients([ClientProfile(name=f"c{i}", speed=2000.0,
                                         cache_capacity=0)
                           for i in range(4)])
        assert await fed.run_until_done(timeout=30.0)
        return fed

    fed = _run(main())
    # origin egress = edge misses: at most one per member edge
    assert 1 <= fed.download_count["dataset"] <= 2
    edge_requests = sum(m.edge.download_count["dataset"]
                       for m in fed.members)
    assert edge_requests > fed.download_count["dataset"]
    for m in fed.members:
        if m.edge.download_count["dataset"]:
            assert m.edge.stats()["hit_rate"] > 0


def test_work_stealing_when_home_shards_dry():
    """All work lands on ONE member's home shard; the other member's
    clients must steal it through the global merge."""
    async def main():
        fed = make_fed(2, n_shards=2)
        # find a task living on member 0's home shard
        task = next(f"task{i}" for i in range(64)
                    if shard_index(f"task{i}", 2) % 2 == 0)
        fed.register_task(TaskDef(task, lambda x, _: x + 1))
        fed.add_work(task, list(range(30)))
        # clients ONLY on member 1, whose home shard owns nothing
        fed.spawn_clients([ClientProfile(name="thief0", speed=2000.0),
                           ClientProfile(name="thief1", speed=2000.0)],
                          member=1)
        assert await fed.run_until_done(timeout=30.0)
        return fed

    fed = _run(main())
    assert len(fed.queue.results()) == 30
    assert fed.members[1].steals >= 1
    assert fed.members[0].steals == 0


def test_member_death_leases_recovered_by_survivors():
    """Killing a member strands its clients' leases; a survivor's
    watchdog patrols the SHARED store, releases them, and the survivor's
    clients steal the tickets — every ticket still completes."""
    async def main():
        # redistribute_min is LONG here so the paper's passive cool-down
        # path can't rescue the tickets first — recovery must come from a
        # survivor's watchdog releasing the stranded lease
        fed = make_fed(2, n_shards=4, grace=2.0, redistribute_min=1.0)
        fed.register_task(TaskDef("inc", lambda x, _: x + 1))
        fed.add_work("inc", list(range(40)))
        # member 0's client is slow enough to be mid-lease when killed
        fed.spawn_clients([ClientProfile(name="victim", speed=50.0)],
                          member=0)
        fed.spawn_clients([ClientProfile(name="survivor", speed=2000.0)],
                          member=1)
        await asyncio.sleep(0.01)          # let the victim take a lease
        n_down = await fed.kill_member(0)
        assert n_down >= 1
        assert await fed.run_until_done(timeout=30.0)
        return fed

    fed = _run(main())
    res = fed.queue.results()
    assert len(res) == 40
    assert all(res[i] == i + 1 for i in range(40))
    con = fed.console()
    assert con["members"][0]["alive"] is False
    # the victim's stranded lease was proactively released
    assert con["lease_releases"] >= 1
    # spawning on a dead member is refused
    with pytest.raises(RuntimeError):
        fed.spawn_clients([ClientProfile(name="late")], member=0)


def test_keep_alive_fans_out_to_members():
    fed = make_fed(2)
    assert fed.keep_alive is False
    fed.keep_alive = True
    assert all(m.keep_alive for m in fed.members)
    assert fed.keep_alive is True


def test_client_rates_feed_adaptive_shard_sizes():
    from repro.core.split_parallel import adaptive_shard_sizes

    async def main():
        fed = make_fed(2)
        fed.register_task(TaskDef("work", lambda x, _: x))
        fed.add_work("work", list(range(30)), work=1.0)
        fed.spawn_clients([ClientProfile(name="fast", speed=4000.0),
                           ClientProfile(name="slow", speed=400.0)])
        assert await fed.run_until_done(timeout=30.0)
        return fed

    fed = _run(main())
    rates = fed.client_rates()
    assert rates["fast"] > rates["slow"]
    sizes = adaptive_shard_sizes(rates, 16)
    assert sum(sizes.values()) == 16
    assert sizes["fast"] > sizes["slow"]


def test_split_dispatcher_rides_federation():
    """§4.1 training rounds run unchanged over a federation: the
    dispatcher only needs the AsyncDistributor duck-type surface."""
    async def main():
        fed = make_fed(2, n_shards=4)
        fed.register_task(TaskDef(
            "backbone_shard", lambda args, _: {"grad": args["lo"]}))
        fed.spawn_clients([ClientProfile(name=f"c{i}", speed=2000.0)
                           for i in range(4)])
        disp = SplitConcurrentDispatcher(fed)
        outs = []
        for step in range(3):
            shards = [{"lo": step * 100 + i, "hi": 0} for i in range(6)]
            outs.append(await disp.run_round(shards, timeout=30.0))
        await fed.shutdown()
        return outs, disp

    outs, disp = _run(main())
    assert disp.rounds == 3
    for step, out in enumerate(outs):
        assert [o["grad"] for o in out] == [step * 100 + i
                                            for i in range(6)]


def test_federation_member_is_async_distributor():
    """Members ARE AsyncDistributors — one scheduler codebase, federated
    by composition, not a parallel implementation."""
    from repro.core.distributor import AsyncDistributor

    fed = make_fed(2)
    assert all(isinstance(m, AsyncDistributor) for m in fed.members)
    assert all(m.queue is fed.queue for m in fed.members)
    homes = [id(s) for m in fed.members for s in m.home_shards]
    assert len(homes) == len(set(homes))           # home shards disjoint
    assert len(homes) == fed.queue.n_shards        # and exhaustive


class TickingClock:
    """Advances by ``step`` on every read — lets a single _queue_lease
    call see time pass between its home attempt and its fabric retry."""

    def __init__(self, step):
        self.t = 0.0
        self.step = step

    def __call__(self):
        self.t += self.step
        return self.t


def test_steals_not_counted_for_home_shard_grant_in_retry():
    """The dry-home fallback re-merges across the WHOLE fabric; when the
    retry's grant turns out to be the member's own home tickets (a home
    cool-down expired between the two calls), it must NOT count as a
    steal — only grants containing foreign-shard tickets do."""
    fed = FederatedDistributor(2, n_shards=2, redistribute_min=5.0,
                               clock=TickingClock(3.0))
    task = next(f"task{i}" for i in range(64)
                if shard_index(f"task{i}", 2) == 0)   # member0's home
    fed.register_task(TaskDef(task, lambda x, _: x))
    fed.add_work(task, [0])
    m0, m1 = fed.members

    # member0 leases its home ticket; the ticket enters its cool-down
    batch = m0._queue_lease("c0", 1)
    assert batch is not None and m0.steals == 0

    # member0 again: home attempt lands inside the cool-down (None), the
    # fabric-wide retry lands after it — granting member0's OWN ticket.
    # The seed code counted this as a steal.
    batch = m0._queue_lease("c0", 1)
    assert batch is not None
    assert batch.shards == [fed.queue.shards[0]]
    assert m0.steals == 0

    # member1's retry granting the same shard-0 ticket IS a steal
    batch = m1._queue_lease("c1", 1)
    assert batch is not None
    assert m1.steals == 1
