"""Differential protocol-version tests: v1 and v2 peers interoperate.

Protocol v2 (binary frames, chunked blobs, weight deltas) must degrade
losslessly: a v1 client against a v2 server — and a v2 client against a
v1 server — negotiates down in ``hello`` and completes the PR 3
re-register storm with zero stale serves; the computed results are
identical to the in-process path regardless of which protocol carried
them.  A v2<->v2 pairing must actually USE the new machinery (delta
fetches spliced in, binary submits, chunked large statics) while
producing the same results.
"""
import asyncio

import numpy as np
import pytest

from repro.core.distributor import (AdaptiveSizer, AsyncDistributor,
                                    ClientProfile, TaskDef)
from repro.core.federation import FederatedDistributor
from repro.core.transport import (PROTOCOL_VERSION, RemoteBrowserClient,
                                  TransportServer, spawn_remote_clients)


# module-level so they pickle across the wire
def _square(x, static):
    return x * x


def _read_weights(x, static):
    return (x, static["weights"])


def _dot_weights(x, static):
    w = static["weights"]
    return (w["round"], float(np.sum(w["params"]["fc"])) * x)


def _dist(**kw):
    kw.setdefault("timeout", 10.0)
    kw.setdefault("redistribute_min", 0.02)
    kw.setdefault("sizer", AdaptiveSizer(target_lease_time=0.05, max_size=8))
    kw.setdefault("watchdog_interval", 0.01)
    return AsyncDistributor(**kw)


async def _run_storm(d, server, clients, tasks, *, rounds=6, width=10):
    """Drive the PR 3 re-register storm over whatever peers are wired up;
    returns (stale, total, per_round_results)."""
    stale = total = 0
    per_round = []
    for rnd in range(rounds):
        d.add_static("weights", rnd)
        tids = d.add_work("rw", list(range(width)))
        deadline = asyncio.get_event_loop().time() + 30.0
        while True:
            wake = d._wake_event()
            out = d.queue.results_for(tids)
            if out is not None:
                break
            assert asyncio.get_event_loop().time() < deadline, d.console()
            await d._wait_on(wake, 0.05)
        for _, w in out:
            total += 1
            stale += (w != rnd)
        per_round.append(out)
        d.queue.prune(tids)
    for c in clients:
        await c.stop()
    await asyncio.gather(*tasks, return_exceptions=True)
    await d.shutdown()
    await server.stop()
    return stale, total, per_round


def _storm_with(server_kw, client_kw, n_clients=2):
    async def go():
        d = _dist(keep_alive=True)
        d.add_static("weights", -1)
        d.register_task(TaskDef("rw", _read_weights,
                                static_files=("weights",)))
        server = TransportServer(d, **server_kw)
        addr = await server.start()
        clients, tasks = spawn_remote_clients(
            addr, [ClientProfile(name=f"c{i}", speed=2000.0)
                   for i in range(n_clients)], **client_kw)
        stale, total, per_round = await _run_storm(d, server, clients, tasks)
        return stale, total, per_round, clients, server, d
    return asyncio.run(go())


def _storm_in_process():
    async def go():
        d = _dist(keep_alive=True)
        d.add_static("weights", -1)
        d.register_task(TaskDef("rw", _read_weights,
                                static_files=("weights",)))
        d.spawn_clients([ClientProfile(name=f"c{i}", speed=2000.0)
                         for i in range(2)])
        stale = total = 0
        per_round = []
        for rnd in range(6):
            d.add_static("weights", rnd)
            tids = d.add_work("rw", list(range(10)))
            deadline = asyncio.get_event_loop().time() + 30.0
            while True:
                wake = d._wake_event()
                out = d.queue.results_for(tids)
                if out is not None:
                    break
                assert asyncio.get_event_loop().time() < deadline
                await d._wait_on(wake, 0.05)
            stale += sum(w != rnd for _, w in out)
            total += len(out)
            per_round.append(out)
            d.queue.prune(tids)
        await d.shutdown()
        return stale, total, per_round
    return asyncio.run(go())


# ---------------------------------------------------------------------------
# negotiation
# ---------------------------------------------------------------------------


def test_v1_client_against_v2_server_negotiates_down():
    stale, total, per_round, clients, server, d = _storm_with(
        {}, {"max_proto": 1})
    assert total == 6 * 10 and stale == 0
    assert all(c.proto == 1 for c in clients)
    # nothing v2 crossed the wire to (or from) a v1 client
    assert server.chunks_in == 0 and server.chunks_out == 0
    assert all(c.deltas_applied == 0 for c in clients)
    assert per_round == _storm_in_process()[2]     # exact result parity


def test_v2_client_against_v1_server_negotiates_down():
    stale, total, per_round, clients, server, d = _storm_with(
        {"max_proto": 1}, {})
    assert total == 6 * 10 and stale == 0
    assert all(c.proto == 1 for c in clients)
    assert server.chunks_in == 0 and server.chunks_out == 0
    assert all(c.deltas_applied == 0 for c in clients)
    assert per_round == _storm_in_process()[2]


def test_v2_peers_negotiate_v2_and_use_it():
    stale, total, per_round, clients, server, d = _storm_with({}, {})
    assert total == 6 * 10 and stale == 0
    assert all(c.proto == PROTOCOL_VERSION for c in clients)
    # the re-published weights travelled as v2 deltas, not full payloads
    assert sum(c.deltas_applied for c in clients) > 0
    assert d.delta_count["weights"] > 0
    assert per_round == _storm_in_process()[2]


# ---------------------------------------------------------------------------
# weight deltas over the wire
# ---------------------------------------------------------------------------


def _weight_rounds(server_kw, client_kw, *, rounds=6):
    """Re-publish a two-part weight pytree each round, mutating only the
    small 'fc' leaf — the shape of a frozen-backbone training loop."""
    async def go():
        d = _dist(keep_alive=True)
        backbone = np.zeros((256,), np.float32)    # never changes
        d.add_static("weights", {"round": -1,
                                 "params": {"backbone": backbone,
                                            "fc": np.zeros(4, np.float32)}})
        d.register_task(TaskDef("rw", _dot_weights,
                                static_files=("weights",)))
        server = TransportServer(d, **server_kw)
        addr = await server.start()
        clients, tasks = spawn_remote_clients(
            addr, [ClientProfile(name="c0", speed=2000.0)], **client_kw)
        stale = total = 0
        for rnd in range(rounds):
            d.add_static("weights",
                         {"round": rnd,
                          "params": {"backbone": backbone,
                                     "fc": np.full(4, rnd, np.float32)}})
            tids = d.add_work("rw", list(range(4)))
            deadline = asyncio.get_event_loop().time() + 30.0
            while True:
                wake = d._wake_event()
                out = d.queue.results_for(tids)
                if out is not None:
                    break
                assert asyncio.get_event_loop().time() < deadline, \
                    d.console()
                await d._wait_on(wake, 0.05)
            for seen_round, _ in out:
                total += 1
                stale += (seen_round != rnd)
            d.queue.prune(tids)
        for c in clients:
            await c.stop()
        await asyncio.gather(*tasks, return_exceptions=True)
        await d.shutdown()
        await server.stop()
        return stale, total, clients[0], server, dict(d.delta_count), \
            dict(d.download_count)
    return asyncio.run(go())


def test_v2_weight_rounds_ship_deltas_with_zero_stale():
    stale, total, client, server, deltas, downloads = _weight_rounds({}, {})
    assert stale == 0 and total == 6 * 4
    assert client.proto == PROTOCOL_VERSION
    # rounds 1..5 each arrived as a changed-leaves delta, not a payload
    assert client.deltas_applied >= 4
    assert deltas.get("weights", 0) >= 4
    # exactly one full weights payload ever crossed the wire (the miss)
    assert downloads.get("weights", 0) == 1


def test_v1_weight_rounds_same_results_no_deltas():
    stale, total, client, server, deltas, downloads = _weight_rounds(
        {"max_proto": 1}, {})
    assert stale == 0 and total == 6 * 4
    assert client.proto == 1
    assert client.deltas_applied == 0 and deltas.get("weights", 0) == 0
    # v1 re-downloads the full payload every round
    assert downloads.get("weights", 0) >= 6


# ---------------------------------------------------------------------------
# chunked large statics
# ---------------------------------------------------------------------------


def test_large_static_streams_in_many_chunks():
    """A static bigger than chunk_bytes streams as multiple chunk frames
    and reassembles bit-exactly (the 100MB-blob shape, scaled down)."""
    async def go():
        d = _dist()
        big = np.arange(64 * 1024, dtype=np.float32)   # 256 KiB raw
        d.add_static("weights", big)
        d.register_task(TaskDef("rw", _read_weights,
                                static_files=("weights",)))
        tids = d.add_work("rw", [1])
        server = TransportServer(d, chunk_bytes=16 * 1024)
        addr = await server.start()
        clients, tasks = spawn_remote_clients(
            addr, [ClientProfile(name="c0", speed=2000.0)],
            chunk_bytes=16 * 1024)
        ok = await d.run_until_done(timeout=30.0)
        await asyncio.gather(*tasks)
        await server.stop()
        res = d.queue.results_for(tids)
        return ok, res, server, big

    ok, res, server, big = asyncio.run(go())
    assert ok
    (x, got), = res
    assert x == 1
    assert isinstance(got, np.ndarray)
    assert got.tobytes() == big.tobytes()              # bit-exact across wire
    assert server.chunks_out >= 256 // 16              # actually streamed
    # the result (which echoes the array) came back as a binary submit
    assert server.chunks_in > 0


def test_v1_connection_still_fetches_large_static():
    async def go():
        d = _dist()
        big = np.arange(8 * 1024, dtype=np.float32)
        d.add_static("weights", big)
        d.register_task(TaskDef("rw", _read_weights,
                                static_files=("weights",)))
        d.add_work("rw", [1])
        server = TransportServer(d)
        addr = await server.start()
        clients, tasks = spawn_remote_clients(
            addr, [ClientProfile(name="c0", speed=2000.0)], max_proto=1)
        ok = await d.run_until_done(timeout=30.0)
        await asyncio.gather(*tasks)
        await server.stop()
        return ok, d.queue.results(), server

    ok, res, server = asyncio.run(go())
    assert ok and server.chunks_out == 0               # pure JSON path


# ---------------------------------------------------------------------------
# federation: edge caches serve deltas without an origin round-trip
# ---------------------------------------------------------------------------


def test_federated_edges_serve_deltas_zero_stale():
    async def go():
        fed = FederatedDistributor(
            2, timeout=10.0, redistribute_min=0.02,
            sizer=AdaptiveSizer(target_lease_time=0.05, max_size=8),
            watchdog_interval=0.01, keep_alive=True)
        fed.add_static("weights", -1)
        fed.register_task(TaskDef("rw", _read_weights,
                                  static_files=("weights",)))
        server = TransportServer(fed)
        addr = await server.start()
        clients, tasks = spawn_remote_clients(
            addr, [ClientProfile(name=f"c{i}", speed=2000.0)
                   for i in range(2)])
        stale, total, _ = await _run_storm(fed, server, clients, tasks)
        edge_deltas = sum(m.edge.delta_count.total()
                          for m in fed.members)
        return stale, total, clients, edge_deltas

    stale, total, clients, edge_deltas = asyncio.run(go())
    assert total == 6 * 10 and stale == 0
    assert all(c.proto == PROTOCOL_VERSION for c in clients)
    assert edge_deltas > 0                 # deltas served from the edges
