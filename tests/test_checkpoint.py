"""Checkpoint tests: the paper's JSON+base64 model format must round-trip
bit-exactly ("exchanged among machines without rounding errors")."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.checkpoint import (load_json_model, load_npz, save_json_model,
                              save_npz, tree_from_json, tree_to_json)


def test_json_roundtrip_simple_tree():
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": np.array([1, 2, 3], np.int32),
                  "d": (np.float64(3.25), [np.ones(4, np.float16)])},
            "scalar": 7, "name": "sukiyaki"}
    rt = tree_from_json(tree_to_json(tree))
    np.testing.assert_array_equal(rt["a"], tree["a"])
    np.testing.assert_array_equal(rt["b"]["c"], tree["b"]["c"])
    assert isinstance(rt["b"]["d"], tuple)
    assert rt["scalar"] == 7 and rt["name"] == "sukiyaki"


@settings(max_examples=30, deadline=None)
@given(hnp.arrays(dtype=st.sampled_from([np.float32, np.float64, np.int32,
                                         np.uint8, np.float16]),
                  shape=hnp.array_shapes(max_dims=3, max_side=8)))
def test_json_roundtrip_bit_exact(arr):
    """Property: arbitrary arrays survive the paper's base64-JSON format
    without rounding (bit-for-bit)."""
    rt = tree_from_json(tree_to_json({"x": arr}))["x"]
    assert rt.dtype == arr.dtype
    assert rt.shape == arr.shape
    np.testing.assert_array_equal(
        rt.view(np.uint8) if rt.dtype.kind == "f" else rt,
        arr.view(np.uint8) if arr.dtype.kind == "f" else arr)


def test_json_roundtrip_bfloat16_via_file(tmp_path):
    tree = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(4, 4)),
                             jnp.bfloat16)}
    path = str(tmp_path / "model.json")
    save_json_model(path, tree)
    rt = load_json_model(path)
    np.testing.assert_array_equal(np.asarray(tree["w"], np.float32),
                                  np.asarray(rt["w"], np.float32))


def test_npz_roundtrip_nested(tmp_path):
    tree = {"blocks": {"w": np.ones((3, 2), np.float32)},
            "tup": (np.zeros(2), {"x": np.arange(3)}),
            "lst": [np.ones(1), np.zeros(1)]}
    path = str(tmp_path / "ck.npz")
    save_npz(path, tree)
    rt = load_npz(path)
    np.testing.assert_array_equal(rt["blocks"]["w"], tree["blocks"]["w"])
    assert isinstance(rt["tup"], tuple) and isinstance(rt["lst"], list)
    np.testing.assert_array_equal(rt["tup"][1]["x"], tree["tup"][1]["x"])


def test_full_train_state_roundtrip_paper_format(tmp_path):
    """A FULL split-training ``TrainState`` — backbone params, head and
    stale-head slots, BOTH optimizer states, bf16 feature-replay buffers,
    and the step counter — survives the paper's JSON+base64 round-
    checkpoint format bit-exactly (the resumable-training contract)."""
    import dataclasses

    import jax
    from repro.configs import get_smoke_config
    from repro.core.split_parallel import init_prev_features, make_train_step
    from repro.data import make_lm_batch
    from repro.models.model import build_model
    from repro.optim import get_optimizer
    from repro.train_fabric import (checkpoint_path, load_round_checkpoint,
                                    save_round_checkpoint, state_to_tree)

    cfg = dataclasses.replace(get_smoke_config("qwen3-4b"),
                              tie_embeddings=False)
    api = build_model(cfg, compute_dtype=jnp.float32)
    opt = get_optimizer("adagrad", 0.05)
    init_state, step = make_train_step(api, opt, strategy="split_concurrent")
    state = init_state(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {k: jnp.asarray(v)
             for k, v in make_lm_batch(rng, 2, 16, cfg.vocab_size).items()}
    state = init_prev_features(state, api, batch, dtype=jnp.bfloat16)
    state, _ = jax.jit(step)(state, batch)    # non-trivial opt state, step=1
    # the replay buffer is kept in bf16 between steps on memory-tight
    # runs — exercise exactly that mixed-precision layout
    state = dataclasses.replace(
        state, prev_features=jnp.asarray(state.prev_features, jnp.bfloat16))

    path = save_round_checkpoint(checkpoint_path(str(tmp_path), 1), state,
                                 round_index=1, extra={"demo": True})
    got, rnd, extra = load_round_checkpoint(path)
    assert rnd == 1 and extra == {"demo": True}
    assert int(got.step) == 1

    ref = jax.tree_util.tree_leaves_with_path(state_to_tree(state))
    new = jax.tree_util.tree_leaves_with_path(state_to_tree(got))
    assert len(ref) == len(new)
    saw_bf16 = False
    for (ka, a), (kb, b) in zip(sorted(ref, key=lambda kv: str(kv[0])),
                                sorted(new, key=lambda kv: str(kv[0]))):
        assert str(ka) == str(kb)
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype and a.shape == b.shape, str(ka)
        assert a.tobytes() == b.tobytes(), f"bits differ at {ka}"
        saw_bf16 |= str(a.dtype) == "bfloat16"
    assert saw_bf16, "the state must exercise bf16 leaves"


def test_model_params_roundtrip(tmp_path):
    """A real (smoke) model's params survive the paper format."""
    import jax
    from repro.configs import get_smoke_config
    from repro.models.model import build_model
    from repro.sharding.spec import values_tree

    cfg = get_smoke_config("qwen1.5-0.5b")
    api = build_model(cfg, compute_dtype=jnp.float32)
    params = values_tree(api.init(jax.random.PRNGKey(0)))
    path = str(tmp_path / "model.json")
    save_json_model(path, params)
    rt = load_json_model(path)
    flat1 = jax.tree_util.tree_leaves(params)
    flat2 = jax.tree_util.tree_leaves(rt)
    assert len(flat1) == len(flat2)
    for a, b in zip(flat1, flat2):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
