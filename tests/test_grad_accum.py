"""Gradient accumulation: identical math to the unaccumulated step."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.split_parallel import init_prev_features, make_train_step
from repro.data import make_lm_batch
from repro.models.model import build_model
from repro.optim import sgd
from repro.sharding.spec import values_tree


@pytest.mark.parametrize("strategy", ["dp_full", "split_concurrent"])
def test_grad_accum_matches_unaccumulated(strategy):
    cfg = dataclasses.replace(get_smoke_config("qwen3-4b"),
                              tie_embeddings=False)
    api = build_model(cfg, compute_dtype=jnp.float32)
    rng = np.random.default_rng(0)
    batch = {k: jnp.asarray(v)
             for k, v in make_lm_batch(rng, 8, 32, cfg.vocab_size).items()}

    i1, s1 = make_train_step(api, sgd(0.1), strategy=strategy)
    i2, s2 = make_train_step(api, sgd(0.1), strategy=strategy, grad_accum=4)
    st1, st2 = i1(jax.random.PRNGKey(0)), i2(jax.random.PRNGKey(0))
    if strategy == "split_concurrent":
        st1 = init_prev_features(st1, api, batch, dtype=jnp.float32)
        st2 = init_prev_features(st2, api, batch, dtype=jnp.float32)
    st1, m1 = jax.jit(s1)(st1, batch)
    st2, m2 = jax.jit(s2)(st2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), st1.params, st2.params)
    assert max(jax.tree_util.tree_leaves(diffs)) < 1e-5


def test_grad_accum_feature_replay_layout():
    """split_concurrent + accumulation must re-assemble features in batch
    order for the server's next-step training."""
    cfg = dataclasses.replace(get_smoke_config("qwen3-4b"),
                              tie_embeddings=False)
    api = build_model(cfg, compute_dtype=jnp.float32)
    rng = np.random.default_rng(0)
    batch = {k: jnp.asarray(v)
             for k, v in make_lm_batch(rng, 8, 16, cfg.vocab_size).items()}
    init_state, step = make_train_step(api, sgd(0.1),
                                       strategy="split_concurrent",
                                       grad_accum=2)
    state = init_prev_features(init_state(jax.random.PRNGKey(0)), api,
                               batch, dtype=jnp.float32)
    state, _ = jax.jit(step)(state, batch)
    assert state.prev_features.shape == (8, 16, cfg.d_model)
    # features must equal the direct forward on the same batch
    params = {**state.params}
    # (stale head == head at step 1 sync period 4? check shape only + finite)
    assert np.isfinite(np.asarray(state.prev_features)).all()


def test_fused_chunked_loss_matches_naive():
    """loss_chunks: value and gradients identical to the naive path,
    for both tied and untied heads."""
    import jax
    from repro.models.model import build_model as bm

    for arch in ("qwen3-4b", "qwen1.5-0.5b"):
        cfg = get_smoke_config(arch)
        api0 = bm(cfg, compute_dtype=jnp.float32)
        api8 = bm(cfg, compute_dtype=jnp.float32, loss_chunks=8)
        params = values_tree(api0.init(jax.random.PRNGKey(0)))
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                                  jnp.int32),
            "mask": jnp.ones((2, 16), jnp.float32),
        }
        l0, _ = api0.train_loss(params, batch)
        l8, _ = api8.train_loss(params, batch)
        assert float(l0) == pytest.approx(float(l8), rel=1e-6)
        g0 = jax.grad(lambda p: api0.train_loss(p, batch)[0])(params)
        g8 = jax.grad(lambda p: api8.train_loss(p, batch)[0])(params)
        d = max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
            lambda a, b: float(jnp.abs(a - b).max()), g0, g8)))
        assert d < 1e-5, (arch, d)
