"""Collection shim for the chaos harness.

The harness and its tests live in ``tests/chaos.py`` — kept without the
``test_`` prefix so benchmarks and future suites can import
``ChurningFleet``/``chaos_profiles`` without dragging a test module
name along.  Re-exporting here puts the ``test_*`` functions where
pytest's default collection pattern finds them.
"""
from chaos import *  # noqa: F401,F403
