"""Cache-coherent federation tests: the versioned registry (monotonic
versions, ETag-style conditional fetches, the cold-miss/revalidation
ledger split), pin-driven browser revalidation, targeted edge
invalidation (no full clear()), re-register-mid-flight semantics (zero
stale executions after the invalidation barrier; pinned-version execution
for in-flight leases), and per-round weight re-registration through the
split dispatcher."""
import asyncio
import threading

import pytest

from repro.core.distributor import (AsyncDistributor, BrowserNodeBase,
                                    ClientProfile, Distributor,
                                    HttpServerBase, TaskDef)
from repro.core.federation import EdgeCache, FederatedDistributor
from repro.core.split_parallel import SplitConcurrentDispatcher


def _run(coro):
    return asyncio.run(coro)


class Node(BrowserNodeBase):
    """Bare browser-node state (no thread/loop): drives the versioned
    cache helpers deterministically."""

    def __init__(self, distributor, name="node", capacity=16):
        self._init_browser(distributor,
                           ClientProfile(name=name, cache_capacity=capacity))


# --- registry versioning unit ------------------------------------------------


def test_register_task_stamps_monotonic_versions():
    s = HttpServerBase()
    s.register_task(TaskDef("a", lambda x, _: x))
    v1 = s.tasks["a"].version
    s.add_static("ds", [1])
    s.register_task(TaskDef("a", lambda x, _: -x))
    v2 = s.tasks["a"].version
    assert v1 >= 1 and v2 > v1            # one shared monotonic clock
    assert s.static_version("ds") > v1


def test_task_version_is_coherence_max_over_code_and_statics():
    s = HttpServerBase()
    s.add_static("w", 0)
    s.register_task(TaskDef("t", lambda x, st: st["w"],
                            static_files=("w",)))
    code_v = s.tasks["t"].version
    assert s.task_version("t") == code_v
    s.add_static("w", 1)                  # data-only re-publish
    assert s.task_version("t") == s.static_version("w") > code_v
    assert s.tasks["t"].version == code_v  # code version untouched
    assert s.task_version("missing") == 0


def test_conditional_fetch_splits_ledger_cold_miss_vs_revalidation():
    s = HttpServerBase()
    s.add_static("ds", "blob")
    s.register_task(TaskDef("t", lambda x, _: x))
    # cold miss: payload crosses, download ledger
    got = s.fetch_task_versioned("t")
    assert not got.not_modified and got.value.name == "t"
    assert s.download_count["task:t"] == 1
    # current copy: not-modified stub, revalidation ledger, NO download
    again = s.fetch_task_versioned("t", if_version=got.version)
    assert again.not_modified and again.value is None
    assert again.version == got.version
    assert s.download_count["task:t"] == 1
    assert s.revalidation_count["task:t"] == 1
    # stale copy: payload again
    s.register_task(TaskDef("t", lambda x, _: -x))
    refetch = s.fetch_task_versioned("t", if_version=got.version)
    assert not refetch.not_modified and refetch.version > got.version
    assert s.download_count["task:t"] == 2
    # statics follow the same protocol
    g1 = s.serve_static_versioned("ds")
    g2 = s.serve_static_versioned("ds", if_version=g1.version)
    assert g2.not_modified
    assert s.download_count["ds"] == 1 and s.revalidation_count["ds"] == 1


def test_directly_written_static_store_stays_unversioned():
    """The seed idiom ``d.static_store[k] = v`` still serves (version 0,
    never invalidated) — versioning is opt-in through add_static."""
    s = HttpServerBase()
    s.static_store["raw"] = 42
    assert s.serve_static("raw") == 42
    assert s.static_version("raw") == 0
    got = s.serve_static_versioned("raw", if_version=0)
    assert got.not_modified                 # version 0 == version 0


# --- browser cache: pin-driven revalidation ----------------------------------


def test_browser_pin_forces_conditional_refetch_of_stale_code():
    d = Distributor()
    d.register_task(TaskDef("t", lambda x, _: "old"))
    n = Node(d)
    pin1 = d.task_version("t")
    assert n._get_task("t", pin1).run(0, {}) == "old"
    assert d.download_count["task:t"] == 1
    d.register_task(TaskDef("t", lambda x, _: "new"))
    pin2 = d.task_version("t")
    # stale pin still serves from cache (pinned-version execution)...
    assert n._get_task("t", pin1).run(0, {}) == "old"
    assert d.download_count["task:t"] == 1
    # ...the new pin refetches exactly once, then caches the fresh copy
    assert n._get_task("t", pin2).run(0, {}) == "new"
    assert d.download_count["task:t"] == 2
    assert n._get_task("t", pin2).run(0, {}) == "new"
    assert d.download_count["task:t"] == 2


def test_browser_revalidation_of_unchanged_asset_is_counter_bump():
    """A pin bumped by a DATA change revalidates the unchanged code as a
    not-modified stub — no code payload moves."""
    d = Distributor()
    d.add_static("w", 0)
    d.register_task(TaskDef("t", lambda x, st: st["w"],
                            static_files=("w",)))
    n = Node(d)
    pin = d.task_version("t")
    task = n._get_task("t", pin)
    assert n._get_static(task, pin) == {"w": 0}
    assert d.download_count["task:t"] == 1 and d.download_count["w"] == 1
    d.add_static("w", 1)                   # weights-only re-publish
    pin2 = d.task_version("t")
    task = n._get_task("t", pin2)
    assert n._get_static(task, pin2) == {"w": 1}
    # code revalidated (bump), weights re-downloaded (payload)
    assert d.download_count["task:t"] == 1
    assert d.revalidation_count["task:t"] == 1
    assert d.download_count["w"] == 2
    assert n.revalidations == 1


def test_in_flight_lease_pins_creation_version():
    """A lease taken BEFORE a re-register runs the pinned version from
    cache; tickets added AFTER the barrier carry the new pin."""
    d = Distributor()
    d.register_task(TaskDef("t", lambda x, _: "v1"))
    d.add_work("t", [0])
    n = Node(d)
    batch = d.queue.lease("node", 1)
    (old_ticket,) = batch.tickets
    n._get_task("t", old_ticket.task_version)      # cache warmed at v1
    d.register_task(TaskDef("t", lambda x, _: "v2"))   # barrier
    new_tid = d.add_work("t", [1])[0]
    # the in-flight ticket still executes v1 straight from cache
    task = n._get_task("t", old_ticket.task_version)
    assert task.run(0, {}) == "v1"
    assert d.download_count["task:t"] == 1         # no refetch
    d.queue.submit_batch(batch.lease_id, {old_ticket.ticket_id: "done"},
                         "node")
    # the post-barrier ticket carries the new pin and gets v2
    batch2 = d.queue.lease("node", 1)
    (new_ticket,) = batch2.tickets
    assert new_ticket.ticket_id == new_tid
    assert new_ticket.task_version > old_ticket.task_version
    assert n._get_task("t", new_ticket.task_version).run(0, {}) == "v2"


# --- edge cache: targeted invalidation ---------------------------------------


def test_edge_invalidation_busts_exactly_the_republished_key():
    origin = HttpServerBase()
    origin.add_static("keep", "stays-cached")
    origin.add_static("w", 0)
    origin.register_task(TaskDef("t", lambda x, _: x))
    edge = EdgeCache(origin, capacity=8)
    edge.serve_static("keep")
    edge.serve_static("w")
    edge.fetch_task("t")
    assert origin.download_count["keep"] == 1
    origin.add_static("w", 1)              # invalidates ONLY static:w
    assert edge.invalidations == 1
    assert edge.serve_static("w") == 1     # re-warms from origin
    assert origin.download_count["w"] == 2
    edge.serve_static("keep")
    edge.fetch_task("t")
    # the untouched keys never went back to the origin (no clear())
    assert origin.download_count["keep"] == 1
    assert origin.download_count["task:t"] == 1


def test_edge_answers_conditional_fetch_locally_when_current():
    origin = HttpServerBase()
    origin.add_static("ds", "blob")
    edge = EdgeCache(origin, capacity=8)
    got = edge.serve_static_versioned("ds")
    again = edge.serve_static_versioned("ds", if_version=got.version)
    assert again.not_modified
    assert edge.revalidation_count["ds"] == 1
    # the revalidation never reached the origin
    assert origin.download_count["ds"] == 1
    assert origin.revalidation_count["ds"] == 0


def test_edge_cache_thread_safety_under_concurrent_clients():
    """v1 thread clients routed through one edge: concurrent fetches,
    invalidations and stats must not corrupt the LRU OrderedDict."""
    origin = HttpServerBase()
    for i in range(8):
        origin.add_static(f"k{i}", i)
    origin.register_task(TaskDef("t", lambda x, _: x))
    edge = EdgeCache(origin, capacity=3)   # small: constant eviction churn
    errors = []

    def hammer(seed):
        try:
            for i in range(300):
                k = (seed + i) % 8
                assert edge.serve_static(f"k{k}") == k
                edge.fetch_task("t")
                if i % 50 == 0:
                    edge.stats()
        except Exception as e:              # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(s,)) for s in range(6)]
    for t in threads:
        t.start()
    for i in range(40):
        origin.add_static(f"k{i % 8}", i % 8)   # concurrent invalidations
    for t in threads:
        t.join()
    assert not errors
    s = edge.stats()
    assert s["requests"] == 6 * 300 * 2


# --- re-register mid-flight, end to end --------------------------------------


def test_no_stale_execution_after_reregister_async_distributor():
    """Stale-serve regression: after the re-register barrier, no ticket
    created behind the barrier may execute the old code — even though
    every client cached it."""

    async def main():
        d = AsyncDistributor(timeout=5.0, redistribute_min=0.02,
                             watchdog_interval=0.005)
        d.register_task(TaskDef("gen", lambda x, _: ("old", x)))
        d.add_work("gen", list(range(20)))
        d.spawn_clients([ClientProfile(name=f"c{i}", speed=2000.0)
                         for i in range(3)])
        assert await d.run_until_done(timeout=30.0)
        first = dict(d.queue.results())
        # barrier: re-register, then a second wave of tickets
        d.register_task(TaskDef("gen", lambda x, _: ("new", x)))
        tids2 = d.add_work("gen", list(range(20, 40)))
        d.spawn_clients([ClientProfile(name=f"c{i}", speed=2000.0)
                         for i in range(3)])
        assert await d.run_until_done(timeout=30.0)
        return d, first, tids2

    d, first, tids2 = _run(main())
    res = d.queue.results()
    assert all(first[t][0] == "old" for t in first)
    assert all(res[t][0] == "new" for t in tids2)      # zero stale serves
    # invalidation was targeted: the one payload refetch per client that
    # actually revalidated, not a thundering re-download of everything
    assert d.download_count["task:gen"] <= 6


def test_federation_reregister_propagates_to_every_edge_and_browser():
    """Re-registering on the façade invalidates the key on EVERY member's
    edge; all second-round tickets execute fresh code through warmed
    caches, with no edge clear()."""

    async def main():
        fed = FederatedDistributor(2, n_shards=4, timeout=5.0,
                                   redistribute_min=0.02,
                                   watchdog_interval=0.005)
        fed.add_static("keep", "x")
        fed.register_task(TaskDef("job", lambda x, s: ("old", x),
                                  static_files=("keep",)))
        fed.add_work("job", list(range(16)))
        fed.spawn_clients([ClientProfile(name=f"c{i}", speed=2000.0)
                           for i in range(4)])
        assert await fed.run_until_done(timeout=30.0)
        fed.register_task(TaskDef("job", lambda x, s: ("new", x),
                                  static_files=("keep",)))
        tids2 = fed.add_work("job", list(range(16, 32)))
        fed.spawn_clients([ClientProfile(name=f"c{i}", speed=2000.0)
                           for i in range(4)])
        assert await fed.run_until_done(timeout=30.0)
        return fed, tids2

    fed, tids2 = _run(main())
    res = fed.queue.results()
    assert all(res[t][0] == "new" for t in tids2)
    # both edges took the targeted invalidation for task:job
    assert sum(m.edge.invalidations for m in fed.members) >= 1
    # the untouched static was fetched from the origin at most once per
    # edge across BOTH rounds — proof the edges were never cleared
    assert fed.download_count["keep"] <= 2


def test_split_dispatcher_round_statics_are_fresh_by_construction():
    """Per-round weight re-registration through run_round(statics=...):
    round t's shards always see round t's weights, warmed caches
    notwithstanding."""

    async def main():
        d = AsyncDistributor(timeout=5.0, redistribute_min=0.02,
                             watchdog_interval=0.005)
        d.register_task(TaskDef(
            "backbone_shard", lambda args, s: (s["weights"], args),
            static_files=("weights",)))
        d.spawn_clients([ClientProfile(name=f"c{i}", speed=2000.0)
                         for i in range(2)])
        disp = SplitConcurrentDispatcher(d)
        outs = []
        for rnd in range(4):
            outs.append(await disp.run_round(
                list(range(6)), statics={"weights": rnd}, timeout=30.0))
        await d.shutdown()
        return d, outs

    d, outs = _run(main())
    for rnd, out in enumerate(outs):
        assert [w for w, _ in out] == [rnd] * 6        # never stale
    # unchanged task code revalidated across rounds instead of moving
    assert d.download_count["task:backbone_shard"] <= 2
    assert d.download_count["weights"] >= 4            # fresh every round


def test_run_project_tickets_are_version_pinned():
    """The paper's appendix API rides the versioned registry: calculate()
    pins tickets, so a mid-project re-register would invalidate."""
    from repro.core.project import CalculationFramework, ProjectBase, TaskBase

    class Echo(TaskBase):
        def run(self, input, static):  # noqa: A002
            return input

    class P(ProjectBase):
        def run(self):
            t = self.create_task(Echo)
            t.calculate([1, 2, 3])
            return t

    d = Distributor(timeout=2.0, redistribute_min=0.01)
    handle = CalculationFramework(d).run_project(P)
    pin = d.task_version("Echo")
    assert pin >= 1
    leased = d.queue.lease("probe", 3)
    assert all(t.task_version == pin for t in leased.tickets)
    assert handle is not None


def test_edge_floor_rejects_fill_raced_by_invalidation():
    """An invalidation landing while a miss fill is in flight must not be
    lost: the raced (stale) fill is never cached as current, and a
    conditional fetch with the stale version is never answered
    not-modified."""
    origin = HttpServerBase()
    origin.add_static("w", "v1")
    edge = EdgeCache(origin, capacity=4)
    real = origin.serve_static_versioned
    fired = {"done": False}

    def racing(key, if_version=None):
        got = real(key, if_version)
        if not fired["done"]:
            fired["done"] = True
            origin.add_static("w", "v2")   # re-publish lands mid-flight
        return got

    origin.serve_static_versioned = racing
    got = edge.serve_static_versioned("w")  # fill carries v1 payload
    origin.serve_static_versioned = real
    assert got.value == "v1"                # the raced reply itself
    # ...but it was NOT frozen in: the stale version can't revalidate,
    # and the next request re-warms to the current copy
    again = edge.serve_static_versioned("w", if_version=got.version)
    assert not again.not_modified
    assert again.value == "v2"
    final = edge.serve_static_versioned("w", if_version=again.version)
    assert final.not_modified               # now provably current


def test_browser_pin_heals_through_raced_edge_fill():
    """A browser whose pinned fetch comes back OLDER than the pin (the
    edge's fill raced an invalidation) retries unconditionally and ends
    up with the fresh copy — the stale payload is never validated at the
    pin."""
    origin = HttpServerBase()
    origin.add_static("w", "v1")                       # registry clock 1
    origin.register_task(TaskDef("t", lambda x, s: s["w"],
                                 static_files=("w",)))  # clock 2
    edge = EdgeCache(origin, capacity=4)
    real = origin.serve_static_versioned
    fired = {"done": False}

    def racing(key, if_version=None):
        got = real(key, if_version)
        if not fired["done"]:
            fired["done"] = True
            origin.add_static("w", "v2")               # clock 3, mid-fill
        return got

    origin.serve_static_versioned = racing
    n = Node(edge)
    task = n._get_task("t", 2)
    # the ticket pins the post-re-publish coherence version (3): the
    # edge's raced fill hands back v1, the browser heals with one
    # unconditional retry
    data = n._get_static(task, 3)
    origin.serve_static_versioned = real
    assert data == {"w": "v2"}
    assert origin.task_version("t") == 3
    # and the healed entry is cached: same pin, no further edge traffic
    before = edge.download_count["w"]
    assert n._get_static(task, 3) == {"w": "v2"}
    assert edge.download_count["w"] == before
