"""Transport-layer tests: framing, codecs, the loopback server, remote
clients, reconnect-with-resume, and cache coherence over the wire.

Everything runs on real sockets (loopback, ephemeral ports) — these tests
exercise genuine serialization boundaries, not shared references, so they
use wall-clock time with generous deadlines and tiny simulated workloads.
"""
import asyncio

import pytest

from repro.core.distributor import (AdaptiveSizer, AsyncDistributor,
                                    ClientProfile, Fetched, HttpServerBase,
                                    TaskDef)
from repro.core.federation import FederatedDistributor
from repro.core.tickets import LeaseBatch, Ticket
from repro.core.transport import (PROTOCOL_VERSION, ProtocolError,
                                  RemoteBrowserClient, TransportServer,
                                  decode_payload, encode_frame,
                                  encode_payload, read_frame,
                                  spawn_remote_clients)


# module-level so they pickle across the wire
def _square(x, static):
    return x * x


def _plus_bias(x, static):
    return x + static["bias"]


def _read_weights(x, static):
    return (x, static["weights"])


def _always_raise(x, static):
    raise RuntimeError("boom")


def _fed_dist(n_members=2, **kw):
    kw.setdefault("timeout", 10.0)
    kw.setdefault("redistribute_min", 0.02)
    kw.setdefault("sizer", AdaptiveSizer(target_lease_time=0.05, max_size=8))
    kw.setdefault("watchdog_interval", 0.01)
    return FederatedDistributor(n_members, **kw)


def _dist(**kw):
    kw.setdefault("timeout", 10.0)
    kw.setdefault("redistribute_min", 0.02)
    kw.setdefault("sizer", AdaptiveSizer(target_lease_time=0.05, max_size=8))
    kw.setdefault("watchdog_interval", 0.01)
    return AsyncDistributor(**kw)


def _feed_reader(*chunks: bytes) -> asyncio.StreamReader:
    reader = asyncio.StreamReader()
    for c in chunks:
        reader.feed_data(c)
    reader.feed_eof()
    return reader


# ---------------------------------------------------------------------------
# Framing
# ---------------------------------------------------------------------------


def test_frame_roundtrip():
    msg = {"type": "hello", "seq": 1, "client": "c0", "proto": 1}

    async def go():
        return await read_frame(_feed_reader(encode_frame(msg)))

    assert asyncio.run(go()) == msg


def test_frame_clean_eof_returns_none():
    async def go():
        return await read_frame(_feed_reader())

    assert asyncio.run(go()) is None


@pytest.mark.parametrize("raw", [
    b"\x00\x00\x00",                      # EOF inside the length header
    b"\x00\x00\x00\x10{\"type\"",         # EOF inside the body
])
def test_frame_truncated_raises_instead_of_hanging(raw):
    async def go():
        with pytest.raises(ProtocolError) as ei:
            await read_frame(_feed_reader(raw))
        return ei.value

    assert asyncio.run(go()).code == "truncated-frame"


def test_frame_oversized_rejected_without_reading_body():
    async def go():
        with pytest.raises(ProtocolError) as ei:
            await read_frame(_feed_reader(b"\xff\xff\xff\xff"),
                             max_bytes=1024)
        return ei.value

    assert asyncio.run(go()).code == "frame-too-large"


@pytest.mark.parametrize("body,code", [
    (b"this is not json!!", "bad-json"),
    (b"[1,2,3]", "bad-message"),          # JSON but not an object
    (b"{\"no\":\"type\"}", "bad-message"),
])
def test_frame_bad_body_rejected(body, code):
    import struct
    raw = struct.pack(">I", len(body)) + body

    async def go():
        with pytest.raises(ProtocolError) as ei:
            await read_frame(_feed_reader(raw))
        return ei.value

    assert asyncio.run(go()).code == code


# ---------------------------------------------------------------------------
# Wire codecs (the dataclass layer)
# ---------------------------------------------------------------------------


def test_ticket_wire_roundtrip_preserves_execution_fields():
    t = Ticket(7, "knn", {"lo": 0, "hi": 10}, created_at=123.4, work=2.5,
               distribute_count=3, last_distributed_at=200.0,
               lease_id=11, task_version=9)
    back = Ticket.from_wire(t.to_wire(encode_payload), decode_payload)
    assert (back.ticket_id, back.task_name, back.args, back.work,
            back.lease_id, back.task_version) == \
        (7, "knn", {"lo": 0, "hi": 10}, 2.5, 11, 9)
    # scheduling state is server-only and never crosses the wire
    assert back.created_at == 0.0 and back.distribute_count == 0


def test_lease_batch_wire_roundtrip():
    tickets = [Ticket(i, "t", i * 10, created_at=1.0, lease_id=5,
                      task_version=2) for i in range(3)]
    batch = LeaseBatch(5, "c0", tickets, issued_at=50.0,
                       expected_duration=1.5, shards=["server-only"])
    wire = batch.to_wire(encode_payload)
    assert "shards" not in wire and "issued_at" not in wire
    back = LeaseBatch.from_wire(wire, decode_payload)
    assert back.lease_id == 5 and back.client == "c0"
    assert [t.args for t in back.tickets] == [0, 10, 20]
    assert back.ticket_ids == [0, 1, 2]


def test_fetched_wire_roundtrip():
    got = Fetched({"w": [1, 2]}, 4, current=False)
    back = Fetched.from_wire(got.to_wire(encode_payload), decode_payload)
    assert (back.value, back.version, back.not_modified, back.current) == \
        ({"w": [1, 2]}, 4, False, False)
    nm = Fetched(None, 9, not_modified=True)
    wire = nm.to_wire(encode_payload)
    assert "payload" not in wire
    back = Fetched.from_wire(wire, decode_payload)
    assert back.not_modified and back.version == 9 and back.value is None


# ---------------------------------------------------------------------------
# Server robustness: garbage in, error frame out
# ---------------------------------------------------------------------------


async def _raw_conn(server):
    host, port = server.address
    return await asyncio.open_connection(host, port)


def test_malformed_frame_gets_error_reply_not_a_hung_reader():
    async def go():
        d = _dist()
        server = TransportServer(d)
        await server.start()
        try:
            reader, writer = await _raw_conn(server)
            import struct
            body = b"!!! not json at all"
            writer.write(struct.pack(">I", len(body)) + body)
            await writer.drain()
            reply = await asyncio.wait_for(read_frame(reader), timeout=5.0)
            writer.close()
            return reply, server.protocol_errors
        finally:
            await server.stop()

    reply, errors = asyncio.run(go())
    assert reply["type"] == "error" and reply["code"] == "bad-json"
    assert errors == 1


def test_truncated_frame_after_hello_gets_error_reply():
    async def go():
        d = _dist()
        server = TransportServer(d)
        await server.start()
        try:
            reader, writer = await _raw_conn(server)
            writer.write(encode_frame({"type": "hello", "seq": 1,
                                       "client": "raw",
                                       "proto": PROTOCOL_VERSION}))
            await writer.drain()
            hello = await asyncio.wait_for(read_frame(reader), timeout=5.0)
            # announce a 64-byte body but send only 3 bytes, then EOF
            writer.write(b"\x00\x00\x00\x40abc")
            writer.write_eof()
            reply = await asyncio.wait_for(read_frame(reader), timeout=5.0)
            writer.close()
            return hello, reply
        finally:
            await server.stop()

    hello, reply = asyncio.run(go())
    assert hello["type"] == "hello_ok"
    assert reply["type"] == "error" and reply["code"] == "truncated-frame"


def test_unknown_message_type_rejected_but_connection_survives():
    async def go():
        d = _dist()
        d.register_task(TaskDef("sq", _square))
        server = TransportServer(d)
        await server.start()
        try:
            reader, writer = await _raw_conn(server)
            writer.write(encode_frame({"type": "hello", "seq": 1,
                                       "client": "raw",
                                       "proto": PROTOCOL_VERSION}))
            writer.write(encode_frame({"type": "frobnicate", "seq": 2}))
            # a well-formed request AFTER the bad one must still be served
            writer.write(encode_frame({"type": "fetch_task", "seq": 3,
                                       "name": "sq"}))
            await writer.drain()
            replies = [await asyncio.wait_for(read_frame(reader),
                                              timeout=5.0)
                       for _ in range(3)]
            writer.close()
            return replies
        finally:
            await server.stop()

    hello, bad, fetched = asyncio.run(go())
    assert hello["type"] == "hello_ok"
    assert bad["type"] == "error" and bad["code"] == "bad-type"
    assert fetched["type"] == "task_data" and fetched["seq"] == 3
    assert decode_payload(fetched["payload"]).name == "sq"


def test_hello_with_no_alive_endpoint_gets_error_not_silent_close():
    async def go():
        fed = _fed_dist(2, n_shards=4)
        server = TransportServer(fed)
        await server.start()
        try:
            for i in range(2):             # every member dead
                await fed.kill_member(i)
            reader, writer = await _raw_conn(server)
            writer.write(encode_frame({"type": "hello", "seq": 1,
                                       "client": "late",
                                       "proto": PROTOCOL_VERSION}))
            await writer.drain()
            reply = await asyncio.wait_for(read_frame(reader), timeout=5.0)
            writer.close()
            return reply
        finally:
            await server.stop()

    reply = asyncio.run(go())
    assert reply["type"] == "error" and reply["code"] == "no-endpoint"
    assert reply["seq"] == 1


def test_server_error_with_null_seq_is_fatal_not_a_reconnect_loop():
    """A framing error is reported with seq=null; the client must raise
    ProtocolError instead of discarding the frame and re-dialing to send
    the identical doomed bytes max_reconnects times."""
    async def go():
        d = _dist()
        d.register_task(TaskDef("big", _big_result))
        d.add_work("big", [0])
        # the server refuses to READ frames over 512 bytes; the client's
        # submit (a ~3 KB pickled result) trips it
        server = TransportServer(d, max_frame_bytes=512)
        addr = await server.start()
        clients, tasks = spawn_remote_clients(
            addr, [ClientProfile(name="r0", speed=500.0)],
            reconnect_delay=0.01)
        done, _ = await asyncio.wait(tasks, timeout=10.0)
        assert done, "client hung instead of failing fast"
        exc = list(done)[0].exception()
        await d.shutdown()
        await server.stop()
        return exc, clients[0].reconnects

    exc, reconnects = asyncio.run(go())
    assert isinstance(exc, ProtocolError) and exc.code == "frame-too-large"
    assert reconnects == 0                 # fatal on first sight, no loop


def _big_result(x, static):
    return "x" * 2000


def test_proto_mismatch_refused():
    async def go():
        d = _dist()
        server = TransportServer(d)
        await server.start()
        try:
            reader, writer = await _raw_conn(server)
            writer.write(encode_frame({"type": "hello", "seq": 1,
                                       "client": "old", "proto": 999}))
            await writer.drain()
            reply = await asyncio.wait_for(read_frame(reader), timeout=5.0)
            writer.close()
            return reply
        finally:
            await server.stop()

    reply = asyncio.run(go())
    assert reply["type"] == "error" and reply["code"] == "proto-mismatch"


# ---------------------------------------------------------------------------
# End-to-end rounds over the socket
# ---------------------------------------------------------------------------


def test_remote_round_completes_and_results_match():
    async def go():
        d = _dist()
        d.register_task(TaskDef("sq", _square))
        tids = d.add_work("sq", list(range(40)))
        server = TransportServer(d)
        addr = await server.start()
        clients, tasks = spawn_remote_clients(
            addr, [ClientProfile(name="r0", speed=500.0),
                   ClientProfile(name="r1", speed=100.0)])
        ok = await d.run_until_done(timeout=30.0)
        await asyncio.gather(*tasks)
        await server.stop()
        return ok, d.queue.results(), tids, clients, d

    ok, res, tids, clients, d = asyncio.run(go())
    assert ok
    assert [res[t] for t in tids] == [i * i for i in range(40)]
    # every ticket ran on a RemoteBrowserClient, zero in-process clients
    assert d.clients == []
    assert sum(c.executed for c in clients) >= 40
    # the adaptive sizer saw the remote clients' EWMA rates
    assert all(s.rate for s in d.queue.stats.values())


def test_remote_static_fetch_and_version_pins():
    async def go():
        d = _dist()
        d.add_static("bias", 5)
        d.register_task(TaskDef("pb", _plus_bias, static_files=("bias",)))
        tids = d.add_work("pb", list(range(20)))
        server = TransportServer(d)
        addr = await server.start()
        clients, tasks = spawn_remote_clients(
            addr, [ClientProfile(name="r0", speed=500.0)])
        ok = await d.run_until_done(timeout=30.0)
        await asyncio.gather(*tasks)
        await server.stop()
        return ok, d.queue.results(), tids

    ok, res, tids = asyncio.run(go())
    assert ok
    assert [res[t] for t in tids] == [i + 5 for i in range(20)]


def test_remote_errors_reported_and_work_still_completes():
    async def go():
        d = _dist(grace=2.0)
        d.register_task(TaskDef("sq", _square))
        d.register_task(TaskDef("boom", _always_raise))
        sq_tids = d.add_work("sq", list(range(10)))
        boom_tid = d.add_work("boom", [0])[0]
        server = TransportServer(d)
        addr = await server.start()
        clients, tasks = spawn_remote_clients(
            addr, [ClientProfile(name="r0", speed=500.0)])
        # the boom ticket can never complete; wait for the sq tickets only
        deadline = asyncio.get_event_loop().time() + 30.0
        while d.queue.results_for(sq_tids) is None:
            assert asyncio.get_event_loop().time() < deadline, d.console()
            await asyncio.sleep(0.02)
        reports = []
        for tid in [boom_tid]:
            t = d.queue._tickets[tid]
            reports.extend(t.error_reports)
        for c in clients:
            await c.stop()
        await asyncio.gather(*tasks, return_exceptions=True)
        await d.shutdown()
        await server.stop()
        return d.queue.results_for(sq_tids), reports, clients[0]

    res, reports, client = asyncio.run(go())
    assert res == [i * i for i in range(10)]
    assert reports and "boom" in reports[0][1]       # traceback crossed wire
    assert client.errors >= 1 and client.reloads >= 1


def test_die_after_releases_lease_over_wire():
    async def go():
        d = _dist(grace=2.0)
        d.register_task(TaskDef("sq", _square))
        tids = d.add_work("sq", list(range(30)))
        server = TransportServer(d)
        addr = await server.start()
        clients, tasks = spawn_remote_clients(
            addr, [ClientProfile(name="mortal", speed=200.0, die_after=1),
                   ClientProfile(name="survivor", speed=200.0)])
        ok = await d.run_until_done(timeout=30.0)
        await asyncio.gather(*tasks)
        await server.stop()
        return ok, d.queue.results(), tids, clients

    ok, res, tids, clients = asyncio.run(go())
    assert ok
    assert [res[t] for t in tids] == [i * i for i in range(30)]
    mortal = next(c for c in clients if c.profile.name == "mortal")
    assert mortal.done and mortal.leases_taken == 2   # died on its 2nd lease


# ---------------------------------------------------------------------------
# Conditional fetch parity with the in-process path
# ---------------------------------------------------------------------------


def test_versioned_fetch_not_modified_parity_with_inprocess():
    """A conditional fetch answered over the wire must be byte-for-byte
    the minimal not_modified frame, and decode to exactly the Fetched the
    in-process path returns."""
    async def go():
        d = _dist()
        d.add_static("w", [1, 2, 3])
        d.register_task(TaskDef("sq", _square, static_files=("w",)))
        v_task = d.tasks["sq"].version
        v_static = d.static_version("w")
        server = TransportServer(d)
        await server.start()
        try:
            reader, writer = await _raw_conn(server)
            writer.write(encode_frame({"type": "hello", "seq": 1,
                                       "client": "raw",
                                       "proto": PROTOCOL_VERSION}))
            writer.write(encode_frame({"type": "fetch_task", "seq": 2,
                                       "name": "sq", "if_version": v_task}))
            writer.write(encode_frame({"type": "fetch_static", "seq": 3,
                                       "key": "w", "if_version": v_static}))
            await writer.drain()
            await read_frame(reader)                       # hello_ok
            # capture the raw bytes of the task reply for the byte-level
            # comparison, then parse it
            import struct as _struct
            header = await reader.readexactly(4)
            (length,) = _struct.unpack(">I", header)
            body = header + await reader.readexactly(length)
            static_reply = await asyncio.wait_for(read_frame(reader),
                                                  timeout=5.0)
            writer.close()
            return d, v_task, v_static, body, static_reply
        finally:
            await server.stop()

    d, v_task, v_static, task_bytes, static_reply = asyncio.run(go())
    # byte-for-byte: the wire frame is exactly the canonical encoding of
    # the minimal not_modified message
    assert task_bytes == encode_frame({"type": "not_modified", "seq": 2,
                                       "version": v_task})
    assert static_reply == {"type": "not_modified", "seq": 3,
                            "version": v_static}
    # and the in-process path agrees field-for-field
    inproc = d.fetch_task_versioned("sq", if_version=v_task)
    assert inproc.not_modified and inproc.version == v_task
    inproc_s = d.serve_static_versioned("w", if_version=v_static)
    assert inproc_s.not_modified and inproc_s.version == v_static
    # both wire revalidations landed on the origin's revalidation ledger
    assert d.revalidation_count["task:sq"] >= 1
    assert d.revalidation_count["w"] >= 1


# ---------------------------------------------------------------------------
# Reconnect with resume
# ---------------------------------------------------------------------------


def test_reconnect_after_drop_completes_all_work():
    async def go():
        d = _dist(grace=2.0)
        d.register_task(TaskDef("sq", _square))
        tids = d.add_work("sq", list(range(30)))
        server = TransportServer(d)
        addr = await server.start()
        clients, tasks = spawn_remote_clients(
            addr, [ClientProfile(name="r0", speed=100.0)],
            reconnect_delay=0.02)
        await asyncio.sleep(0.1)           # let a lease get in flight
        assert server.drop_connections() == 1
        ok = await d.run_until_done(timeout=30.0)
        await asyncio.gather(*tasks)
        await server.stop()
        return ok, d.queue.results(), tids, clients[0]

    ok, res, tids, client = asyncio.run(go())
    assert ok
    assert [res[t] for t in tids] == [i * i for i in range(30)]
    assert client.reconnects >= 1


def test_reconnect_after_server_side_lease_expiry_releases_cleanly():
    """Connection dies mid-lease; the client's reconnect is slower than
    the watchdog, so the server releases the lease (the dead-client path)
    BEFORE the client comes back.  The reconnected client re-leases and
    the round still completes exactly."""
    async def go():
        d = _dist(grace=1.0,
                  sizer=AdaptiveSizer(target_lease_time=0.05, max_size=4))
        d.register_task(TaskDef("sq", _square))
        tids = d.add_work("sq", list(range(24)))
        server = TransportServer(d)
        addr = await server.start()
        clients, tasks = spawn_remote_clients(
            addr, [ClientProfile(name="r0", speed=50.0)],
            reconnect_delay=0.5)           # reconnect slower than watchdog
        await asyncio.sleep(0.15)          # mid-lease
        server.drop_connections()
        # wait for the watchdog to actually release the orphaned lease
        deadline = asyncio.get_event_loop().time() + 10.0
        while d.queue.releases == 0:
            assert asyncio.get_event_loop().time() < deadline
            await asyncio.sleep(0.01)
        ok = await d.run_until_done(timeout=30.0)
        await asyncio.gather(*tasks)
        await server.stop()
        return ok, d.queue.results(), tids, clients[0], d.queue.releases

    ok, res, tids, client, releases = asyncio.run(go())
    assert ok
    assert [res[t] for t in tids] == [i * i for i in range(24)]
    assert releases >= 1                   # server-side expiry happened
    assert client.reconnects >= 1          # and the client came back


# ---------------------------------------------------------------------------
# Federation over the wire
# ---------------------------------------------------------------------------


def test_federation_over_transport_spreads_clients_and_serves_edges():
    async def go():
        fed = _fed_dist(2, n_shards=4)
        fed.add_static("bias", 7)
        fed.register_task(TaskDef("pb", _plus_bias, static_files=("bias",)))
        tids = fed.add_work("pb", list(range(40)))
        server = TransportServer(fed)
        addr = await server.start()
        clients, tasks = spawn_remote_clients(
            addr, [ClientProfile(name=f"r{i}", speed=500.0)
                   for i in range(4)])
        ok = await fed.run_until_done(timeout=30.0)
        await asyncio.gather(*tasks)
        await server.stop()
        return ok, fed, tids, clients

    ok, fed, tids, clients = asyncio.run(go())
    assert ok
    res = fed.queue.results()
    assert [res[t] for t in tids] == [i + 7 for i in range(40)]
    # hello bound two clients to each member, least-connected
    assert sorted(c.member for c in clients) == [0, 0, 1, 1]
    # asset traffic went through the members' edges, not the origin:
    # the origin saw at most one cold miss per key per edge
    for key, count in fed.download_count.items():
        assert count <= len(fed.members), (key, count)
    edge_requests = sum(m.edge.stats()["requests"] for m in fed.members)
    assert edge_requests > 0


# ---------------------------------------------------------------------------
# Cache coherence across the serialization boundary
# ---------------------------------------------------------------------------


def test_reregister_storm_over_wire_zero_stale_serves():
    """The PR 3 storm, but with every client on the far side of a socket:
    weights re-registered each round, tickets pin the new coherence
    version, and no ticket may ever observe a stale weight."""
    async def go():
        d = _dist(keep_alive=True)
        d.add_static("weights", -1)
        d.register_task(TaskDef("rw", _read_weights,
                                static_files=("weights",)))
        server = TransportServer(d)
        addr = await server.start()
        clients, tasks = spawn_remote_clients(
            addr, [ClientProfile(name=f"c{i}", speed=2000.0)
                   for i in range(3)])
        stale = total = 0
        for rnd in range(8):
            d.add_static("weights", rnd)
            tids = d.add_work("rw", list(range(12)))
            deadline = asyncio.get_event_loop().time() + 30.0
            while True:
                wake = d._wake_event()
                out = d.queue.results_for(tids)
                if out is not None:
                    break
                assert asyncio.get_event_loop().time() < deadline, \
                    d.console()
                await d._wait_on(wake, 0.05)
            for _, w in out:
                total += 1
                stale += (w != rnd)
            d.queue.prune(tids)
        for c in clients:
            await c.stop()
        await asyncio.gather(*tasks, return_exceptions=True)
        await d.shutdown()
        await server.stop()
        return stale, total, clients

    stale, total, clients = asyncio.run(go())
    assert total == 8 * 12
    assert stale == 0
    # unchanged task code revalidated as counter bumps, not payloads
    assert sum(c.revalidations for c in clients) > 0
    # and the origin's push invalidations reached the remote caches
    assert sum(c.push_invalidations for c in clients) > 0
