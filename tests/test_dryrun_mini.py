"""Mini dry-run: the full lower+compile+roofline path on an 8-device forced
CPU mesh with reduced configs (subprocess so the device-count flag doesn't
leak into other tests).  The production 512-device sweep runs via
``python -m repro.launch.dryrun --all`` (results in results/)."""
import os
import subprocess
import sys
import textwrap

import pytest


def _run(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=__file__.rsplit("/tests/", 1)[0], timeout=600)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    return r.stdout


@pytest.mark.parametrize("arch,shape,strategy", [
    ("qwen3-4b", "train_4k", "split_concurrent"),
    ("dbrx-132b", "decode_32k", "fsdp_tp"),
    ("rwkv6-1.6b", "long_500k", "fsdp_tp"),
])
def test_mini_mesh_lower_compile(arch, shape, strategy):
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, dataclasses
        from repro.configs.base import INPUT_SHAPES, RunConfig, get_smoke_config
        from repro.launch.mesh import make_local_mesh
        from repro.launch.steps import build_step
        from repro.launch.hlo_analysis import (cost_analysis_dict,
                                               roofline_from_compiled)

        shape = dataclasses.replace(INPUT_SHAPES["{shape}"], seq_len=256,
                                    global_batch=8)
        cfg = get_smoke_config("{arch}")
        run = RunConfig(strategy="{strategy}")
        mesh = make_local_mesh(data=2, model=4)
        bundle = build_step(cfg, run, shape, mesh)
        lowered = bundle.lower()
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        roof = roofline_from_compiled(compiled, 8, model_flops=1e6)
        assert roof.flops > 0
        assert mem.temp_size_in_bytes >= 0
        print("MINI_DRYRUN_OK", roof.dominant,
              cost_analysis_dict(compiled).get("flops", 0))
    """)
    out = _run(code)
    assert "MINI_DRYRUN_OK" in out


def test_collective_parse_on_real_hlo():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_local_mesh
        from repro.launch.hlo_analysis import parse_collectives

        mesh = make_local_mesh(data=2, model=4)
        x = jax.ShapeDtypeStruct((8, 512), jnp.float32)
        w = jax.ShapeDtypeStruct((512, 512), jnp.float32)
        f = jax.jit(lambda x, w: (x @ w).sum(),
                    in_shardings=(NamedSharding(mesh, P("data", None)),
                                  NamedSharding(mesh, P(None, "model"))))
        comp = f.lower(x, w).compile()
        stats = parse_collectives(comp.as_text())
        # summing a (data,model)-sharded product requires an all-reduce
        assert stats.total_bytes > 0, comp.as_text()[:800]
        print("PARSE_OK", stats.bytes_by_kind)
    """)
    out = _run(code)
    assert "PARSE_OK" in out
