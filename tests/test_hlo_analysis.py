"""Unit tests for the roofline-term extraction machinery."""
import pytest

from repro.launch.hlo_analysis import (Roofline, parse_collectives,
                                       shape_bytes)


def test_shape_bytes_simple():
    assert shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert shape_bytes("bf16[2,3,4]{2,1,0}") == 24 * 2
    assert shape_bytes("pred[8]") == 8
    assert shape_bytes("s32[]") == 4  # scalar: empty dims -> one element


def test_shape_bytes_tuple():
    s = "(f32[64,64]{1,0}, u8[128])"
    assert shape_bytes(s) == 64 * 64 * 4 + 128


def test_parse_collectives_counts_and_kinds():
    hlo = """
  %ag = f32[512,128]{1,0} all-gather(%p0), replica_groups={...}
  %ar.1 = bf16[256]{0} all-reduce(%x), to_apply=%add
  %rs = (f32[64]{0}, f32[64]{0}) reduce-scatter(%a, %b), dimensions={0}
  %a2a = f32[32,32]{1,0} all-to-all(%y), dimensions={0}
  %cp = f32[16]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %ags = f32[512,128]{1,0} all-gather-start(%p1)
  %normal = f32[10]{0} add(%u, %v)
"""
    stats = parse_collectives(hlo)
    assert stats.count_by_kind["all-gather"] == 2  # incl. -start
    assert stats.count_by_kind["all-reduce"] == 1
    assert stats.count_by_kind["reduce-scatter"] == 1
    assert stats.count_by_kind["all-to-all"] == 1
    assert stats.count_by_kind["collective-permute"] == 1
    assert stats.bytes_by_kind["all-gather"] == 2 * 512 * 128 * 4
    assert stats.bytes_by_kind["reduce-scatter"] == 2 * 64 * 4
    assert stats.total_bytes > 0


def test_roofline_terms_and_dominant():
    r = Roofline(flops=256 * 197e12, hbm_bytes=256 * 819e9 * 2,
                 collective_bytes=256 * 50e9 * 0.5, chips=256,
                 model_flops=256 * 197e12 * 0.8)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.dominant == "memory"
    assert r.useful_flops_ratio == pytest.approx(0.8)
    d = r.as_dict()
    assert d["dominant"] == "memory"
