"""Pallas kernel sweeps: shapes x dtypes, assert_allclose vs the pure-jnp
ref.py oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.adagrad.ops import adagrad_update
from repro.kernels.adagrad.ref import adagrad_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba.ops import mamba_scan
from repro.kernels.mamba.ref import mamba_scan_ref
from repro.kernels.rwkv6.ops import wkv
from repro.kernels.rwkv6.ref import wkv_ref
from repro.kernels.server_step.ops import server_step_update
from repro.kernels.server_step.ref import server_step_ref

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("b,s,hq,hkv,hd", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 8, 2, 64),     # GQA 4:1
    (1, 512, 4, 1, 128),    # MQA
    (2, 192, 6, 2, 32),     # non-power-of-two seq (padding path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 64)])
def test_flash_attention_sweep(b, s, hq, hkv, hd, dtype, causal, window):
    q = jnp.asarray(RNG.normal(size=(b, s, hq, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, s, hkv, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, s, hkv, hd)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window)
    ref = attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal,
        window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("b,t,h,hd", [(1, 64, 2, 64), (2, 200, 4, 64),
                                      (1, 128, 8, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_wkv_sweep(b, t, h, hd, dtype):
    r = jnp.asarray(RNG.normal(size=(b, t, h, hd)) * 0.5, dtype)
    k = jnp.asarray(RNG.normal(size=(b, t, h, hd)) * 0.5, dtype)
    v = jnp.asarray(RNG.normal(size=(b, t, h, hd)) * 0.5, dtype)
    w = jnp.asarray(RNG.uniform(0.7, 0.999, size=(b, t, h, hd)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(h, hd)) * 0.1, jnp.float32)
    s0 = jnp.asarray(RNG.normal(size=(b, h, hd, hd)) * 0.1, jnp.float32)
    y1, sT1 = wkv(r, k, v, w, u, s0)
    y2, sT2 = wkv_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(sT1), np.asarray(sT2),
                               atol=1e-3, rtol=1e-3)


def test_rwkv6_state_chaining_equals_one_shot():
    """Running two chunks with carried state == one long sequence."""
    b, t, h, hd = 1, 64, 2, 64
    r, k, v = (jnp.asarray(RNG.normal(size=(b, t, h, hd)), jnp.float32) * 0.5
               for _ in range(3))
    w = jnp.asarray(RNG.uniform(0.7, 0.99, size=(b, t, h, hd)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(h, hd)) * 0.1, jnp.float32)
    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    y_full, sT_full = wkv(r, k, v, w, u, s0)
    y1, s1 = wkv(r[:, :32], k[:, :32], v[:, :32], w[:, :32], u, s0)
    y2, s2 = wkv(r[:, 32:], k[:, 32:], v[:, 32:], w[:, 32:], u, s1)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate([y1, y2], 1)),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(sT_full), np.asarray(s2),
                               atol=1e-4)


@pytest.mark.parametrize("b,t,di,ds", [(1, 64, 512, 16), (2, 96, 1024, 8),
                                       (1, 64, 512, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba_scan_sweep(b, t, di, ds, dtype):
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, size=(b, t, di)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(b, t, di)), dtype)
    b_t = jnp.asarray(RNG.normal(size=(b, t, ds)), dtype)
    c_t = jnp.asarray(RNG.normal(size=(b, t, ds)), dtype)
    a = -jnp.asarray(RNG.uniform(0.5, 4.0, size=(di, ds)), jnp.float32)
    h0 = jnp.asarray(RNG.normal(size=(b, di, ds)) * 0.1, jnp.float32)
    y1, h1 = mamba_scan(dt, x, b_t, c_t, a, h0)
    y2, h2 = mamba_scan_ref(dt, x, b_t, c_t, a, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               **_tol(dtype))
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("shape", [(127,), (8, 1024), (33, 77), (3, 5, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("wd", [0.0, 0.01])
def test_adagrad_kernel_sweep(shape, dtype, wd):
    p = jnp.asarray(RNG.normal(size=shape), dtype)
    g = jnp.asarray(RNG.normal(size=shape), dtype)
    acc = jnp.asarray(np.abs(RNG.normal(size=shape)), jnp.float32)
    p1, a1 = adagrad_update(p, g, acc, lr=0.05, beta=1.5, weight_decay=wd)
    p2, a2 = adagrad_ref(p, g, acc, lr=0.05, beta=1.5, weight_decay=wd)
    np.testing.assert_allclose(np.asarray(p1, np.float32),
                               np.asarray(p2, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("shape", [
    (1,),        # pads to a single block
    (127,),      # sub-tile remainder
    (8192,),     # exactly BLOCK_ROWS x BLOCK_COLS, zero padding
    (33, 77),    # odd 2-d leaf
    (3, 5, 7),   # 3-d leaf
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("members,wd", [(1, 0.0), (5, 0.01)])
def test_server_step_kernel_sweep(shape, dtype, members, wd):
    """The interpret-mode fused server-step kernel is BIT-equal — not
    allclose — to the XLA-jitted oracle over the same padded program
    (``mode="xla"``): the federated loop's fused and reference paths
    must be interchangeable without drifting the trajectory.  A plain
    allclose against the unpadded oracle guards the math itself (the
    bit comparison can't see a shared bug in the padded pipeline)."""
    import functools
    p = jnp.asarray(RNG.normal(size=shape), dtype)
    acc = jnp.asarray(np.abs(RNG.normal(size=shape)), jnp.float32)
    g = jnp.asarray(RNG.normal(size=(members,) + shape), dtype)
    coeffs = jnp.asarray(RNG.uniform(0.1, 1.0, size=members), jnp.float32)
    kw = dict(lr=0.05, beta=1.5, weight_decay=wd)
    p1, a1 = server_step_update(p, g, acc, coeffs, mode="interpret", **kw)
    p2, a2 = server_step_update(p, g, acc, coeffs, mode="xla", **kw)
    assert np.array_equal(np.asarray(p1), np.asarray(p2))
    assert np.array_equal(np.asarray(a1), np.asarray(a2))
    p3, a3 = jax.jit(functools.partial(server_step_ref, **kw))(
        p, g, acc, coeffs)
    np.testing.assert_allclose(np.asarray(p1, np.float32),
                               np.asarray(p3, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a3),
                               atol=1e-5, rtol=1e-5)


def test_flash_attention_matches_model_attention_layer():
    """The kernel agrees with the XLA attention path used by the models."""
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.models import layers as L
    from repro.sharding.spec import values_tree

    cfg = get_smoke_config("qwen3-4b")
    p = values_tree(L.init_attention(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    pos = jnp.arange(64)
    y_model, (k, v) = L.attention(p, cfg, x, positions=pos)
    # rebuild q/k/v exactly as the layer does, then run the kernel
    q, k2, v2 = L._proj_qkv(p, cfg, x, x)
    cos, sin = L.rope_cos_sin(pos, cfg.resolved_head_dim, cfg.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k2 = L.apply_rope(k2, cos, sin)
    out = flash_attention(q, k2, v2, causal=True)
    y_kernel = jnp.einsum("bqhe,hed->bqd", out, p["wo"])
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_kernel),
                               atol=2e-4, rtol=1e-3)
