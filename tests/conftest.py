import os
import sys
import types

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# tests/chaos.py holds real test functions but is imported (via
# tests/test_chaos.py) rather than collected directly; opt it into
# pytest's assert rewriting so its failures stay introspectable
pytest.register_assert_rewrite("chaos")

# The container has no `hypothesis`; register the deterministic shim in its
# place so the property tests still execute (see tests/_hypothesis_shim.py).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_shim as _shim

    hyp = types.ModuleType("hypothesis")
    hyp.given = _shim.given
    hyp.settings = _shim.settings
    hyp.strategies = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "lists", "sampled_from", "booleans",
                  "just", "binary", "one_of", "tuples"):
        setattr(hyp.strategies, _name, getattr(_shim, _name))
    extra = types.ModuleType("hypothesis.extra")
    extra.numpy = types.ModuleType("hypothesis.extra.numpy")
    extra.numpy.arrays = _shim.arrays
    extra.numpy.array_shapes = _shim.array_shapes
    hyp.extra = extra
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = hyp.strategies
    sys.modules["hypothesis.extra"] = extra
    sys.modules["hypothesis.extra.numpy"] = extra.numpy

import jax

jax.config.update("jax_platform_name", "cpu")
