"""Training-fabric tests: the round engine's K-of-N barrier and
straggler policies, per-member affinity placement, shard rebalancing,
explicit client-lifetime ownership, and resumable round checkpoints."""
import asyncio

import numpy as np
import pytest

from repro.core.distributor import (AdaptiveSizer, AsyncDistributor,
                                    ClientProfile, FixedSizer, TaskDef)
from repro.core.federation import FederatedDistributor
from repro.core.shards import ShardedTicketQueue
from repro.core.split_parallel import (SplitConcurrentDispatcher,
                                       TrainState, weighted_grad_mean)
from repro.core.tickets import CANCELLED, TicketQueue
from repro.optim import adagrad
from repro.train_fabric import (EmptyRoundError, FederatedTrainer,
                                FederatedTrainingLoop, FusedServerStep,
                                Rebalancer, RoundResult, TreeServerStep,
                                checkpoint_path, latest_checkpoint,
                                load_round_checkpoint, member_coeffs,
                                resolve_barrier_k, save_round_checkpoint,
                                state_from_tree, state_to_tree)


def _run(coro):
    return asyncio.run(coro)


def make_fed(n_members=2, **kw):
    kw.setdefault("timeout", 5.0)
    kw.setdefault("redistribute_min", 0.02)
    kw.setdefault("sizer", AdaptiveSizer(target_lease_time=0.02, max_size=8))
    kw.setdefault("watchdog_interval", 0.005)
    kw.setdefault("grace", 2.0)
    return FederatedDistributor(n_members, **kw)


# --- queue-level primitives -------------------------------------------------


def test_cancel_drains_bookkeeping_and_drops_late_submit():
    q = TicketQueue(timeout=5.0, redistribute_min=0.01)
    tids = q.add_many("t", [1, 2, 3])
    batch = q.lease("c", 3)
    assert q.cancel(tids[1:]) == 2
    assert not q.all_done()                      # tids[0] still open
    # the straggler's late submit for a cancelled ticket is a duplicate
    assert q.submit_batch(batch.lease_id, {tids[1]: "late"}, "c") == 0
    assert q.submit(tids[0], "real", "c")
    assert q.all_done()
    got = q.completed_results(tids)
    assert got[tids[0]] == "real"
    assert got[tids[1]] is CANCELLED and got[tids[2]] is CANCELLED
    # cancelling an already-completed or unknown id is a no-op
    assert q.cancel([tids[0], 999]) == 0


def test_completed_results_is_partial():
    q = TicketQueue(timeout=5.0, redistribute_min=0.01)
    tids = q.add_many("t", ["a", "b"])
    assert q.completed_results(tids) == {}
    q.lease("c", 1)
    q.submit(tids[0], "ra", "c")
    assert q.completed_results(tids) == {tids[0]: "ra"}
    assert q.results_for(tids) is None           # all-or-nothing contract


def test_sharded_add_many_explicit_shard_placement_routes_results():
    q = ShardedTicketQueue(4, timeout=5.0, redistribute_min=0.01)
    a = q.add_many("task", [1, 2], shard=3)
    b = q.add_many("task", [3], shard=0)
    assert all(t.ticket_id in [x for x in a]
               for t in q.shards[3]._tickets.values())
    assert len(q.shards[0]._tickets) == 1
    # same task name, two shards: submit/results/cancel still route
    batch = q.lease("c", 3)
    assert sorted(batch.ticket_ids) == sorted(a + b)
    q.submit_batch(batch.lease_id, {a[0]: 10, b[0]: 30}, "c")
    assert q.completed_results(a + b) == {a[0]: 10, b[0]: 30}
    assert q.cancel([a[1]]) == 1
    assert q.all_done()


def test_sharded_cancel_gcs_fully_drained_lease():
    """A lease whose every ticket was cancelled (fold path, client dead —
    it will never submit) must not leak its global lease record."""
    q = ShardedTicketQueue(2, timeout=5.0, redistribute_min=0.01)
    tids = q.add_many("t", [1, 2])
    batch = q.lease("doomed", 2)
    assert batch is not None and len(q._leases) == 1
    q.cancel(tids)
    assert q.all_done()
    assert q._leases == {}          # GC'd, not leaked until process exit


def test_resolve_barrier_k():
    assert resolve_barrier_k(8, None) == 8
    assert resolve_barrier_k(8, 6) == 6
    assert resolve_barrier_k(8, 100) == 8
    assert resolve_barrier_k(8, 0) == 1
    assert resolve_barrier_k(8, 0.75) == 6
    assert resolve_barrier_k(8, 0.8) == 7        # ceil
    assert resolve_barrier_k(8, 1.0) == 8
    with pytest.raises(ValueError):
        resolve_barrier_k(8, 1.5)
    with pytest.raises(KeyError):
        FederatedTrainer(make_fed(), straggler_policy="nope")


# --- the round engine -------------------------------------------------------


def _grad_task():
    def run(args, static):
        return {"grad": {"w": np.full(2, float(args), np.float32)},
                "loss": float(args),
                "round": static["weights"]["round"]}
    return TaskDef("backbone_shard", run, static_files=("weights",))


async def _basic_round(policy, barrier_k, profiles):
    # one-ticket leases: the slow client holds exactly one shard, so the
    # K-of-N policies trigger deterministically
    fed = make_fed(2, n_shards=4, sizer=FixedSizer(1))
    fed.register_task(_grad_task())
    fed.spawn_clients(profiles)
    async with FederatedTrainer(fed, barrier_k=barrier_k,
                                straggler_policy=policy,
                                timeout=20.0) as tr:
        res = await tr.run_round(
            list(range(6)), shard_work=[1.0] * 6,
            statics={"weights": {"round": 0}})
    await fed.shutdown()
    return res


def test_run_round_full_barrier_orders_results():
    res = _run(_basic_round(
        "wait", None,
        [ClientProfile(name=f"c{i}", speed=500.0) for i in range(3)]))
    assert res.complete and res.stragglers == []
    assert [r["loss"] for r in res.results] == [0.0, 1, 2, 3, 4, 5]
    assert res.work_arrived == res.work_total == 6.0


def test_run_round_fold_cancels_straggler():
    # one client is ~1000x slower; the barrier closes at 5 of 6 and folds
    res = _run(_basic_round(
        "fold", 5,
        [ClientProfile(name="fast0", speed=500.0),
         ClientProfile(name="fast1", speed=500.0),
         ClientProfile(name="dead-slow", speed=0.5)]))
    assert len(res.arrived) >= 5
    assert len(res.stragglers) <= 1
    for p in res.stragglers:
        assert res.results[p] is None
    assert res.work_arrived == float(len(res.arrived))


def test_run_round_reticket_recovers_all_results():
    res = _run(_basic_round(
        "reticket", 5,
        [ClientProfile(name="fast0", speed=500.0),
         ClientProfile(name="fast1", speed=500.0),
         ClientProfile(name="dead-slow", speed=0.5)]))
    # the laggard's lease was force-released and a fast client redid it:
    # every shard still arrived, math exact
    assert res.complete
    assert [r["loss"] for r in res.results] == [0.0, 1, 2, 3, 4, 5]


def test_trainer_restores_keep_alive_and_aclose_is_idempotent():
    async def body():
        fed = make_fed(2)
        assert fed.keep_alive is False
        tr = FederatedTrainer(fed)
        assert fed.keep_alive is True
        await tr.aclose()
        assert fed.keep_alive is False
        await tr.aclose()                        # idempotent
        with pytest.raises(RuntimeError):
            await tr.run_round([1])
        # a pre-set keep_alive=True caller keeps its mode
        fed2 = make_fed(2, keep_alive=True)
        async with FederatedTrainer(fed2):
            pass
        assert fed2.keep_alive is True
        await fed.shutdown()
        await fed2.shutdown()
    _run(body())


def test_split_dispatcher_restores_keep_alive():
    async def body():
        d = AsyncDistributor(timeout=5.0, redistribute_min=0.02)
        assert d.keep_alive is False
        async with SplitConcurrentDispatcher(d) as disp:
            assert d.keep_alive is True
            d.register_task(TaskDef("backbone_shard",
                                    lambda a, s: a * 2))
            d.spawn_clients([ClientProfile(name="c", speed=500.0)])
            out = await disp.run_round([1, 2, 3], timeout=20.0)
            assert out == [2, 4, 6]
        assert d.keep_alive is False
        await d.shutdown()
    _run(body())


def test_affinity_placement_spreads_over_alive_members_home_shards():
    fed = make_fed(3, n_shards=6)
    tr = FederatedTrainer(fed)
    groups = tr.placement(6)
    # every target shard belongs to some alive member's home set
    home_all = {j for m in fed.members
                for j in fed.home_shard_indices(m.index)}
    assert set(groups) <= home_all
    assert sorted(p for ps in groups.values() for p in ps) == list(range(6))
    # a dead member's home shards stop receiving placements
    fed.members[0].alive = False
    groups2 = tr.placement(6)
    dead_home = set(fed.home_shard_indices(0))
    assert not (set(groups2) & dead_home)
    # single AsyncDistributor: no placement (plain add_work path)
    d = AsyncDistributor()
    assert FederatedTrainer(d).placement(4) is None


def test_plan_shards_uses_measured_rates():
    fed = make_fed(2)
    tr = FederatedTrainer(fed)
    assert tr.plan_shards(10, default_shards=4) == [3, 3, 2, 2]
    from repro.core.tickets import ClientStats
    fed.queue.stats["fast"] = ClientStats("fast", rate=30.0)
    fed.queue.stats["slow"] = ClientStats("slow", rate=10.0)
    sizes = tr.plan_shards(8)
    assert sorted(sizes) == [2, 6]
    # the satellite surface: AsyncDistributor.client_rates matches
    d = AsyncDistributor()
    d.queue.stats["c"] = ClientStats("c", rate=5.0)
    assert d.client_rates() == {"c": 5.0}


def test_timed_out_round_cancels_its_tickets():
    """An abandoned round must not leave zombie tickets leasable (or
    all_done() poisoned) after the TimeoutError is handled."""
    async def body():
        fed = make_fed(2, n_shards=4, sizer=FixedSizer(1))
        fed.register_task(_grad_task())
        fed.spawn_clients([ClientProfile(name="dead-slow", speed=0.01)])
        async with FederatedTrainer(fed, timeout=0.2) as tr:
            with pytest.raises(TimeoutError):
                await tr.run_round([0, 1],
                                   statics={"weights": {"round": 0}})
            assert fed.queue.all_done()
            assert fed.queue.results() == {}       # pruned, not lingering
        await fed.shutdown()
    _run(body())


def test_plan_shards_skips_dead_members_clients():
    """EWMA entries outlive their clients; a killed member's clients
    must not be apportioned phantom shards."""
    async def body():
        from repro.core.tickets import ClientStats
        fed = make_fed(2)
        fed.spawn_clients([ClientProfile(name="gone", speed=100.0)],
                          member=0)
        fed.spawn_clients([ClientProfile(name="alive", speed=100.0)],
                          member=1)
        fed.queue.stats["gone"] = ClientStats("gone", rate=50.0)
        fed.queue.stats["alive"] = ClientStats("alive", rate=50.0)
        tr = FederatedTrainer(fed)
        assert sorted(tr.plan_shards(8)) == [4, 4]
        await fed.kill_member(0)
        assert tr.plan_shards(8) == [8]        # only the live client
        await tr.aclose(shutdown=True)
    _run(body())


# --- rebalancer -------------------------------------------------------------


def test_rebalancer_migrates_to_chronic_stealer():
    fed = make_fed(2, n_shards=4)
    reb = Rebalancer(fed, steal_threshold=2, cooldown=1)
    # backlog on member0's home shards; member1 keeps stealing
    fed.register_task(TaskDef("t", lambda a, s: a))
    home0 = fed.home_shard_indices(0)
    fed.add_work("t", list(range(10)), shard=home0[0])
    fed.members[1].steals = 5
    migs = reb.observe_round()
    assert len(migs) == 1
    m = migs[0]
    assert m.reason == "steals"
    assert m.from_member == 0 and m.to_member == 1
    assert m.shard_index in home0
    assert m.shard_index in fed.home_shard_indices(1)
    assert fed.migrations == 1
    # cool-down: an immediately repeated signal does not migrate again
    fed.members[1].steals += 5
    assert reb.observe_round() == []


def test_rebalancer_fails_over_dead_members_shards():
    fed = make_fed(3, n_shards=6)
    reb = Rebalancer(fed)
    dead_home = fed.home_shard_indices(0)
    assert len(dead_home) == 2
    fed.members[0].alive = False
    migs = reb.observe_round()
    assert {m.reason for m in migs} == {"failover"}
    assert sorted(m.shard_index for m in migs) == sorted(dead_home)
    assert fed.home_shard_indices(0) == []
    # survivors got one each (round-robin)
    assert len(fed.home_shard_indices(1)) == 3
    assert len(fed.home_shard_indices(2)) == 3


def test_migrate_shard_guards():
    fed = make_fed(2, n_shards=4)
    own0 = fed.home_shard_indices(0)
    assert fed.migrate_shard(own0[0], 0) is False      # already owns it
    fed.members[1].alive = False
    with pytest.raises(RuntimeError):
        fed.migrate_shard(own0[0], 1)


# --- aggregate fusion -------------------------------------------------------


def test_weighted_grad_mean_matches_manual_weighting():
    rng = np.random.default_rng(0)
    shards = [{"a": rng.normal(size=(3, 2)).astype(np.float32),
               "b": {"c": rng.normal(size=4).astype(np.float32)}}
              for _ in range(4)]
    sizes = [1.0, 2.0, 3.0, 6.0]
    out = weighted_grad_mean(shards, sizes)
    total = sum(sizes)
    want_a = sum(s["a"] * (w / total) for s, w in zip(shards, sizes))
    np.testing.assert_allclose(out["a"], want_a, atol=1e-6)
    want_c = sum(s["b"]["c"] * (w / total) for s, w in zip(shards, sizes))
    np.testing.assert_allclose(out["b"]["c"], want_c, atol=1e-6)
    # the dispatcher's staticmethod is the same fused rule
    out2 = SplitConcurrentDispatcher.aggregate(shards, sizes)
    np.testing.assert_array_equal(out["a"], out2["a"])


# --- checkpointing ----------------------------------------------------------


def _full_state(opt):
    import jax.numpy as jnp
    params = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
              "emb": jnp.asarray(np.ones((2, 2)), jnp.bfloat16)}
    head = {"head": {"out": np.full((3,), 0.5, np.float32)}}
    return TrainState(
        params=params, head=head,
        head_stale={"head": {"out": np.full((3,), 0.25, np.float32)}},
        opt_state=opt.init(params), head_opt_state=opt.init(head),
        prev_features=np.zeros((2, 4), np.float32),
        prev_labels=np.zeros((2,), np.int32),
        prev_mask=np.ones((2,), np.float32),
        step=np.asarray(7, np.int32))


def test_round_checkpoint_roundtrips_full_train_state(tmp_path):
    import jax
    opt = adagrad(0.1)
    state = _full_state(opt)
    path = save_round_checkpoint(
        checkpoint_path(str(tmp_path), 3), state, round_index=3,
        extra={"losses": [1.0, 0.5], "policy": "reticket"})
    assert latest_checkpoint(str(tmp_path)) == path
    got, rnd, extra = load_round_checkpoint(path)
    assert rnd == 3
    assert extra["policy"] == "reticket" and extra["losses"] == [1.0, 0.5]
    a_leaves = jax.tree_util.tree_leaves(state_to_tree(state))
    b_leaves = jax.tree_util.tree_leaves(state_to_tree(got))
    assert len(a_leaves) == len(b_leaves)
    for a, b in zip(a_leaves, b_leaves):
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(
            a.view(np.uint8) if a.dtype.kind in "fV" else a,
            b.view(np.uint8) if b.dtype.kind in "fV" else b)
    assert int(got.step) == 7
    with pytest.raises(ValueError):
        (tmp_path / "bad.json").write_text('{"__dict__": {}}')
        load_round_checkpoint(str(tmp_path / "bad.json"))


def test_state_tree_roundtrip_preserves_structure():
    opt = adagrad(0.1)
    state = _full_state(opt)
    rebuilt = state_from_tree(state_to_tree(state))
    assert isinstance(rebuilt, TrainState)
    assert rebuilt.prev_features.shape == (2, 4)


# --- the training loop: kill/resume regression ------------------------------


def _lin_data():
    rng = np.random.default_rng(11)
    X = rng.normal(size=(48, 4)).astype(np.float32)
    y = (X @ rng.normal(size=4).astype(np.float32)).astype(np.float32)
    return X, y


_X, _Y = _lin_data()


def _lin_grad_task():
    def run(args, static):
        lo, hi = args
        w = np.asarray(static["weights"]["params"]["w"])
        r = _X[lo:hi] @ w - _Y[lo:hi]
        return {"grad": {"w": (2 * _X[lo:hi].T @ r / (hi - lo))
                         .astype(np.float32)},
                "loss": float((r ** 2).mean()),
                "round": static["weights"]["round"]}
    return TaskDef("backbone_shard", run, static_files=("weights",))


async def _train(rounds, ckdir, resume_from=None, server_step_factory=None):
    fed = make_fed(2, n_shards=4, sizer=FixedSizer(1))
    fed.register_task(_lin_grad_task())
    fed.spawn_clients([ClientProfile(name=f"c{i}", speed=500.0)
                       for i in range(3)])
    opt = adagrad(0.2)
    if resume_from is None:
        params = {"w": np.zeros(4, np.float32)}
        state = TrainState(params=params, head={}, head_stale={},
                           opt_state=opt.init(params), head_opt_state={},
                           prev_features=(), prev_labels=(), prev_mask=(),
                           step=np.zeros((), np.int32))
        start = 0
    else:
        state, start, _ = load_round_checkpoint(resume_from)
    trainer = FederatedTrainer(fed, timeout=20.0)
    loop = FederatedTrainingLoop(trainer, opt, state, round_index=start,
                                 checkpoint_dir=ckdir,
                                 server_step=(None if server_step_factory
                                              is None
                                              else server_step_factory(opt)))
    args = [(i, i + 12) for i in range(0, 48, 12)]
    async with trainer:
        for _ in range(start, rounds):
            await loop.run_round(args, [12.0] * 4)
        await trainer.aclose(shutdown=True)
    return loop


def _wire_grad_shard(args, static):
    """Module-level so the task code pickles across the wire."""
    lo, hi = args
    w = np.asarray(static["weights"]["params"]["w"])
    r = _X[lo:hi] @ w - _Y[lo:hi]
    return {"grad": {"w": (2 * _X[lo:hi].T @ r / (hi - lo))
                     .astype(np.float32)},
            "loss": float((r ** 2).mean()),
            "round": static["weights"]["round"]}


def test_training_rounds_over_wire_with_member_failover():
    """The round engine is transport-agnostic: remote clients speaking
    only the wire protocol drive training rounds, and when a member dies
    mid-training its connections are dropped so the clients reconnect to
    a survivor and the next round still completes exactly."""
    from repro.core.transport import TransportServer, spawn_remote_clients

    async def body():
        fed = make_fed(2, n_shards=4)
        fed.register_task(TaskDef("backbone_shard", _wire_grad_shard,
                                  static_files=("weights",)))
        server = TransportServer(fed)
        host, port = await server.start()
        clients, tasks = spawn_remote_clients(
            (host, port),
            [ClientProfile(name=f"r{i}", speed=500.0) for i in range(3)],
            reconnect_delay=0.02)
        opt = adagrad(0.2)
        params = {"w": np.zeros(4, np.float32)}
        state = TrainState(params=params, head={}, head_stale={},
                           opt_state=opt.init(params), head_opt_state={},
                           prev_features=(), prev_labels=(), prev_mask=(),
                           step=np.zeros((), np.int32))
        trainer = FederatedTrainer(fed, timeout=20.0)
        loop = FederatedTrainingLoop(trainer, opt, state)
        shard_args = [(i, i + 12) for i in range(0, 48, 12)]
        async with trainer:
            res = await loop.run_round(shard_args, [12.0] * 4)
            assert res.complete
            await fed.kill_member(0)
            dropped = server.drop_member_connections(0)
            res2 = await loop.run_round(shard_args, [12.0] * 4)
            assert res2.complete
        await asyncio.gather(*tasks)
        await server.stop()
        assert dropped >= 1
        assert loop.stale_executions == 0
        assert len(loop.losses) == 2 and loop.losses[1] < loop.losses[0]
        # every surviving connection is bound to the alive member
        assert all(c.member == 1 for c in clients if c.member is not None)
        await fed.shutdown()

    _run(body())


def test_kill_and_resume_at_round_boundary_reproduces_trajectory(tmp_path):
    full = _run(_train(5, str(tmp_path / "a")))
    assert full.stale_executions == 0
    # "kill" after 2 rounds, resume from the round-2 checkpoint
    killed_dir = str(tmp_path / "b")
    _run(_train(2, killed_dir))
    ck = latest_checkpoint(killed_dir)
    assert ck == checkpoint_path(killed_dir, 2)
    resumed = _run(_train(5, str(tmp_path / "c"), resume_from=ck))
    assert resumed.round_index == 5 and len(resumed.losses) == 3
    np.testing.assert_allclose(resumed.losses, full.losses[2:],
                               rtol=0, atol=1e-7)


# --- the server step --------------------------------------------------------


def _ragged_tree(rng, dtype, scale=1.0):
    """A deliberately ragged multi-leaf pytree: 2-d, 1-d, nested 3-d and
    tiny leaves — exercises the fused path's flatten/concat bookkeeping
    and the leafwise path's tree_map alike.  The smallest leaf is 3
    elements: XLA scalarises 1-2-element leaves with FMA contraction the
    explicit kernel doesn't replay, so the bit-equivalence contract
    starts at 3 (see the ServerStep module docstring)."""
    import jax.numpy as jnp
    mk = lambda *s: jnp.asarray(rng.normal(size=s).astype(np.float32)
                                * scale, dtype)
    return {"w": mk(33, 7), "b": mk(5),
            "deep": {"k": mk(3, 5, 7), "tiny": mk(3)}}


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("clip", [None, 1.0])
def test_fused_server_step_bit_equal_to_tree_reference(dtype, clip):
    """FusedServerStep (interpret-mode Pallas kernel AND the leafwise
    XLA fusion) is BIT-equal to the TreeServerStep reference on ragged
    multi-leaf trees — params and accumulator both, across dtypes and
    with clipping on or off.  This is the contract that lets the
    federated loop swap implementations without moving the trajectory."""
    import jax
    import jax.numpy as jnp
    dt = jnp.dtype(dtype)
    rng = np.random.default_rng(5)
    params = _ragged_tree(rng, dt)
    grads = [_ragged_tree(rng, dt, scale=0.5) for _ in range(4)]
    works = [1.0, 2.0, 0.5, 1.5]
    opt = adagrad(0.05, beta=1.5)
    state = opt.init(params)
    p1, s1 = TreeServerStep(opt, clip_norm=clip).step(
        grads, works, params, state)
    for mode in ("interpret", "xla"):
        p2, s2 = FusedServerStep(opt, lr=0.05, beta=1.5, clip_norm=clip,
                                 mode=mode).step(grads, works, params, state)
        for a, b in zip(jax.tree_util.tree_leaves((p1, s1["acc"])),
                        jax.tree_util.tree_leaves((p2, s2["acc"]))):
            assert np.array_equal(np.asarray(a), np.asarray(b)), \
                f"mode={mode} diverged from the tree reference"


def test_member_coeffs_clip_disabled_is_pure_work_weighting():
    """With clipping off the coefficients are exactly the normalised
    work weights, and a clip bound no member reaches is a bitwise
    identity (min(1, big/norm) == 1.0 exactly) — so enabling the clip
    argument 'just in case' costs nothing when it never binds."""
    import jax.numpy as jnp
    rng = np.random.default_rng(9)
    grads = [_ragged_tree(rng, jnp.float32) for _ in range(3)]
    works = [3.0, 1.0, 2.0]
    c_off = member_coeffs(grads, works)
    np.testing.assert_array_equal(
        np.asarray(c_off), np.asarray(works, np.float32) / 6.0)
    c_huge = member_coeffs(grads, works, clip_norm=1e9)
    np.testing.assert_array_equal(np.asarray(c_off), np.asarray(c_huge))
    # a binding clip really rescales: member norms here are >> 0.01
    c_tight = member_coeffs(grads, works, clip_norm=0.01)
    assert (np.asarray(c_tight) < np.asarray(c_off)).all()


def test_training_rounds_through_custom_and_fused_server_step(tmp_path):
    """The loop delegates every round's aggregate+update to the injected
    ServerStep, and swapping the reference for the fused implementation
    reproduces the identical loss trajectory through real fabric rounds."""
    calls = []

    class CountingStep(TreeServerStep):
        def step(self, grads, works, params, opt_state):
            calls.append(len(grads))
            return super().step(grads, works, params, opt_state)

    base = _run(_train(3, str(tmp_path / "a")))
    custom = _run(_train(3, str(tmp_path / "b"),
                         server_step_factory=CountingStep))
    assert calls == [4, 4, 4]            # one call per round, 4 shards
    np.testing.assert_allclose(custom.losses, base.losses, rtol=0, atol=0)
    fused = _run(_train(
        3, str(tmp_path / "c"),
        server_step_factory=lambda opt: FusedServerStep(opt, lr=0.2)))
    np.testing.assert_allclose(fused.losses, base.losses, rtol=0, atol=0)


def test_fused_server_step_rejects_non_adagrad():
    from repro.optim import sgd

    with pytest.raises(ValueError, match="AdaGrad"):
        FusedServerStep(sgd(0.1), lr=0.1)


def test_empty_round_raises_structured_error_and_traces():
    """A round that closes with zero arrived gradients must NOT step the
    optimizer on a 0/0 mean: the loop raises EmptyRoundError carrying
    the offending RoundResult, leaves its state untouched (retry = call
    run_round again), and drops a round.empty_fold instant on the
    trace so the gap is visible on the timeline."""
    from repro.obs import Tracer

    async def body():
        tr = Tracer()
        fed = make_fed(2, n_shards=4, sizer=FixedSizer(1), tracer=tr)
        tr.clock = fed.queue.clock
        fed.register_task(_lin_grad_task())
        fed.spawn_clients([ClientProfile(name="c0", speed=500.0)])
        opt = adagrad(0.2)
        params = {"w": np.zeros(4, np.float32)}
        state = TrainState(params=params, head={}, head_stale={},
                           opt_state=opt.init(params), head_opt_state={},
                           prev_features=(), prev_labels=(), prev_mask=(),
                           step=np.zeros((), np.int32))
        trainer = FederatedTrainer(fed, timeout=20.0)
        loop = FederatedTrainingLoop(trainer, opt, state)

        async def all_straggled(shard_args, *, shard_work=None,
                                statics=None, timeout=None):
            n = len(shard_args)
            return RoundResult(index=0, results=[None] * n,
                               ticket_ids=list(range(n)), arrived=[],
                               stragglers=list(range(n)))

        trainer.run_round = all_straggled
        async with trainer:
            with pytest.raises(EmptyRoundError) as ei:
                await loop.run_round([(0, 12), (12, 24)], [12.0] * 2)
        await fed.shutdown()
        err = ei.value
        assert err.round_index == 0
        assert err.result.stragglers == [0, 1] and not err.result.arrived
        assert "0 of 2" in str(err)
        # the loop's state is untouched: same round, no loss recorded
        assert loop.round_index == 0 and loop.losses == []
        assert np.array_equal(np.asarray(loop.state.params["w"]),
                              np.zeros(4, np.float32))
        ev = [e for e in tr.events() if e["name"] == "round.empty_fold"]
        assert len(ev) == 1 and ev[0]["args"]["stragglers"] == 2

    _run(body())
