"""Browser-node cache-path tests (the paper's §2.1.2 in-browser LRU GC):
eviction order, read-through hit/miss accounting against the
``download_count`` ledger, and the reload-on-error re-download path."""
from repro.core.distributor import (BrowserNodeBase, ClientProfile,
                                    Distributor, LRUCache, TaskDef)


class Node(BrowserNodeBase):
    """Bare browser-node state, no thread/loop — drives the cache helpers
    deterministically."""

    def __init__(self, distributor, profile):
        self._init_browser(distributor, profile)


def make_node(cache_capacity=16):
    d = Distributor(timeout=2.0, redistribute_min=0.01)
    n = Node(d, ClientProfile(name="node", cache_capacity=cache_capacity))
    return d, n


# --- LRUCache eviction order -------------------------------------------------


def test_lru_eviction_follows_exact_recency_order():
    c = LRUCache(capacity=3)
    for k in ("a", "b", "c"):
        c.put(k, k.upper())
    c.get("a")                     # recency now: b, c, a
    c.put("d", "D")                # evicts b (least recent)
    assert c.get("b") is None
    c.get("c")                     # recency now: a, d, c
    c.put("e", "E")                # evicts a
    assert c.get("a") is None
    assert c.get("c") == "C" and c.get("d") == "D" and c.get("e") == "E"
    assert c.evictions == 2


def test_lru_put_refreshes_recency_not_just_get():
    c = LRUCache(capacity=2)
    c.put("a", 1)
    c.put("b", 2)
    c.put("a", 10)                 # refresh a -> b is now least recent
    c.put("c", 3)                  # evicts b
    assert c.get("b") is None
    assert c.get("a") == 10


def test_lru_zero_capacity_caches_nothing():
    c = LRUCache(capacity=0)
    c.put("a", 1)
    assert c.get("a") is None
    assert c.evictions == 1


# --- read-through _get_task / _get_static vs the download ledger -------------


def test_get_task_read_through_downloads_once():
    d, n = make_node()
    d.register_task(TaskDef("t", lambda x, _: x))
    for _ in range(5):
        assert n._get_task("t").name == "t"
    assert d.download_count["task:t"] == 1          # one miss, four hits
    assert n.cache.hits == 4 and n.cache.misses == 1


def test_get_static_hit_miss_counts_match_download_ledger():
    d, n = make_node()
    d.add_static("ds1", [1])
    d.add_static("ds2", [2])
    task = TaskDef("t", lambda x, _: x, static_files=("ds1", "ds2"))
    d.register_task(task)
    for _ in range(3):
        data = n._get_static(task)
        assert data == {"ds1": [1], "ds2": [2]}
    # each asset crossed the wire exactly once; the other 2 rounds hit
    assert d.download_count["ds1"] == 1
    assert d.download_count["ds2"] == 1
    assert n.cache.misses == 2 and n.cache.hits == 4


def test_get_static_eviction_pressure_redownloads():
    """A cache smaller than the task's working set thrashes: every round
    re-downloads, and the ledger shows it."""
    d, n = make_node(cache_capacity=1)
    d.add_static("big1", "x")
    d.add_static("big2", "y")
    task = TaskDef("t", lambda x, _: x, static_files=("big1", "big2"))
    d.register_task(task)
    for _ in range(3):
        n._get_static(task)
    # capacity 1 can't hold both: big1 evicted by big2 every round
    assert d.download_count["big1"] == 3
    assert d.download_count["big2"] == 3
    assert n.cache.evictions >= 5


# --- reload-on-error: cache cleared, assets re-downloaded --------------------


def test_reload_clears_cache_and_redownloads():
    """Paper: on error the browser reloads itself — the cache empties and
    the next ticket re-fetches code and data from the server."""
    d, n = make_node()
    d.add_static("ds", [1, 2, 3])
    task = TaskDef("t", lambda x, _: x, static_files=("ds",))
    d.register_task(task)
    n._get_task("t")
    n._get_static(task)
    assert d.download_count["task:t"] == 1
    assert d.download_count["ds"] == 1
    n._reload()                    # the error path
    assert n.reloads == 1
    n._get_task("t")
    n._get_static(task)
    assert d.download_count["task:t"] == 2          # re-downloaded
    assert d.download_count["ds"] == 2


def test_reload_on_error_end_to_end_redownload():
    """Integration: a task that fails once forces a reload; the ledger
    shows the static asset downloaded twice by the erroring client."""
    d = Distributor(timeout=2.0, redistribute_min=0.01)
    d.add_static("ds", 7)
    calls = {"n": 0}

    def flaky_once(x, static):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("boom")
        return static["ds"] + x

    d.register_task(TaskDef("flaky", flaky_once, static_files=("ds",)))
    d.queue.add_many("flaky", [1, 2])
    clients = d.spawn_clients([ClientProfile(name="solo")])
    assert d.queue.wait_all(timeout=10)
    d.shutdown()
    res = d.queue.results()
    assert sorted(res.values()) == [8, 9]
    c = clients[0]
    assert c.errors == 1 and c.reloads == 1
    # downloaded once before the error, once after the reload
    assert d.download_count["ds"] == 2
