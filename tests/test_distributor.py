"""Integration tests for the Distributor + simulated browser clients."""
import time

from repro.core.distributor import (BrowserClient, ClientProfile, Distributor,
                                    LRUCache, TaskDef)
from repro.core.project import CalculationFramework, ProjectBase, TaskBase


def make_distributor(**kw):
    kw.setdefault("timeout", 2.0)
    kw.setdefault("redistribute_min", 0.01)
    return Distributor(**kw)


def test_lru_cache_evicts_least_recently_used():
    c = LRUCache(capacity=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1      # a is now most recent
    c.put("c", 3)               # evicts b
    assert c.get("b") is None
    assert c.get("a") == 1
    assert c.get("c") == 3
    assert c.evictions == 1


def test_distributed_execution_collects_all_results():
    d = make_distributor()
    d.register_task(TaskDef("square", lambda x, _: x * x))
    tids = d.queue.add_many("square", list(range(20)))
    d.spawn_clients([ClientProfile(name=f"c{i}") for i in range(3)])
    assert d.queue.wait_all(timeout=10)
    d.shutdown()
    res = d.queue.results()
    assert [res[t] for t in tids] == [i * i for i in range(20)]


def test_fault_tolerance_dead_client_ticket_redistributed():
    """A client that dies after grabbing tickets must not lose work."""
    d = make_distributor()
    d.register_task(TaskDef("slow", lambda x, _: x + 1))
    tids = d.queue.add_many("slow", list(range(10)))
    # one client dies after 2 tickets; a healthy one finishes the rest
    d.spawn_clients([ClientProfile(name="dying", die_after=2),
                     ClientProfile(name="healthy")])
    assert d.queue.wait_all(timeout=10)
    d.shutdown()
    assert len(d.queue.results()) == 10


def test_failing_client_reports_error_and_reloads():
    d = make_distributor()
    d.register_task(TaskDef("flaky", lambda x, _: x))
    d.queue.add_many("flaky", list(range(8)))
    flaky = ClientProfile(name="flaky", fail_prob=0.5)
    clients = d.spawn_clients([flaky, ClientProfile(name="ok")])
    assert d.queue.wait_all(timeout=10)
    d.shutdown()
    console = d.console()
    assert console["executed"] == 8
    # the flaky client reloaded at least once (cleared cache) if it errored
    flaky_client = [c for c in clients if c.profile.name == "flaky"][0]
    assert flaky_client.reloads == flaky_client.errors


def test_static_files_served_and_cached():
    d = make_distributor()
    d.static_store["dataset"] = [1, 2, 3]
    d.register_task(TaskDef("use_data", lambda x, static:
                            static["dataset"][x], static_files=("dataset",)))
    d.queue.add_many("use_data", [0, 1, 2, 0, 1, 2])
    d.spawn_clients([ClientProfile(name="c0")])
    assert d.queue.wait_all(timeout=10)
    d.shutdown()
    # dataset downloaded once (cached thereafter)
    assert d.download_count["dataset"] == 1


# --- the paper's appendix example -------------------------------------------


def _is_prime(n: int) -> bool:
    if n < 2:
        return False
    i = 2
    while i * i <= n:
        if n % i == 0:
            return False
        i += 1
    return True


class IsPrimeTask(TaskBase):
    static_code_files = ("is_prime",)

    def run(self, input, static):  # noqa: A002
        return {"is_prime": static["is_prime"](input["candidate"])}


class PrimeListMakerProject(ProjectBase):
    name = "PrimeListMakerProject"
    limit = 200

    def run(self):
        task = self.create_task(IsPrimeTask)
        task.calculate([{"candidate": i} for i in range(1, self.limit + 1)])
        out = {}

        def cb(results):
            out["primes"] = [i + 1 for i, r in enumerate(results)
                             if r["is_prime"]]

        task.block(cb, timeout=20)
        return out["primes"]


def test_prime_list_maker_project_end_to_end():
    d = make_distributor(project_name="PrimeListMakerProject")
    fw = CalculationFramework(d)
    fw.add_static("is_prime", _is_prime)
    d.spawn_clients([ClientProfile(name=f"browser{i}") for i in range(2)])
    primes = fw.run_project(PrimeListMakerProject)
    d.shutdown()
    assert primes[:8] == [2, 3, 5, 7, 11, 13, 17, 19]
    assert all(_is_prime(p) for p in primes)
    assert len(primes) == 46  # primes <= 200


def test_v1_client_speed_scales_task_duration():
    """profile.speed is a duration multiplier for v1 thread clients: a
    0.25x client takes ~4x the real execution time per ticket (the old
    code slept 0 and ignored speed entirely, so a 'slow' client drained
    the queue as fast as a fast one)."""
    d = make_distributor(timeout=5.0)
    d.register_task(TaskDef("spin", lambda x, _: time.sleep(0.005) or x))
    d.add_work("spin", list(range(4)))
    t0 = time.monotonic()
    d.spawn_clients([ClientProfile(name="slow", speed=0.25)])
    assert d.queue.wait_all(timeout=15)
    elapsed = time.monotonic() - t0
    d.shutdown()
    # 4 tickets x 5 ms real work at 0.25x speed >= 80 ms of simulated
    # time; the ignored-speed path finished in ~20 ms
    assert elapsed >= 0.06, elapsed
