"""Optimizer tests: the paper's modified AdaGrad vs closed form, and
hypothesis properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim import adagrad, adamw, get_optimizer, sgd


def test_adagrad_matches_paper_update_rule():
    """θ_t = θ_{t-1} - α g / sqrt(β + Σ g²) — checked over 3 steps."""
    lr, beta = 0.1, 2.0
    opt = adagrad(lr, beta=beta)
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    s = opt.init(p)
    gs = [jnp.array([0.5, -1.0, 2.0]), jnp.array([1.0, 1.0, -1.0]),
          jnp.array([-0.5, 0.25, 0.0])]
    acc = np.zeros(3)
    theta = np.array([1.0, -2.0, 3.0])
    for g in gs:
        p, s = opt.update({"w": g}, s, p)
        acc += np.asarray(g) ** 2
        theta = theta - lr * np.asarray(g) / np.sqrt(beta + acc)
        np.testing.assert_allclose(np.asarray(p["w"]), theta, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(s["acc"]["w"]), acc, rtol=1e-6)


def test_adagrad_beta_stabilises_first_step():
    """Without β the first step is ±lr regardless of gradient magnitude;
    with β it scales with the gradient (the paper's motivation)."""
    p = {"w": jnp.zeros(1)}
    tiny = {"w": jnp.array([1e-4])}
    opt_nobeta = adagrad(0.1, beta=1e-12)
    opt_beta = adagrad(0.1, beta=1.0)
    p1, _ = opt_nobeta.update(tiny, opt_nobeta.init(p), p)
    p2, _ = opt_beta.update(tiny, opt_beta.init(p), p)
    assert abs(float(p1["w"][0])) == pytest.approx(0.1, rel=1e-3)
    assert abs(float(p2["w"][0])) == pytest.approx(0.1 * 1e-4, rel=1e-3)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-5, 5, allow_nan=False).map(lambda x: x or 0.1),
                min_size=2, max_size=8),
       st.floats(0.1, 10.0))
def test_adagrad_effective_lr_monotonically_decreases(grads, beta):
    """Property: |Δθ|/|g| never increases over steps for a fixed-sign
    gradient stream (accumulator only grows)."""
    opt = adagrad(1.0, beta=beta)
    p = {"w": jnp.zeros(())}
    s = opt.init(p)
    prev_scale = None
    for g in grads:
        g = abs(g) + 0.01
        old = float(p["w"])
        p, s = opt.update({"w": jnp.asarray(g)}, s, p)
        scale = abs(float(p["w"]) - old) / g
        if prev_scale is not None:
            assert scale <= prev_scale * (1 + 1e-3) + 1e-7  # f32 rsqrt noise
        prev_scale = scale


def test_adagrad_kernel_path_matches_pytree_path():
    opt_ref = adagrad(0.05, beta=1.5)
    opt_kern = adagrad(0.05, beta=1.5, use_kernel=True)
    p = {"a": jnp.asarray(np.random.default_rng(0).normal(size=(64, 33)),
                          jnp.float32),
         "b": jnp.asarray(np.random.default_rng(1).normal(size=(17,)),
                          jnp.float32)}
    g = jax.tree_util.tree_map(lambda x: x * 0.3 + 0.1, p)
    s1 = opt_ref.init(p)
    s2 = opt_kern.init(p)
    p1, s1 = opt_ref.update(g, s1, p)
    p2, s2 = opt_kern.update(g, s2, p)
    for k in p:
        np.testing.assert_allclose(np.asarray(p1[k]), np.asarray(p2[k]),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(s1["acc"][k]),
                                   np.asarray(s2["acc"][k]), atol=1e-6)


@pytest.mark.parametrize("name", ["adagrad", "adamw", "sgd"])
def test_optimizers_reduce_quadratic(name):
    opt = get_optimizer(name, 0.5 if name == "adagrad" else 0.1)
    p = {"w": jnp.array([3.0, -2.0])}
    s = opt.init(p)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(p))
    for _ in range(50):
        g = jax.grad(loss)(p)
        p, s = opt.update(g, s, p)
    assert float(loss(p)) < l0 * 0.2


def test_sgd_momentum():
    opt = sgd(0.1, momentum=0.9)
    p = {"w": jnp.array([1.0])}
    s = opt.init(p)
    p1, s = opt.update({"w": jnp.array([1.0])}, s, p)
    p2, s = opt.update({"w": jnp.array([1.0])}, s, p1)
    # second step larger due to momentum
    assert float((p1["w"] - p2["w"])[0]) > float((p["w"] - p1["w"])[0])
