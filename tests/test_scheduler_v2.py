"""Distributor v2 tests: batched leases, adaptive sizing, client-speed
EWMA, proactive release, and the asyncio end-to-end path."""
import asyncio

import pytest

from repro.core.distributor import (AdaptiveSizer, AsyncDistributor,
                                    ClientProfile, FixedSizer, LRUCache,
                                    TaskDef)
from repro.core.split_parallel import (SplitConcurrentDispatcher,
                                       adaptive_shard_sizes)
from repro.core.tickets import ClientStats, TicketQueue


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def make_queue(timeout=300.0, redist=10.0):
    clock = FakeClock()
    q = TicketQueue(timeout=timeout, redistribute_min=redist, clock=clock)
    return q, clock


# --- lease-batch API ----------------------------------------------------


def test_lease_batch_serves_vct_order_up_to_max():
    q, clock = make_queue()
    ids = [q.add("t", i) for i in range(5)]
    batch = q.lease("c1", 3)
    assert batch.ticket_ids == ids[:3]
    assert batch.client == "c1"
    batch2 = q.lease("c1", 3)
    assert batch2.ticket_ids == ids[3:]


def test_lease_respects_redistribute_min_throttle():
    """No ticket is re-leased within redistribute_min of its last
    distribution, even across differently-sized lease requests."""
    q, clock = make_queue(redist=10.0)
    q.add_many("t", [0, 1])
    assert q.lease("c1", 8).ticket_ids == [0, 1]
    clock.advance(9.9)
    assert q.lease("c2", 8) is None          # still inside the cool-down
    clock.advance(0.2)
    again = q.lease("c2", 8)                 # eligible again
    assert again is not None and again.ticket_ids == [0, 1]


def test_duplicate_batch_results_dropped_first_wins():
    q, clock = make_queue(redist=0.0)
    q.add_many("t", ["a", "b"])
    b1 = q.lease("c1", 2)
    b2 = q.lease("c2", 2)                    # redistribution (redist=0)
    assert b1.ticket_ids == b2.ticket_ids
    assert q.submit_batch(b1.lease_id, {0: "r1", 1: "r1"}, "c1") == 2
    assert q.submit_batch(b2.lease_id, {0: "r2", 1: "r2"}, "c2") == 0
    assert q.results() == {0: "r1", 1: "r1"}
    assert all(t.completed_by == "c1" for t in q._tickets.values())


def test_client_dies_mid_lease_release_makes_tickets_fresh():
    """Releasing a lease must return its unfinished tickets with
    freshly-created VCT so another client picks them up immediately —
    not after the five-minute timeout."""
    q, clock = make_queue(timeout=300.0, redist=10.0)
    q.add_many("t", [0, 1, 2, 3])
    batch = q.lease("dying", 4)
    clock.advance(1.0)
    # partial progress: ticket 0 landed before the tab closed
    q.submit_batch(batch.lease_id, {0: "ok"}, "dying")
    assert q.release(batch.lease_id, client_failed=True) == 3
    # released tickets are immediately eligible despite redistribute_min
    rescue = q.lease("healthy", 8)
    assert rescue is not None
    assert sorted(rescue.ticket_ids) == [1, 2, 3]
    assert q.stats["dying"].failures == 1
    assert q.snapshot()["lease_releases"] == 1


def test_released_tickets_sort_as_freshly_created():
    q, clock = make_queue(timeout=300.0, redist=10.0)
    a = q.add("t", "a")
    clock.advance(1.0)
    b = q.add("t", "b")
    batch = q.lease("c1", 1)                 # takes a
    assert batch.ticket_ids == [a]
    q.release(batch.lease_id)
    # a's VCT resets to its creation time (0.0) < b's (1.0) -> a first
    again = q.lease("c2", 2)
    assert again.ticket_ids == [a, b]


def test_ewma_rate_tracks_completed_work_per_second():
    q, clock = make_queue(redist=0.0)
    q.add_many("t", list(range(4)), work=1.0)
    b = q.lease("c1", 2)
    clock.advance(0.5)                       # 2 units in 0.5 s -> 4/s
    q.submit_batch(b.lease_id, {t: "r" for t in b.ticket_ids}, "c1")
    assert q.stats["c1"].rate == pytest.approx(4.0)
    b2 = q.lease("c1", 2)
    clock.advance(2.0)                       # 2 units in 2 s -> 1/s sample
    q.submit_batch(b2.lease_id, {t: "r" for t in b2.ticket_ids}, "c1")
    # EWMA(alpha=0.3): 0.3*1 + 0.7*4 = 3.1
    assert q.stats["c1"].rate == pytest.approx(3.1)


def test_client_stats_observe_ewma():
    s = ClientStats("c", alpha=0.5)
    s.observe(10.0, 1.0)
    assert s.rate == pytest.approx(10.0)
    s.observe(2.0, 1.0)
    assert s.rate == pytest.approx(6.0)
    assert s.completed_work == 12.0


def test_completed_tickets_counts_tickets_not_leases():
    q, clock = make_queue(redist=0.0)
    q.add_many("t", list(range(4)))
    b = q.lease("c1", 4)
    clock.advance(1.0)
    q.submit_batch(b.lease_id, {t: "r" for t in b.ticket_ids}, "c1")
    assert q.stats["c1"].completed_tickets == 4
    assert q.snapshot()["clients"]["c1"]["completed"] == 4


def test_stale_lease_gcd_when_competing_lease_wins():
    """A ticket completed via a redistributed lease must also be dropped
    from the older lease's outstanding set, so the watchdog never
    'releases' a lease whose tickets are all done."""
    q, clock = make_queue(redist=0.0)
    q.add("t", "x")
    a = q.lease("A", 1)
    b = q.lease("B", 1)                      # redistribution of the same ticket
    q.submit_batch(b.lease_id, {0: "rB"}, "B")   # B wins
    assert q.outstanding_leases() == []      # A's stale lease GC'd too
    assert q.submit_batch(a.lease_id, {0: "rA"}, "A") == 0
    assert q.stats.get("A") is None or q.stats["A"].failures == 0


def test_release_without_reset_keeps_cooldown():
    """The error-retry path must keep the paper's redistribute_min
    cool-down so a deterministically failing task can't hot-loop."""
    q, clock = make_queue(redist=10.0)
    q.add("t", 0)
    b = q.lease("c", 1)
    clock.advance(1.0)
    q.release(b.lease_id, reset_vct=False)
    assert q.lease("c2", 1) is None          # cool-down still applies
    clock.advance(9.5)
    assert q.lease("c2", 1) is not None


def test_release_skips_tickets_re_leased_to_another_client():
    """A stale lease release must not clobber a ticket an active newer
    lease owns (no triple-distribution stampede)."""
    q, clock = make_queue(redist=0.0)
    q.add("t", 0)
    a = q.lease("A", 1)
    clock.advance(1.0)
    b = q.lease("B", 1)                      # redistributed to B
    assert q.release(a.lease_id) == 0        # nothing actually returned
    t = q._tickets[0]
    assert t.lease_id == b.lease_id          # B still owns it
    assert t.last_distributed_at == 1.0      # VCT untouched


def test_late_submit_after_release_still_calibrates_ewma():
    """A slower-than-expected client whose lease was watchdog-released
    must still get an EWMA sample from its late submit — otherwise it
    re-probes forever."""
    q, clock = make_queue(redist=0.0)
    q.add_many("t", [0, 1], work=8.0)
    b = q.lease("slow", 2)
    q.release(b.lease_id, client_failed=True)     # watchdog fired early
    clock.advance(2.0)
    assert q.submit_batch(b.lease_id, {0: "r", 1: "r"}, "slow") == 2
    assert q.stats["slow"].rate == pytest.approx(16.0 / 2.0)
    assert q.stats["slow"].mean_ticket_work == pytest.approx(8.0)


def test_prune_forgets_completed_rounds():
    q, clock = make_queue(redist=0.0)
    tids = q.add_many("t", [0, 1, 2])
    b = q.lease("c", 3)
    q.submit_batch(b.lease_id, {t: "r" for t in tids}, "c")
    assert q.prune(tids) == 3
    assert q.results() == {}
    assert q.snapshot()["tickets"] == 0
    assert q.all_done()


# --- sizing policies ------------------------------------------------------


def test_adaptive_sizer_scales_with_rate_and_clamps():
    sizer = AdaptiveSizer(target_lease_time=0.5, min_size=1, max_size=16,
                          probe_size=2)
    assert sizer.lease_size(None) == 2                       # probe
    assert sizer.lease_size(ClientStats("c", rate=8.0)) == 4
    assert sizer.lease_size(ClientStats("c", rate=0.1)) == 1   # clamp low
    assert sizer.lease_size(ClientStats("c", rate=1000.0)) == 16  # clamp high


def test_fixed_sizer_ignores_stats():
    sizer = FixedSizer(3)
    assert sizer.lease_size(None) == 3
    assert sizer.lease_size(ClientStats("c", rate=99.0)) == 3


def test_adaptive_sizer_converts_work_rate_to_ticket_count():
    """rate is work-units/s; heavy tickets must yield smaller leases and
    a correspondingly longer ETA."""
    stats = ClientStats("c", rate=80.0)
    stats.completed_work, stats.completed_tickets = 80.0, 10   # 8 work/ticket
    sizer = AdaptiveSizer(target_lease_time=0.5, max_size=64)
    assert sizer.lease_size(stats) == 5          # 80 * 0.5 / 8
    assert sizer.expected_duration(stats, 5) == pytest.approx(0.5)


# --- LRU cache counters ---------------------------------------------------


def test_lru_eviction_and_hit_miss_counters():
    c = LRUCache(capacity=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1          # hit; a most-recent
    c.put("c", 3)                   # evicts b
    assert c.get("b") is None       # miss
    c.put("d", 4)                   # evicts a (c was more recent? no: order a,c -> evicts a)
    assert c.get("a") is None       # miss
    assert c.get("c") == 3
    assert c.get("d") == 4
    assert c.evictions == 2
    assert c.hits == 3
    assert c.misses == 2


# --- asyncio end-to-end -----------------------------------------------------


def _run(coro):
    return asyncio.run(coro)


def test_async_distributor_end_to_end_heterogeneous():
    """Bimodal clients drain the queue; the fast client ends up with a
    higher measured rate and (eventually) bigger leases."""

    async def main():
        d = AsyncDistributor(timeout=5.0, redistribute_min=0.02,
                             sizer=AdaptiveSizer(target_lease_time=0.02,
                                                 max_size=16),
                             watchdog_interval=0.005)
        d.register_task(TaskDef("square", lambda x, _: x * x))
        d.add_work("square", list(range(40)), work=1.0)
        d.spawn_clients([
            ClientProfile(name="fast", speed=4000.0),
            ClientProfile(name="slow", speed=500.0),
        ])
        assert await d.run_until_done(timeout=30.0)
        res = d.queue.results()
        assert sorted(res) == list(range(40))
        assert all(res[i] == i * i for i in range(40))
        fast = d.queue.stats["fast"]
        slow = d.queue.stats["slow"]
        assert fast.rate > slow.rate
        return d

    d = _run(main())
    snap = d.console()
    assert snap["executed"] == 40


def test_async_client_death_mid_lease_work_recovered():
    """A v2 client that dies holding a lease must not strand its tickets:
    the release path (plus the watchdog) hands them to survivors."""

    async def main():
        d = AsyncDistributor(timeout=5.0, redistribute_min=0.02,
                             sizer=AdaptiveSizer(target_lease_time=0.02,
                                                 max_size=8),
                             watchdog_interval=0.005)
        d.register_task(TaskDef("inc", lambda x, _: x + 1))
        d.add_work("inc", list(range(30)))
        d.spawn_clients([
            ClientProfile(name="dying", speed=2000.0, die_after=1),
            ClientProfile(name="healthy", speed=2000.0),
        ])
        assert await d.run_until_done(timeout=30.0)
        return d

    d = _run(main())
    res = d.queue.results()
    assert len(res) == 30
    assert all(res[i] == i + 1 for i in range(30))
    # the dying client released at least one lease back
    assert d.queue.snapshot()["lease_releases"] >= 1


def test_async_flaky_client_errors_reported_and_cache_reloaded():
    async def main():
        d = AsyncDistributor(timeout=5.0, redistribute_min=0.02,
                             watchdog_interval=0.005)
        d.register_task(TaskDef("echo", lambda x, _: x))
        d.add_work("echo", list(range(20)))
        clients = d.spawn_clients([
            ClientProfile(name="flaky", speed=2000.0, fail_prob=0.4),
            ClientProfile(name="ok", speed=2000.0),
        ])
        assert await d.run_until_done(timeout=30.0)
        return d, clients

    d, clients = _run(main())
    assert len(d.queue.results()) == 20
    flaky = [c for c in clients if c.profile.name == "flaky"][0]
    assert flaky.reloads == flaky.errors


def test_async_static_files_cached_once_per_client():
    async def main():
        d = AsyncDistributor(timeout=5.0, redistribute_min=0.02)
        d.add_static("dataset", [1, 2, 3])
        d.register_task(TaskDef("use", lambda x, s: s["dataset"][x],
                                static_files=("dataset",)))
        d.add_work("use", [0, 1, 2, 0, 1, 2])
        d.spawn_clients([ClientProfile(name="c0", speed=2000.0)])
        assert await d.run_until_done(timeout=30.0)
        return d

    d = _run(main())
    assert d.download_count["dataset"] == 1


def test_watchdog_rearmed_after_round_drains():
    """A non-keep_alive distributor's watchdog self-terminates when a round
    drains; spawning clients for a second round must arm a fresh one."""

    async def main():
        d = AsyncDistributor(timeout=5.0, redistribute_min=0.02,
                             watchdog_interval=0.005)
        d.register_task(TaskDef("echo", lambda x, _: x))
        d.add_work("echo", [1, 2])
        d.spawn_clients([ClientProfile(name="c0", speed=2000.0)])
        # drain WITHOUT run_until_done/shutdown: the watchdog task
        # self-terminates but stays bound (done, not None)
        while not d.queue.all_done():
            await asyncio.sleep(0.005)
        for _ in range(200):
            if d._watchdog_task.done():
                break
            await asyncio.sleep(0.005)
        assert d._watchdog_task.done()
        d.add_work("echo", [3, 4])
        d.spawn_clients([ClientProfile(name="c1", speed=2000.0)])
        assert not d._watchdog_task.done()      # fresh watchdog armed
        assert await d.run_until_done(timeout=30.0)
        return d

    d = _run(main())
    assert len(d.queue.results()) == 4


# --- split_parallel wiring ---------------------------------------------------


def test_adaptive_shard_sizes_proportional_and_exact():
    sizes = adaptive_shard_sizes({"fast": 30.0, "slow": 10.0}, 8)
    assert sizes == {"fast": 6, "slow": 2}
    assert sum(sizes.values()) == 8


def test_adaptive_shard_sizes_unknown_clients_get_mean_share():
    sizes = adaptive_shard_sizes({"a": 20.0, "b": None, "c": 20.0}, 12)
    assert sum(sizes.values()) == 12
    assert sizes["b"] >= 1           # newcomer not starved
    assert sizes["a"] == sizes["c"]


def test_adaptive_shard_sizes_min_shard_floor():
    sizes = adaptive_shard_sizes({"fast": 1000.0, "slow": 1.0}, 10,
                                 min_shard=1)
    assert sizes["slow"] >= 1
    assert sum(sizes.values()) == 10


def test_adaptive_shard_sizes_batch_smaller_than_floor_terminates():
    """global_batch < len(rates) * min_shard must not hang: the floor is
    dropped and some clients get zero."""
    sizes = adaptive_shard_sizes({"a": 1.0, "b": 1.0, "c": 1.0}, 2)
    assert sum(sizes.values()) == 2
    assert all(v >= 0 for v in sizes.values())


def test_split_dispatcher_round_aggregates_in_order():
    """One §4.1 training round through the v2 scheduler: backbone shard
    'gradients' come back ordered like the inputs."""

    async def main():
        d = AsyncDistributor(timeout=5.0, redistribute_min=0.02,
                             sizer=AdaptiveSizer(target_lease_time=0.02))
        d.register_task(TaskDef(
            "backbone_shard", lambda args, _: {"grad": args["lo"]}))
        d.spawn_clients([ClientProfile(name="c0", speed=2000.0),
                         ClientProfile(name="c1", speed=2000.0)])
        disp = SplitConcurrentDispatcher(d)
        shards = [{"lo": i, "hi": i + 4} for i in range(0, 16, 4)]
        out = await disp.run_round(shards, shard_work=[4.0] * 4,
                                   timeout=30.0)
        await d.shutdown()
        return out, disp

    out, disp = _run(main())
    assert [o["grad"] for o in out] == [0, 4, 8, 12]
    assert disp.rounds == 1


def test_split_dispatcher_multiple_rounds_reuse_clients():
    """Clients must survive a drained queue between training steps
    (keep_alive): round N+1 reuses the same client pool."""

    async def main():
        d = AsyncDistributor(timeout=5.0, redistribute_min=0.02,
                             sizer=AdaptiveSizer(target_lease_time=0.02))
        d.register_task(TaskDef("backbone_shard",
                                lambda args, _: args["step"] * 100 + args["i"]))
        d.spawn_clients([ClientProfile(name="c0", speed=2000.0),
                         ClientProfile(name="c1", speed=2000.0)])
        disp = SplitConcurrentDispatcher(d)
        outs = []
        for step in range(3):
            shards = [{"step": step, "i": i} for i in range(4)]
            outs.append(await disp.run_round(shards, timeout=30.0))
        await d.shutdown()
        return outs, disp

    outs, disp = _run(main())
    assert disp.rounds == 3
    for step, out in enumerate(outs):
        assert out == [step * 100 + i for i in range(4)]


def test_split_dispatcher_weighted_aggregate():
    grads = [{"w": 1.0}, {"w": 3.0}]
    agg = SplitConcurrentDispatcher.aggregate(grads, [1.0, 3.0])
    # (1*1 + 3*3) / 4 = 2.5
    assert agg["w"] == pytest.approx(2.5)


def test_run_until_done_deadline_follows_injected_clock():
    """The drain loop's deadline is measured on the queue's injectable
    clock: with a frozen virtual clock and a microscopic timeout, the run
    must still complete (the old code raced WALL time and bailed out
    False before the clients could finish)."""

    async def main():
        clock = FakeClock()          # frozen at 0.0 throughout
        d = AsyncDistributor(timeout=5.0, redistribute_min=0.02,
                             clock=clock, watchdog_interval=0.005)
        d.register_task(TaskDef("echo", lambda x, _: x))
        d.add_work("echo", [1, 2, 3])
        d.spawn_clients([ClientProfile(name="c0", speed=2000.0)])
        assert await d.run_until_done(timeout=1e-6)
        return d

    d = _run(main())
    assert len(d.queue.results()) == 3


def test_run_until_done_times_out_in_virtual_seconds():
    """Conversely, advancing the virtual clock past the deadline times
    the run out even though almost no wall time passed."""

    async def main():
        clock = FakeClock()
        d = AsyncDistributor(timeout=5.0, redistribute_min=0.02,
                             clock=clock, watchdog_interval=0.005)
        d.register_task(TaskDef("echo", lambda x, _: x))
        d.add_work("echo", [1, 2, 3])
        # no clients: the queue can never drain
        runner = asyncio.ensure_future(d.run_until_done(timeout=1.0))
        await asyncio.sleep(0.02)
        clock.advance(10.0)          # virtual time blows the 1.0s budget
        return await runner

    assert _run(main()) is False
