"""Sharded ticket store tests: per-task partitioning, the global min-VCT
merge (property-tested against a single TicketQueue), cross-shard leases,
and the once-globally client-stats bookkeeping."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.shards import ShardedTicketQueue, shard_index
from repro.core.tickets import TicketQueue


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def make_pair(n_shards=3, timeout=300.0, redist=10.0):
    """A single queue and a sharded queue on separate but identically
    advanced clocks, for lock-step order-parity checks."""
    c1, c2 = FakeClock(), FakeClock()
    single = TicketQueue(timeout=timeout, redistribute_min=redist, clock=c1)
    sharded = ShardedTicketQueue(n_shards, timeout=timeout,
                                 redistribute_min=redist, clock=c2)

    class Both:
        def advance(self, dt):
            c1.advance(dt)
            c2.advance(dt)

    return single, sharded, Both()


def make_sharded(n_shards=3, timeout=300.0, redist=10.0):
    clock = FakeClock()
    q = ShardedTicketQueue(n_shards, timeout=timeout,
                           redistribute_min=redist, clock=clock)
    return q, clock


def distinct_shard_tasks(n_tasks, n_shards):
    """``n_tasks`` task names guaranteed to land on pairwise-distinct
    shards (crc32 placement is name-dependent, so probe for them)."""
    names, used = [], set()
    i = 0
    while len(names) < n_tasks:
        idx = shard_index(f"task{i}", n_shards)
        if idx not in used:
            used.add(idx)
            names.append(f"task{i}")
        i += 1
    return names


# --- partitioning / ids ----------------------------------------------------


def test_shard_index_stable_and_in_range():
    for n in (1, 2, 5):
        for name in ("alpha", "beta", "backbone_shard", ""):
            i = shard_index(name, n)
            assert 0 <= i < n
            assert i == shard_index(name, n)     # deterministic


def test_tickets_partition_by_task_single_shard_per_task():
    q, clock = make_sharded(n_shards=4)
    tids = q.add_many("taskA", [0, 1, 2])
    sh = q.shard_for("taskA")
    assert all(tid in sh._tickets for tid in tids)
    others = [s for s in q.shards if s is not sh]
    assert all(not s._tickets for s in others)


def test_ticket_ids_globally_unique_and_arrival_ordered():
    q, clock = make_sharded(n_shards=3)
    tids = []
    for i in range(9):
        tids.append(q.add(f"task{i % 3}", i))
    assert tids == list(range(9))        # one shared id stream


def test_add_many_batch_shares_one_creation_time():
    """The bulk insert reads the clock once under one lock acquisition —
    the whole batch lands atomically with identical created_at."""
    q = TicketQueue(clock=FakeClock())
    tids = q.add_many("t", list(range(5)), work=[1, 2, 3, 4, 5])
    created = {q._tickets[t].created_at for t in tids}
    assert len(created) == 1
    assert [q._tickets[t].work for t in tids] == [1, 2, 3, 4, 5]


# --- global min-VCT merge --------------------------------------------------


def test_lease_merges_across_shards_in_global_vct_order():
    q, clock = make_sharded(n_shards=3)
    order = []
    for i in range(6):
        task = f"task{i % 3}"
        order.append(q.add(task, (task, i)))
        clock.advance(0.1)               # strictly increasing created_at
    batch = q.lease("c", 6)
    assert batch.ticket_ids == order     # interleaved across shards


def test_lease_respects_cooldown_across_shards():
    q, clock = make_sharded(n_shards=2, redist=10.0)
    ta, tb = distinct_shard_tasks(2, 2)
    q.add(ta, 0)
    q.add(tb, 1)
    first = q.lease("c1", 8)
    assert len(first.ticket_ids) == 2
    clock.advance(9.9)
    assert q.lease("c2", 8) is None      # both shards still cooling down
    clock.advance(0.2)
    assert len(q.lease("c2", 8).ticket_ids) == 2


def test_lease_shards_hint_restricts_merge():
    """A member's home-shard lease must never see foreign shards' work."""
    q, clock = make_sharded(n_shards=2)
    ta, tb = distinct_shard_tasks(2, 2)
    q.add(ta, "a")
    q.add(tb, "b")
    batch = q.lease("c", 8, shards=[q.shard_for(ta)])
    assert [t.args for t in batch.tickets] == ["a"]
    other = q.lease("c", 8, shards=[q.shard_for(tb)])
    assert [t.args for t in other.tickets] == ["b"]


def test_cross_shard_lease_single_id_and_routing_submit():
    q, clock = make_sharded(n_shards=2)
    ta, tb = distinct_shard_tasks(2, 2)
    q.add(ta, "a", work=3.0)
    q.add(tb, "b", work=5.0)
    batch = q.lease("c", 2)
    assert len(batch.tickets) == 2
    # both shards track the SAME lease id
    assert sum(sh.lease_is_outstanding(batch.lease_id)
               for sh in q.shards) == 2
    clock.advance(2.0)
    assert q.submit_batch(batch.lease_id,
                          {t: "r" for t in batch.ticket_ids}, "c") == 2
    assert q.all_done()
    # EWMA observed ONCE globally: (3+5) work over 2 s -> 4/s
    assert q.stats["c"].rate == pytest.approx(4.0)
    assert q.stats["c"].leases == 1
    assert q.stats["c"].completed_tickets == 2
    # drained lease GC'd from the global table
    assert q.outstanding_leases() == []


def test_cross_shard_release_returns_all_unfinished():
    q, clock = make_sharded(n_shards=2, redist=10.0)
    ta, tb = distinct_shard_tasks(2, 2)
    q.add(ta, "a")
    q.add(tb, "b")
    batch = q.lease("dying", 2)
    assert q.release(batch.lease_id, client_failed=True) == 2
    # released tickets immediately eligible again despite the cool-down
    rescue = q.lease("healthy", 8)
    assert len(rescue.ticket_ids) == 2
    # failure + release booked once globally, not once per shard
    assert q.stats["dying"].failures == 1
    assert q.snapshot()["lease_releases"] == 1


def test_late_submit_after_cross_shard_release_calibrates_ewma():
    q, clock = make_sharded(n_shards=2, redist=0.0)
    ta, tb = distinct_shard_tasks(2, 2)
    q.add(ta, "a", work=4.0)
    q.add(tb, "b", work=4.0)
    b = q.lease("slow", 2)
    q.release(b.lease_id, client_failed=True)
    clock.advance(2.0)
    assert q.submit_batch(b.lease_id,
                          {t: "r" for t in b.ticket_ids}, "slow") == 2
    assert q.stats["slow"].rate == pytest.approx(8.0 / 2.0)


def test_duplicate_cross_shard_results_dropped_first_wins():
    q, clock = make_sharded(n_shards=2, redist=0.0)
    ta, tb = distinct_shard_tasks(2, 2)
    q.add(ta, "a")
    q.add(tb, "b")
    b1 = q.lease("c1", 2)
    b2 = q.lease("c2", 2)
    assert sorted(b1.ticket_ids) == sorted(b2.ticket_ids)
    assert q.submit_batch(b1.lease_id,
                          {t: "r1" for t in b1.ticket_ids}, "c1") == 2
    assert q.submit_batch(b2.lease_id,
                          {t: "r2" for t in b2.ticket_ids}, "c2") == 0
    assert set(q.results().values()) == {"r1"}


def test_v1_request_serves_global_min_and_submit_routes():
    q, clock = make_sharded(n_shards=3)
    order = []
    for i in range(4):
        order.append(q.add(f"task{i % 3}", i))
        clock.advance(0.1)
    served = [q.request().ticket_id for _ in range(4)]
    assert served == order
    for tid in order:
        assert q.submit(tid, tid * 2, "c")
    assert q.all_done()
    assert q.results() == {tid: tid * 2 for tid in order}


def test_results_for_prune_and_snapshot():
    q, clock = make_sharded(n_shards=2, redist=0.0)
    ta, tb = distinct_shard_tasks(2, 2)
    tids = q.add_many(ta, [0, 1]) + q.add_many(tb, [2])
    assert q.results_for(tids) is None
    b = q.lease("c", 3)
    q.submit_batch(b.lease_id, {t: t * 10 for t in b.ticket_ids}, "c")
    assert q.results_for(tids) == [0, 10, 20]
    snap = q.snapshot()
    assert snap["executed"] == 3 and snap["tickets"] == 3
    assert len(snap["shards"]) == 2
    assert q.prune(tids) == 3
    assert q.snapshot()["tickets"] == 0
    assert q.results_for(tids) is None   # pruned ids are unknown now


def test_seconds_until_eligible_min_over_shards():
    q, clock = make_sharded(n_shards=2, redist=10.0)
    ta, tb = distinct_shard_tasks(2, 2)
    q.add(ta, 0)
    q.add(tb, 1)
    assert q.lease("c", 8) is not None
    clock.advance(4.0)
    assert q.seconds_until_eligible() == pytest.approx(6.0)
    clock.advance(7.0)
    assert q.seconds_until_eligible() == 0.0


def test_report_error_routes_to_owning_shard():
    q, clock = make_sharded(n_shards=2)
    tid = q.add("taskA", 0)
    q.request()
    q.report_error(tid, "Traceback ...", "c")
    assert q.snapshot()["errors"] == 1


# --- order-parity property test (acceptance criterion) ----------------------


def _drain_parity(single, sharded, both, handed_single, handed_sharded,
                  lease_sizes):
    """Drive both queues to empty, recording hand-out order from each."""
    guard = 0
    sizes = list(lease_sizes) or [1]
    while not single.all_done() or not sharded.all_done():
        guard += 1
        assert guard < 10000
        k = sizes[guard % len(sizes)]
        b1 = single.lease("c", k)
        b2 = sharded.lease("c", k)
        assert (b1 is None) == (b2 is None)
        if b1 is None:
            both.advance(max(single.redistribute_min, 1.0))
            continue
        handed_single.extend(b1.ticket_ids)
        handed_sharded.extend(b2.ticket_ids)
        single.submit_batch(b1.lease_id,
                            {t: "r" for t in b1.ticket_ids}, "c")
        sharded.submit_batch(b2.lease_id,
                             {t: "r" for t in b2.ticket_ids}, "c")


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 99), min_size=1, max_size=60),
       st.integers(2, 5))
def test_sharded_handout_order_matches_single_queue_vct_order(
        ops, n_shards):
    """THE federation invariant: on any interleaved multi-task workload,
    the sharded store hands tickets out in exactly the order a single
    §2.1.2 TicketQueue would — the queue-of-queues merge preserves the
    paper's global ascending-VCT rule (including redistribution, releases,
    and cool-downs)."""
    single, sharded, both = make_pair(n_shards=n_shards, timeout=30.0,
                                      redist=5.0)
    handed_single: list = []
    handed_sharded: list = []
    open_leases: list = []               # [(single_lease_id, sharded_lease_id)]
    serial = 0
    for op in ops:
        kind = op % 5
        if kind in (0, 1):               # add a ticket to one of 3 tasks
            task = f"task{op % 3}"
            t1 = single.add(task, serial)
            t2 = sharded.add(task, serial)
            assert t1 == t2              # shared-arrival-order id streams
            serial += 1
            both.advance(0.01)
        elif kind == 2:                  # lease k; submit or hold
            k = 1 + op % 4
            b1 = single.lease("c", k)
            b2 = sharded.lease("c", k)
            assert (b1 is None) == (b2 is None)
            if b1 is None:
                continue
            handed_single.extend(b1.ticket_ids)
            handed_sharded.extend(b2.ticket_ids)
            if op % 2:                   # submit results
                single.submit_batch(
                    b1.lease_id, {t: "r" for t in b1.ticket_ids}, "c")
                sharded.submit_batch(
                    b2.lease_id, {t: "r" for t in b2.ticket_ids}, "c")
            else:                        # client vanishes with the lease
                open_leases.append((b1.lease_id, b2.lease_id))
        elif kind == 3 and open_leases:  # watchdog releases a held lease
            l1, l2 = open_leases.pop(op % len(open_leases))
            single.release(l1, client_failed=True)
            sharded.release(l2, client_failed=True)
        else:                            # time passes (cool-down / timeout)
            both.advance([0.5, 3.0, 6.0, 31.0][op % 4])
    _drain_parity(single, sharded, both, handed_single, handed_sharded,
                  lease_sizes=[1 + op % 4 for op in ops[:5]])
    assert handed_single == handed_sharded
    assert sorted(set(handed_single)) == list(range(serial))


class CountingLock:
    """Context-manager lock proxy that counts acquisitions."""

    def __init__(self, inner):
        self.inner = inner
        self.acquires = 0

    def __enter__(self):
        self.acquires += 1
        return self.inner.__enter__()

    def __exit__(self, *exc):
        return self.inner.__exit__(*exc)


def test_prune_batches_routing_cleanup_into_bounded_lock_traffic():
    """Pruning N tickets must touch the store's _meta_lock a constant
    number of times (route + cleanup), not once per ticket."""
    q, clock = make_sharded(n_shards=3)
    tasks = distinct_shard_tasks(2, 3)
    tids = []
    for task in tasks:
        tids.extend(q.add_many(task, list(range(25))))
    batch = q.lease("c", len(tids))
    q.submit_batch(batch.lease_id, {t: t for t in batch.ticket_ids}, "c")
    keep = q.add_many(tasks[0], ["unfinished"])   # must survive the prune

    counting = CountingLock(q._meta_lock)
    q._meta_lock = counting
    assert q.prune(tids + keep) == len(tids)      # keep is incomplete
    assert counting.acquires <= 3
    # routing for pruned ids is gone; the unfinished ticket still routes
    assert all(t not in q._ticket_shard for t in tids)
    assert keep[0] in q._ticket_shard
    assert q.results_for(keep) is None
