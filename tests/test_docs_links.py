"""Docs stay true: intra-repo markdown links resolve, and the wire spec
(docs/PROTOCOL.md) covers every message type the transport actually
speaks.  CI runs the same link checker in its docs job; this test keeps
it in the tier-1 loop too."""
import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_intra_repo_markdown_links_resolve():
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "check_md_links.py"),
         REPO],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_protocol_doc_covers_every_wire_message_type():
    src = open(os.path.join(REPO, "src", "repro", "core",
                            "transport.py")).read()
    spec = open(os.path.join(REPO, "docs", "PROTOCOL.md")).read()
    emitted = set(re.findall(r"[\"']type[\"']\s*(?:==|:)\s*[\"'](\w+)[\"']",
                             src))
    # comparisons like msg["type"] != "hello" are still message types
    emitted |= set(re.findall(r"\[[\"']type[\"']\]\s*[!=]=\s*[\"'](\w+)[\"']",
                              src))
    assert emitted, "no message types found in transport.py (regex rot?)"
    # the churn-control messages must be present, not just the legacy set
    assert {"heartbeat", "heartbeat_ok", "busy"} <= emitted
    undocumented = {t for t in emitted if f"`{t}`" not in spec}
    assert not undocumented, (
        f"message types missing from docs/PROTOCOL.md: {undocumented}")


def test_protocol_doc_covers_every_error_code():
    """Every ProtocolError code raised anywhere in the wire stack must
    appear (backtick-quoted) in the spec's error table — a new frame
    type or decoder cannot ship an undocumented failure mode."""
    spec = open(os.path.join(REPO, "docs", "PROTOCOL.md")).read()
    raised = set()
    for mod in ("transport.py", "wire.py", "tickets.py"):
        src = open(os.path.join(REPO, "src", "repro", "core", mod)).read()
        raised |= set(re.findall(r"ProtocolError\(\s*[\"']([a-z-]+)[\"']",
                                 src))
    assert raised, "no ProtocolError codes found in source (regex rot?)"
    # the v2 machinery must be present, not just legacy codes
    assert {"bad-manifest", "bad-blob", "blob-too-large",
            "unexpected-chunk", "chunk-mismatch"} <= raised
    undocumented = {c for c in raised if f"`{c}`" not in spec}
    assert not undocumented, (
        f"error codes missing from docs/PROTOCOL.md: {undocumented}")


def test_protocol_doc_version_matches_code():
    from repro.core.transport import PROTOCOL_VERSION
    spec = open(os.path.join(REPO, "docs", "PROTOCOL.md")).read()
    m = re.search(r"Current protocol version: \*\*(\d+)\*\*", spec)
    assert m, "PROTOCOL.md must state the current protocol version"
    assert int(m.group(1)) == PROTOCOL_VERSION
