"""Deterministic stand-in for the tiny slice of `hypothesis` this repo uses.

The container image does not ship `hypothesis` and installing packages is
off-limits, so ``conftest.py`` registers this module under the names
``hypothesis`` / ``hypothesis.strategies`` / ``hypothesis.extra.numpy``
when the real library is missing.  It is NOT a property-testing engine:
there is no shrinking and no example database.  Each ``@given`` test is
simply run ``max_examples`` times with values drawn from a per-test
seeded PRNG, so failures are reproducible run-to-run.

Supported API (exactly what the test-suite imports):

  * ``given``, ``settings(max_examples=..., deadline=...)``
  * ``strategies.integers / floats / lists / sampled_from / binary /
    one_of / tuples`` with ``.filter`` and ``.map``
  * ``extra.numpy.arrays(dtype=..., shape=...)`` and ``array_shapes``
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib

import numpy as np


class Strategy:
    """A value generator: ``draw(rng) -> value``, composable like hypothesis
    strategies via ``.filter`` and ``.map``."""

    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)

    def filter(self, predicate) -> "Strategy":
        def draw(rng):
            for _ in range(10_000):
                value = self._draw(rng)
                if predicate(value):
                    return value
            raise ValueError("hypothesis shim: filter predicate rejected "
                             "10000 consecutive draws")
        return Strategy(draw)

    def map(self, fn) -> "Strategy":
        return Strategy(lambda rng: fn(self._draw(rng)))


def _as_strategy(value) -> Strategy:
    return value if isinstance(value, Strategy) else Strategy(lambda rng: value)


# --- strategies ------------------------------------------------------------


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float, *, allow_nan: bool = False,
           allow_infinity: bool = False) -> Strategy:
    del allow_nan, allow_infinity  # bounded draws are always finite
    return Strategy(lambda rng: rng.uniform(min_value, max_value))


def sampled_from(elements) -> Strategy:
    pool = list(elements)
    return Strategy(lambda rng: pool[rng.randrange(len(pool))])


def lists(elements: Strategy, *, min_size: int = 0,
          max_size: int = 10) -> Strategy:
    elements = _as_strategy(elements)
    return Strategy(lambda rng: [elements.draw(rng) for _ in
                                 range(rng.randint(min_size, max_size))])


def booleans() -> Strategy:
    return Strategy(lambda rng: bool(rng.getrandbits(1)))


def just(value) -> Strategy:
    return Strategy(lambda rng: value)


def binary(*, min_size: int = 0, max_size: int = 64) -> Strategy:
    return Strategy(lambda rng: rng.randbytes(rng.randint(min_size,
                                                          max_size)))


def one_of(*strategies) -> Strategy:
    pool = [_as_strategy(s) for s in strategies]
    return Strategy(lambda rng: pool[rng.randrange(len(pool))].draw(rng))


def tuples(*strategies) -> Strategy:
    pool = [_as_strategy(s) for s in strategies]
    return Strategy(lambda rng: tuple(s.draw(rng) for s in pool))


# --- decorators ------------------------------------------------------------


def settings(*, max_examples: int = 100, deadline=None, **_ignored):
    """Attach ``max_examples`` to the (already ``@given``-wrapped) test."""
    def decorate(fn):
        fn._shim_max_examples = max_examples
        return fn
    return decorate


def given(*arg_strategies, **kw_strategies):
    """Run the test ``max_examples`` times with deterministic draws.

    The PRNG is seeded from the test's qualified name so a failing example
    recurs on every run (no shrinking — read the assertion values)."""
    arg_strategies = tuple(_as_strategy(s) for s in arg_strategies)
    kw_strategies = {k: _as_strategy(s) for k, s in kw_strategies.items()}

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", 100)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = [s.draw(rng) for s in arg_strategies]
                drawn_kw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                fn(*args, *drawn, **kwargs, **drawn_kw)
        # Hide the inner signature from pytest, which would otherwise treat
        # the strategy-drawn parameters as fixtures to resolve.
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        wrapper.hypothesis_shim = True
        return wrapper
    return decorate


# --- hypothesis.extra.numpy -------------------------------------------------


def array_shapes(*, min_dims: int = 1, max_dims: int = 3, min_side: int = 1,
                 max_side: int = 8) -> Strategy:
    def draw(rng):
        ndims = rng.randint(min_dims, max_dims)
        return tuple(rng.randint(min_side, max_side) for _ in range(ndims))
    return Strategy(draw)


def arrays(*, dtype, shape) -> Strategy:
    dtype_s, shape_s = _as_strategy(dtype), _as_strategy(shape)

    def draw(rng):
        dt = np.dtype(dtype_s.draw(rng))
        shp = shape_s.draw(rng)
        size = int(np.prod(shp)) if shp else 1
        if dt.kind == "f":
            # Mix ordinary values with exact powers of two and zeros so the
            # bit-exactness property sees varied mantissas/exponents.
            vals = [rng.choice([0.0, 1.0, -1.0, 0.5, rng.uniform(-1e4, 1e4),
                                rng.uniform(-1.0, 1.0)]) for _ in range(size)]
            arr = np.asarray(vals, np.float64).astype(dt)
        elif dt.kind == "u":
            info = np.iinfo(dt)
            arr = np.asarray([rng.randint(0, info.max) for _ in range(size)],
                             dt)
        elif dt.kind == "i":
            info = np.iinfo(dt)
            arr = np.asarray([rng.randint(info.min, info.max)
                              for _ in range(size)], dt)
        else:
            raise NotImplementedError(f"shim arrays() dtype kind {dt.kind!r}")
        return arr.reshape(shp)
    return Strategy(draw)
