"""Observability-layer tests: tracer span balance (property-tested over
random queue op sequences), Chrome/Perfetto export format, same-seed
trace determinism, the metrics registry and its naming convention, the
metrics-vs-legacy differential checks, trace-context propagation over
the v2 wire, and the run_until_done stall warning."""
import asyncio
import json

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.distributor import (AdaptiveSizer, AsyncDistributor,
                                    ClientProfile, FixedSizer, TaskDef)
from repro.core.federation import FederatedDistributor
from repro.core.tickets import TicketQueue
from repro.core.transport import (TransportServer, spawn_remote_clients)
from repro.core.wire import make_trace_context, parse_trace_context
from repro.obs import (MetricsRegistry, Tracer, collect_fabric,
                       valid_metric_name)
from repro.train_fabric import FederatedTrainer


def _run(coro):
    return asyncio.run(coro)


class SimClock:
    """Settable virtual clock (docs/ARCHITECTURE.md §Injectable clock)."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


# module-level so it pickles across the wire
def _square(x, static):
    return x * x


def make_fed(n_members=2, **kw):
    kw.setdefault("timeout", 5.0)
    kw.setdefault("redistribute_min", 0.02)
    kw.setdefault("sizer", AdaptiveSizer(target_lease_time=0.02, max_size=8))
    kw.setdefault("watchdog_interval", 0.005)
    kw.setdefault("grace", 2.0)
    return FederatedDistributor(n_members, **kw)


def _grad_task():
    def run(args, static):
        return {"grad": {"w": np.full(2, float(args), np.float32)},
                "loss": float(args),
                "round": static["weights"]["round"]}
    return TaskDef("backbone_shard", run, static_files=("weights",))


# ---------------------------------------------------------------------------
# Tracer core
# ---------------------------------------------------------------------------


def test_tracer_span_schemas_async_lane_instant():
    clock = SimClock()
    tr = Tracer(clock=clock)
    a = tr.begin("lease", track="queue", cat="lease", args={"lease": 1})
    clock.t = 0.25
    x = tr.begin("client.execute", track="client:c0", cat="client",
                 lane=True)
    clock.t = 1.0
    tr.end(x, args={"executed": 2})
    tr.instant("ticket.route", track="queue", cat="ticket",
               args={"shard": 3})
    tr.end(a, args={"status": "drained"})
    assert tr.balanced()
    evs = tr.events()
    assert tr.event_count() == len(evs) == 4     # async pair counts twice
    lane = next(e for e in evs if e["ph"] == "X")
    assert lane["ts"] == 0.25 and lane["dur"] == 0.75
    assert lane["args"] == {"executed": 2}
    b = next(e for e in evs if e["ph"] == "b")
    e = next(e for e in evs if e["ph"] == "e")
    assert b["id"] == e["id"]
    # end-args merge over begin-args on the async begin event
    assert b["args"] == {"lease": 1, "status": "drained"}
    inst = next(e for e in evs if e["ph"] == "i")
    assert inst["args"] == {"shard": 3} and inst["ts"] == 1.0


def test_tracer_end_is_exactly_once_and_none_tolerant():
    tr = Tracer(clock=SimClock())
    tr.end(None)                                 # pop(key, None) idiom
    assert tr.balanced()                         # vacuously
    s = tr.begin("ticket")
    assert not tr.balanced() and tr.open_spans()[0]["name"] == "ticket"
    tr.end(s)
    assert tr.balanced()
    tr.end(s)                                    # double close
    assert tr.end_errors == 1 and not tr.balanced()


def test_tracer_begin_many_bulk_matches_begin():
    clock = SimClock()
    tr = Tracer(clock=clock)
    sids = tr.begin_many("ticket", [{"ticket": i} for i in range(5)],
                         track="queue", cat="ticket")
    assert len(set(sids)) == 5 and tr.spans_opened == 5
    # bulk ids interleave safely with singles
    s = tr.begin("lease")
    assert s not in sids
    clock.t = 1.0
    for sid in sids:
        tr.end(sid)
    tr.end(s)
    assert tr.balanced()
    begins = [e for e in tr.events() if e["ph"] == "b"
              and e["name"] == "ticket"]
    assert [e["args"]["ticket"] for e in begins] == list(range(5))


def test_chrome_trace_format_is_perfetto_loadable():
    clock = SimClock()
    tr = Tracer(clock=clock)
    s = tr.begin("lease", track="queue", cat="lease")
    clock.t = 0.5
    x = tr.begin("client.execute", track="client:c0", cat="client",
                 lane=True)
    clock.t = 2.0
    tr.end(x)
    tr.instant("federation.steal", track="member0", cat="federation")
    tr.end(s)
    trace = tr.chrome_trace()
    assert trace["displayTimeUnit"] == "ms"
    evs = trace["traceEvents"]
    json.dumps(trace)                            # JSON-safe throughout
    # one thread_name + thread_sort_index metadata pair per track
    meta = [e for e in evs if e["ph"] == "M"]
    named = {e["args"]["name"] for e in meta if e["name"] == "thread_name"}
    assert named == {"queue", "client:c0", "member0"}
    assert any(e["name"] == "process_name" for e in meta)
    # timestamps are microseconds; instants carry thread scope
    lane = next(e for e in evs if e["ph"] == "X")
    assert lane["ts"] == 500000.0 and lane["dur"] == 1500000.0
    inst = next(e for e in evs if e["ph"] == "i")
    assert inst["s"] == "t"
    # every event lands on a declared track's tid of the single process
    tids = {e["args"]["name"]: e["tid"] for e in meta
            if e["name"] == "thread_name"}
    for e in evs:
        assert e.get("pid", 1) == 1
        if e["ph"] != "M":
            assert e["tid"] in set(tids.values())


def test_same_ops_same_virtual_clock_serialize_identically():
    def run_once() -> str:
        clock = SimClock()
        tr = Tracer(clock=clock)
        q = TicketQueue(timeout=30.0, redistribute_min=0.5, clock=clock,
                        tracer=tr)
        tids = q.add_many("t", list(range(8)))
        b1 = q.lease("a", 3)
        clock.t = 1.0
        q.submit_batch(b1.lease_id, {t: t for t in b1.ticket_ids}, "a")
        b2 = q.lease("b", 4)
        clock.t = 2.5
        q.release(b2.lease_id, client_failed=True)
        clock.t = 3.1
        b3 = q.lease("a", 8)
        q.submit_batch(b3.lease_id, {t: -t for t in b3.ticket_ids}, "a")
        q.cancel(tids)
        assert q.all_done() and tr.balanced()
        return tr.to_json()

    assert run_once() == run_once()


# ---------------------------------------------------------------------------
# Property: every queue-lifecycle span closes exactly once
# ---------------------------------------------------------------------------


@settings(max_examples=30)
@given(st.lists(st.tuples(
    st.sampled_from(["add", "lease", "submit", "release", "cancel",
                     "tick"]),
    st.integers(min_value=0, max_value=5)), min_size=1, max_size=40))
def test_property_spans_balance_over_random_op_sequences(ops):
    """Random interleavings of add/lease/submit/release/cancel (with
    redistribute_min=0, so one ticket can sit in several overlapping
    leases) must leave the trace balanced once the queue drains: every
    ticket and lease span closed exactly once, no end on a dead id."""
    clock = SimClock()
    tr = Tracer(clock=clock)
    q = TicketQueue(timeout=30.0, redistribute_min=0.0, clock=clock,
                    tracer=tr)
    leases = []
    for op, k in ops:
        if op == "add":
            q.add_many("t", list(range(k + 1)))
        elif op == "lease":
            b = q.lease(f"c{k % 3}", k + 1)
            if b is not None:
                leases.append(b)
        elif op == "submit" and leases:
            b = leases[k % len(leases)]
            q.submit_batch(b.lease_id,
                           {t: t for t in b.ticket_ids[:k + 1]}, b.client)
        elif op == "release" and leases:
            q.release(leases[k % len(leases)].lease_id,
                      client_failed=bool(k % 2))
        elif op == "cancel":
            q.cancel(list(q._tickets)[:k + 1])
        elif op == "tick":
            clock.t += 0.5 * (k + 1)
    # drain whatever the random walk left behind, as a fold would
    q.cancel([tid for tid, t in q._tickets.items() if not t.completed])
    for b in leases:
        q.release(b.lease_id)
    assert q.all_done()
    assert tr.balanced(), (tr.open_spans(), tr.end_errors)
    assert tr.spans_opened == tr.spans_closed
    if any(op == "add" for op, _ in ops):
        assert tr.spans_closed > 0


# ---------------------------------------------------------------------------
# Round engine: traced reticket / fold rounds stay balanced
# ---------------------------------------------------------------------------


async def _traced_round(policy, barrier_k, profiles, metrics=None):
    tr = Tracer()
    fed = make_fed(2, n_shards=4, sizer=FixedSizer(1), tracer=tr)
    tr.clock = fed.queue.clock
    fed.register_task(_grad_task())
    fed.spawn_clients(profiles)
    async with FederatedTrainer(fed, barrier_k=barrier_k,
                                straggler_policy=policy,
                                timeout=20.0, metrics=metrics) as t:
        res = await t.run_round(
            list(range(6)), shard_work=[1.0] * 6,
            statics={"weights": {"round": 0}})
    await fed.shutdown()
    return res, tr, fed


def _names(tr):
    return {e["name"] for e in tr.events()}


def test_traced_reticket_round_balances_and_records_policy_instants():
    res, tr, _ = _run(_traced_round(
        "reticket", 5,
        [ClientProfile(name="fast0", speed=500.0),
         ClientProfile(name="fast1", speed=500.0),
         ClientProfile(name="dead-slow", speed=0.5)]))
    assert res.complete
    assert tr.balanced(), tr.open_spans()
    names = _names(tr)
    assert {"ticket", "lease", "client.execute", "round",
            "ticket.route", "round.barrier_open",
            "round.reticket"} <= names
    # the round lane span closed ok and covers the whole round
    round_ev = next(e for e in tr.events()
                    if e["name"] == "round" and e["ph"] == "X")
    assert round_ev["args"]["status"] == "ok"
    assert round_ev["dur"] >= res.barrier_wait >= 0.0


def test_traced_fold_round_balances_and_cancel_closes_ticket_spans():
    res, tr, _ = _run(_traced_round(
        "fold", 5,
        [ClientProfile(name="fast0", speed=500.0),
         ClientProfile(name="fast1", speed=500.0),
         ClientProfile(name="dead-slow", speed=0.5)]))
    assert len(res.arrived) >= 5
    assert tr.balanced(), tr.open_spans()
    if res.stragglers:                  # straggler lost the race: folded
        assert "round.fold" in _names(tr)
        cancelled = [e for e in tr.events()
                     if e["name"] == "ticket" and e["ph"] == "b"
                     and e["args"].get("status") == "cancelled"]
        assert len(cancelled) == len(res.stragglers)


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


def test_registry_enforces_naming_and_idempotent_registration():
    reg = MetricsRegistry()
    for bad in ("no_subsystem_total", "cache.hits", "cache.hits_pct",
                "Cache.hits_total", "cache.", "queue.Rate_total"):
        assert not valid_metric_name(bad)
        with pytest.raises(ValueError):
            reg.counter(bad)
    c = reg.counter("cache.hits_total", labels=("cache",))
    assert reg.counter("cache.hits_total", labels=("cache",)) is c
    with pytest.raises(ValueError):                 # kind clash
        reg.gauge("cache.hits_total", labels=("cache",))
    with pytest.raises(ValueError):                 # label-set clash
        reg.counter("cache.hits_total", labels=("other",))
    with pytest.raises(ValueError):                 # wrong labels at use
        c.inc(other="x")
    c.inc(cache="edge0")
    c.inc(2.0, cache="edge0")
    assert c.value(cache="edge0") == 3.0
    c.set_total(7, cache="edge1")
    c.set_total(7, cache="edge1")                   # collector idempotence
    assert c.total() == 10.0


def test_histogram_buckets_snapshot_and_export():
    reg = MetricsRegistry()
    h = reg.histogram("round.duration_seconds", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(99.0)
    assert h.count() == 3 and h.sum() == pytest.approx(99.55)
    row = reg.snapshot()["round.duration_seconds"]["values"][0]
    assert row["buckets"] == {"0.1": 1, "1.0": 2, "inf": 3}
    assert row["count"] == 3
    rows = reg.export()
    assert [r["name"] for r in rows] == ["round.duration_seconds"]
    json.dumps(rows)                                 # BENCH-json safe


def test_metrics_registry_values_match_legacy_counters():
    """Differential check: after a real federated round, the registry's
    view (via collect_fabric) equals every legacy counter it absorbs —
    origin download ledger, per-member steals, edge-cache hits, queue
    lifecycle counts — and re-collection doesn't double-count."""
    async def go():
        reg = MetricsRegistry()
        fed = make_fed(2, n_shards=4)
        fed.register_task(_grad_task())
        fed.spawn_clients([ClientProfile(name=f"c{i}", speed=500.0)
                           for i in range(3)])
        async with FederatedTrainer(fed, metrics=reg, timeout=20.0) as t:
            res = await t.run_round(
                list(range(6)), shard_work=[1.0] * 6,
                statics={"weights": {"round": 0}})
        await fed.shutdown()
        collect_fabric(reg, distributor=fed)
        return reg, fed, res

    reg, fed, res = _run(go())
    assert res.complete
    # trainer-owned histograms landed in the RoundResult snapshot
    assert res.metrics["round.duration_seconds"]["values"][0]["count"] == 1
    # the trainer prunes the round's tickets, so the queue counters are
    # small — the differential contract is equality, whatever the value
    snap = fed.queue.snapshot()
    assert reg.get("queue.executed_total").value() == snap["executed"]
    assert (reg.get("queue.redistributions_total").value()
            == snap["redistributions"])
    rate = reg.get("queue.client_rate")
    assert snap["clients"], "no client ever reported"
    for client, cs in snap["clients"].items():
        assert rate.value(client=client) == (cs["rate"] or 0.0) > 0
    dl = reg.get("origin.downloads_total")
    assert fed.download_count, "origin ledger unexpectedly empty"
    for key, n in fed.download_count.items():
        assert dl.value(key=key) == n
    steals = reg.get("federation.steals_total")
    hits = reg.get("cache.hits_total")
    for m in fed.members:
        assert steals.value(member=m.index) == m.steals
        s = m.edge.stats()
        assert hits.value(cache=s["name"]) == s["hits"]
    assert reg.get("federation.alive_count").value() == 2
    # collectors are re-runnable views: same values, not doubled
    before = reg.snapshot()
    collect_fabric(reg, distributor=fed)
    assert reg.snapshot() == before


# ---------------------------------------------------------------------------
# Trace context on the v2 wire
# ---------------------------------------------------------------------------


def test_trace_context_builder_strict_parser_tolerant():
    assert make_trace_context(lease=3, client="c", round=None) == \
        {"lease": 3, "client": "c"}
    with pytest.raises(ValueError):
        make_trace_context(bogus=1)                  # builder is strict
    # parser never raises on junk from an untrusted peer
    assert parse_trace_context(None) is None
    assert parse_trace_context([1, 2]) is None
    assert parse_trace_context("x") is None
    assert parse_trace_context({"lease": True, "client": 7,
                                "exec_s": "fast", "extra": ()}) == {}
    assert parse_trace_context(
        {"lease": 3, "client": "c", "exec_s": 0.25, "round": 2,
         "junk": 1}) == \
        {"lease": 3, "client": "c", "exec_s": 0.25, "round": 2}


def test_wire_trace_context_rides_v2_and_closes_server_spans():
    async def go():
        tr = Tracer()
        d = AsyncDistributor(
            timeout=10.0, redistribute_min=0.02,
            sizer=AdaptiveSizer(target_lease_time=0.05, max_size=8),
            watchdog_interval=0.01, tracer=tr)
        tr.clock = d.queue.clock
        d.register_task(TaskDef("sq", _square))
        d.add_work("sq", list(range(12)))
        server = TransportServer(d)
        addr = await server.start()
        clients, tasks = spawn_remote_clients(
            addr, [ClientProfile(name="r0", speed=500.0)])
        ok = await d.run_until_done(timeout=30.0)
        await asyncio.gather(*tasks)
        await server.stop()
        return ok, tr, clients[0]

    ok, tr, c = _run(go())
    assert ok
    # every grant carried trace context; the submit echo closed the
    # server's wire span with the client-measured execute time
    assert c.trace_contexts == c.leases_taken > 0
    assert tr.balanced(), tr.open_spans()
    wire = [e for e in tr.events()
            if e["name"] == "wire.lease" and e["ph"] == "X"]
    assert wire
    assert all(e["args"]["status"] == "submitted" for e in wire)
    assert all(e["args"]["exec_s"] >= 0 for e in wire)


def test_wire_untraced_grants_carry_no_trace_context():
    async def go():
        d = AsyncDistributor(
            timeout=10.0, redistribute_min=0.02,
            sizer=AdaptiveSizer(target_lease_time=0.05, max_size=8),
            watchdog_interval=0.01)
        d.register_task(TaskDef("sq", _square))
        d.add_work("sq", list(range(8)))
        server = TransportServer(d)
        addr = await server.start()
        clients, tasks = spawn_remote_clients(
            addr, [ClientProfile(name="r0", speed=500.0)])
        ok = await d.run_until_done(timeout=30.0)
        await asyncio.gather(*tasks)
        await server.stop()
        return ok, clients[0]

    ok, c = _run(go())
    assert ok
    assert c.trace_contexts == 0 and c.leases_taken > 0


# ---------------------------------------------------------------------------
# run_until_done stall diagnostics (the silent wall-cap fix)
# ---------------------------------------------------------------------------


def test_run_until_done_wall_cap_warns_with_stall_report():
    clock = SimClock()                    # a wedged virtual clock

    async def go():
        tr = Tracer(clock=clock)
        d = AsyncDistributor(timeout=5.0, redistribute_min=0.02,
                             clock=clock, tracer=tr)
        d.register_task(TaskDef("sq", _square))
        d.add_work("sq", [1, 2, 3])
        d.queue.lease("ghost", 2)         # an in-flight lease to report
        with pytest.warns(RuntimeWarning,
                          match="run_until_done gave up"):
            ok = await d.run_until_done(timeout=100.0, wall_cap=0.2)
        return ok, d.last_stall_report, tr

    ok, report, tr = _run(go())
    assert ok is False
    assert report["reason"] == "wall_cap"
    assert report["snapshot"]["tickets"] == 3
    assert report["snapshot"]["executed"] == 0
    assert [ls["client"] for ls in report["outstanding_leases"]] == ["ghost"]
    assert "ghost" in report["client_rates"]
    json.dumps(report)                    # structured, log-shippable
    # the give-up is also on the trace, where the timeline shows context
    stall = [e for e in tr.events() if e["name"] == "distributor.stall"]
    assert len(stall) == 1 and stall[0]["args"]["reason"] == "wall_cap"


def test_run_until_done_virtual_timeout_warns_with_timeout_reason():
    class SteppingClock:
        def __init__(self):
            self.t = 0.0

        def __call__(self):
            self.t += 1.0
            return self.t

    async def go():
        d = AsyncDistributor(timeout=5.0, redistribute_min=0.02,
                             clock=SteppingClock())
        d.register_task(TaskDef("sq", _square))
        d.add_work("sq", [1])
        with pytest.warns(RuntimeWarning, match="timeout expired"):
            ok = await d.run_until_done(timeout=5.0)
        return ok, d.last_stall_report

    ok, report = _run(go())
    assert ok is False and report["reason"] == "timeout"
    assert report["virtual_clock"] > 5.0
