"""Unit + property tests for the Sashimi ticket queue (paper §2.1.2)."""
import itertools

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.tickets import Ticket, TicketQueue


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def make_queue(timeout=300.0, redist=10.0):
    clock = FakeClock()
    q = TicketQueue(timeout=timeout, redistribute_min=redist, clock=clock)
    return q, clock


def test_fresh_tickets_served_in_creation_order():
    q, clock = make_queue()
    ids = [q.add("t", i) for i in range(5)]
    served = [q.request().ticket_id for _ in range(5)]
    assert served == ids


def test_vct_undistributed_is_creation_time():
    q, clock = make_queue()
    tid = q.add("t", 0)
    t = q._tickets[tid]
    assert t.virtual_created_time(q.timeout) == t.created_at


def test_vct_distributed_is_distribution_plus_timeout():
    q, clock = make_queue()
    q.add("t", 0)
    clock.advance(7.0)
    t = q.request()
    assert t is not None
    live = q._tickets[t.ticket_id]
    assert live.virtual_created_time(q.timeout) == pytest.approx(7.0 + 300.0)


def test_no_redistribution_within_min_interval():
    """Paper: tickets are redistributed at intervals of at least 10 s."""
    q, clock = make_queue()
    q.add("t", 0)
    assert q.request() is not None
    clock.advance(5.0)           # < 10 s
    assert q.request() is None
    clock.advance(6.0)           # >= 10 s since distribution
    assert q.request() is not None


def test_redistribution_order_is_ascending_distribution_time():
    """Paper: when no fresh tickets remain, redistribute in ascending
    last-distribution order."""
    q, clock = make_queue()
    a = q.add("t", "a")
    b = q.add("t", "b")
    assert q.request().ticket_id == a
    clock.advance(1.0)
    assert q.request().ticket_id == b
    clock.advance(20.0)
    # both eligible again: a was distributed first -> smaller VCT
    assert q.request().ticket_id == a
    assert q.request().ticket_id == b


def test_fresh_ticket_preferred_over_timed_out():
    q, clock = make_queue()
    a = q.add("t", "a")
    assert q.request().ticket_id == a
    clock.advance(400.0)          # a timed out (VCT = 300 < now+created?)
    b = q.add("t", "b")           # fresh ticket, created_at = 400
    # a's VCT = 0 + 300 = 300 < b's 400 -> a first (it sorts as re-created
    # at t=300, earlier than b's creation)
    assert q.request().ticket_id == a
    assert q.request().ticket_id == b


def test_first_result_wins_duplicates_dropped():
    q, clock = make_queue(redist=0.0)
    tid = q.add("t", 0)
    t1 = q.request()
    t2 = q.request()    # redistribution allowed (redist=0)
    assert t1.ticket_id == t2.ticket_id == tid
    assert q.submit(tid, "r1", "c1") is True
    assert q.submit(tid, "r2", "c2") is False
    assert q.results()[tid] == "r1"
    assert q._tickets[tid].completed_by == "c1"


def test_error_reports_recorded():
    q, clock = make_queue()
    tid = q.add("t", 0)
    q.request()
    q.report_error(tid, "Traceback ...", "c1")
    assert q.snapshot()["errors"] == 1
    assert not q._tickets[tid].completed


def test_snapshot_counts():
    q, clock = make_queue()
    for i in range(4):
        q.add("t", i)
    q.request()
    snap = q.snapshot()
    assert snap["tickets"] == 4
    assert snap["waiting"] == 3
    assert snap["in_flight"] == 1
    assert snap["executed"] == 0


# ---------------------------------------------------------------------------
# Property tests
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 3), min_size=1, max_size=40).filter(
    lambda p: any(d != 0 for d in p)),   # client must sometimes succeed
       st.integers(1, 10))
def test_every_ticket_eventually_completes_despite_lost_tickets(
        drop_pattern, n_tickets):
    """Exactly-once completion: even when clients repeatedly lose tickets,
    redistribution ensures every ticket finishes, and each result is
    recorded exactly once."""
    q, clock = make_queue(timeout=30.0, redist=5.0)
    ids = [q.add("t", i) for i in range(n_tickets)]
    drops = itertools.cycle(drop_pattern)
    guard = 0
    while not q.all_done():
        guard += 1
        assert guard < 10000
        t = q.request()
        if t is None:
            clock.advance(6.0)
            continue
        if next(drops) == 0:
            continue  # client died with the ticket
        q.submit(t.ticket_id, t.args * 2, "c")
    res = q.results()
    assert sorted(res.keys()) == sorted(ids)
    for tid, i in zip(ids, range(n_tickets)):
        assert res[tid] == i * 2


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 50), st.floats(1.0, 100.0), st.floats(0.1, 20.0))
def test_request_never_returns_completed_ticket(n, timeout, redist):
    q, clock = make_queue(timeout=timeout, redist=redist)
    ids = [q.add("t", i) for i in range(n)]
    done = set()
    for _ in range(n * 3):
        t = q.request()
        clock.advance(redist / 2)
        if t is None:
            continue
        assert t.ticket_id not in done
        q.submit(t.ticket_id, "ok", "c")
        done.add(t.ticket_id)
    assert q.all_done()
