"""Training-fabric benchmark: federation-scale §4.1 rounds end to end.

Five cells, mirroring the acceptance bars:

  * ``throughput`` — discrete-event simulation (virtual clock, fully
    deterministic) of round-based data-parallel SGD over the REAL
    fabric: the real ``ShardedTicketQueue`` with per-member affinity
    placement, real ``EdgeCache``/version-pin fetch paths (clients are
    ``BrowserNodeBase`` instances computing the real gradients), members
    modelled as serialized service stations.  1 vs 4 members on the
    bimodal client mix; the bar is **≥ 2x round throughput at 4
    members**.
  * ``equivalence`` — the real asyncio :class:`FederatedTrainer` +
    :class:`FederatedTrainingLoop` on a 4-member federation: the
    federated loss trajectory must match in-process full-batch training
    within float tolerance, with zero stale-weight executions.
  * ``faults`` — one member killed mid-run AND one pathological
    straggler client, under both straggler policies: ``reticket`` must
    complete every round with exact math (trajectory still matches
    in-process) and ``fold`` must close every round at the K-of-N
    barrier; zero stale-weight executions in both.
  * ``paper_cnn`` — the paper's CNN as the round workload: each ticket
    computes a real conv→pool→softmax gradient shard (``CnnGradShard``
    on the FABRIC_CNN config) and the server aggregates through the
    fused Pallas server step; the fused trajectory must match the
    tree_map reference's and the loss must actually fall.
  * ``resume`` — kill-and-resume from a round-boundary checkpoint
    (paper JSON+base64 format) reproduces the unkilled federated loss
    trajectory.

Usage:
  PYTHONPATH=src python benchmarks/federated_training.py [--json out.json]
                                                         [--smoke]
"""
from __future__ import annotations

import argparse
import asyncio
import heapq
import itertools
import json
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.paper_cnn import FABRIC_CNN
from repro.core.distributor import (AdaptiveSizer, BrowserNodeBase,
                                    ClientProfile, FixedSizer, TaskDef)
from repro.core.federation import FederatedDistributor
from repro.core.split_parallel import TrainState, weighted_grad_mean
from repro.core.tickets import CANCELLED
from repro.models.cnn import CnnGradShard, init_cnn
from repro.optim import adagrad
from repro.sharding.spec import values_tree
from repro.train_fabric import (FederatedTrainer, FederatedTrainingLoop,
                                FusedServerStep, Rebalancer, TreeServerStep,
                                affinity_placement, checkpoint_path,
                                load_round_checkpoint, param_count)

# -- the workload: data-parallel linear regression --------------------------
# Tiny on purpose: the benchmark measures the FABRIC (rounds, barriers,
# failover, checkpoints), not FLOPs.  Gradients are exact, so the
# work-weighted shard aggregate equals the full-batch gradient and
# loss-equivalence is a hard check, not a statistical one.

D_IN = 8
N_ROWS = 96
LR = 0.3
_rng = np.random.default_rng(7)
X = _rng.normal(size=(N_ROWS, D_IN)).astype(np.float32)
W_TRUE = _rng.normal(size=(D_IN,)).astype(np.float32)
Y = (X @ W_TRUE + 0.01 * _rng.normal(size=(N_ROWS,))).astype(np.float32)

RTT = 0.05          # client <-> member round-trip latency (virtual s)
SERVICE = 0.025     # member service time per lease/submit request
N_SIM_CLIENTS = 16
BASE_RATE = 10.0    # rows / s for a "slow" simulated client
SIM_GRACE = 3.0


def grad_shard(args, static):
    """The registered task: exact gradient + loss of one row slice, with
    the served weights' round tag echoed back (stale-weight detector)."""
    lo, hi = args
    w = np.asarray(static["weights"]["params"]["w"])
    r = X[lo:hi] @ w - Y[lo:hi]
    return {"grad": {"w": (2.0 * X[lo:hi].T @ r / (hi - lo))
                     .astype(np.float32)},
            "loss": float((r ** 2).mean()),
            "round": static["weights"]["round"]}


def fresh_state(opt) -> TrainState:
    params = {"w": np.zeros(D_IN, np.float32)}
    return TrainState(params=params, head={}, head_stale={},
                      opt_state=opt.init(params), head_opt_state={},
                      prev_features=(), prev_labels=(), prev_mask=(),
                      step=np.zeros((), np.int32))


def in_process_losses(rounds: int) -> list[float]:
    """Full-batch reference trajectory (same optimizer, same data)."""
    opt = adagrad(LR)
    state = fresh_state(opt)
    params, opt_state = state.params, state.opt_state
    losses = []
    for _ in range(rounds):
        w = np.asarray(params["w"])
        r = X @ w - Y
        losses.append(float((r ** 2).mean()))
        g = {"w": (2.0 * X.T @ r / N_ROWS).astype(np.float32)}
        params, opt_state = opt.update(g, opt_state, params)
    return losses


def equal_plan(n_shards: int) -> tuple[list, list]:
    """Deterministic equal partition of the batch into row slices."""
    bounds = np.linspace(0, N_ROWS, n_shards + 1).astype(int)
    args = [(int(lo), int(hi)) for lo, hi in zip(bounds[:-1], bounds[1:])]
    work = [float(hi - lo) for lo, hi in args]
    return args, work


def rate_plan(trainer: FederatedTrainer, default_shards: int
              ) -> tuple[list, list]:
    """Measured-rate partition: shard sizes from the fabric's per-client
    EWMA throughput (``client_rates`` → ``adaptive_shard_sizes``)."""
    sizes = trainer.plan_shards(N_ROWS, default_shards=default_shards)
    bounds = np.cumsum([0] + sizes)
    args = [(int(lo), int(hi)) for lo, hi in zip(bounds[:-1], bounds[1:])]
    return args, [float(s) for s in sizes]


# ---------------------------------------------------------------------------
# Cell 1: virtual-clock round-throughput simulation (1 vs 4 members)
# ---------------------------------------------------------------------------


class SimClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


class _SimBrowser(BrowserNodeBase):
    """A browser node whose fetches go through its member's real edge
    cache with real version pins — only the *timing* is simulated."""

    def __init__(self, member, profile):
        self._init_browser(member, profile)


def simulate_training(n_members: int, *, rounds: int,
                      redistribute_min: float = 0.5) -> dict:
    """Round-based training as a discrete-event sim: lease/submit pass
    through their member's serialized service station; execution takes
    ``work / speed`` virtual seconds; the driver closes each round at the
    (full) barrier, aggregates, steps the optimizer, publishes the next
    round's weights, and wakes the idle fleet."""
    clock = SimClock()
    fed = FederatedDistributor(
        n_members, n_shards=max(2 * n_members, 2), timeout=300.0,
        redistribute_min=redistribute_min, clock=clock)
    fed.register_task(TaskDef("grad_shard", grad_shard,
                              static_files=("weights",)))
    q = fed.queue
    sizer = AdaptiveSizer(target_lease_time=0.25, max_size=4)
    opt = adagrad(LR)
    params = {"w": np.zeros(D_IN, np.float32)}
    opt_state = opt.init(params)

    speeds = {f"fast{i}": 8 * BASE_RATE for i in range(N_SIM_CLIENTS // 2)}
    speeds.update({f"slow{i}": BASE_RATE
                   for i in range(N_SIM_CLIENTS // 2)})
    member_of = {name: i % n_members
                 for i, name in enumerate(speeds)}
    browsers = {name: _SimBrowser(fed.members[member_of[name]],
                                  ClientProfile(name=name))
                for name in speeds}
    busy = [0.0] * n_members
    idle: set[str] = set()
    seq = itertools.count()
    events: list = []
    losses: list[float] = []
    stale = 0
    state = {"round": -1, "tids": [], "work_of": {}}
    makespan = None

    def service(member: int, t: float) -> float:
        start = max(t, busy[member])
        busy[member] = start + SERVICE
        return busy[member]

    def wake_idle(t: float):
        for name in list(idle):
            heapq.heappush(events, (t, next(seq), "wake", name, None))
        idle.clear()

    def start_round(t: float):
        state["round"] += 1
        fed.add_static("weights", {"round": state["round"],
                                   "params": params})
        # many small equal slices; the adaptive lease sizer batches them
        # per client's measured rate (PR 1's balancing, round-scoped)
        args, work = equal_plan(2 * N_SIM_CLIENTS)
        groups = affinity_placement(fed, len(args))
        tids: list = [None] * len(args)
        for shard, positions in groups.items():
            got = fed.add_work("grad_shard",
                               [args[p] for p in positions],
                               work=[work[p] for p in positions],
                               shard=shard)
            for p, tid in zip(positions, got):
                tids[p] = tid
        state["tids"] = tids
        state["work_of"] = {tid: work[p] for p, tid in enumerate(tids)}
        wake_idle(t)

    def close_round(t: float):
        nonlocal params, opt_state, stale, makespan
        done = q.completed_results(state["tids"])
        got, works = [], []
        for tid in state["tids"]:
            r = done.get(tid)
            if r is None or r is CANCELLED:
                continue
            got.append(r)
            works.append(state["work_of"][tid])
        stale += sum(1 for g in got if g["round"] != state["round"])
        q.prune(state["tids"])
        losses.append(float(sum(g["loss"] * w for g, w in zip(got, works))
                            / sum(works)))
        grads = weighted_grad_mean([g["grad"] for g in got], works)
        params, opt_state = opt.update(grads, opt_state, params)
        if state["round"] + 1 >= rounds:
            makespan = t
            return
        start_round(t)

    idle.update(speeds)        # everyone starts parked; round 0 wakes them
    start_round(0.0)

    while events and makespan is None:
        t, _, kind, name, payload = heapq.heappop(events)
        clock.t = t
        if kind == "wake":
            heapq.heappush(events, (service(member_of[name], t), next(seq),
                                    "leased", name, None))
        elif kind == "leased":
            m = member_of[name]
            home = fed.members[m].home_shards
            stats = q.stats.get(name)
            n_lease = sizer.lease_size(stats)
            batch = q.lease(name, n_lease, shards=home) if home else None
            if batch is None and len(home) < q.n_shards:
                batch = q.lease(name, n_lease)
            if batch is None:
                idle.add(name)
                continue
            eta = sizer.expected_duration(stats, len(batch.tickets))
            batch.expected_duration = eta
            if eta is not None:
                heapq.heappush(events,
                               (batch.issued_at + SIM_GRACE * max(eta, 1e-3),
                                next(seq), "watchdog", "", batch.lease_id))
            # execute now (download-through-cache at the pinned version),
            # deliver after the simulated compute time
            b = browsers[name]
            results = {}
            for ticket in batch.tickets:
                task = b._get_task(ticket.task_name, ticket.task_version)
                static = b._get_static(task, ticket.task_version)
                results[ticket.ticket_id] = task.run(ticket.args, static)
            finish = t + RTT + batch.work / speeds[name]
            heapq.heappush(events, (finish, next(seq), "finish", name,
                                    (batch, results)))
        elif kind == "finish":
            heapq.heappush(events, (service(member_of[name], t), next(seq),
                                    "submitted", name, payload))
        elif kind == "submitted":
            batch, results = payload
            q.submit_batch(batch.lease_id, results, name)
            heapq.heappush(events, (t, next(seq), "wake", name, None))
            done = q.completed_results(state["tids"])
            if len(done) >= len(state["tids"]):
                close_round(t)
        elif kind == "watchdog":
            if q.release(payload, client_failed=True):
                wake_idle(t)

    return {"members": n_members,
            "rounds": rounds,
            "makespan_s": round(makespan or clock.t, 3),
            "rounds_per_s": round(rounds / max(makespan or clock.t, 1e-9),
                                  3),
            "stale_executions": stale,
            "final_loss": round(losses[-1], 6),
            "losses": [round(x, 6) for x in losses]}


def cell_throughput(rounds: int) -> dict:
    cells = {f"fed-{n}": simulate_training(n, rounds=rounds)
             for n in (1, 4)}
    cells["speedup_4v1_rounds"] = round(
        cells["fed-4"]["rounds_per_s"] / cells["fed-1"]["rounds_per_s"], 2)
    return cells


# ---------------------------------------------------------------------------
# Cells 2-4: the real asyncio trainer
# ---------------------------------------------------------------------------


async def _kill_soon(fed, index: int, delay: float):
    await asyncio.sleep(delay)
    await fed.kill_member(index)


async def train_async(*, n_members: int, profiles, rounds: int,
                      straggler_policy: str = "wait", barrier_k=None,
                      plan: str = "equal", n_shards_round: int = 8,
                      kill_member_at_round=None, use_rebalancer=False,
                      checkpoint_dir=None, resume_from=None,
                      sizer=None) -> dict:
    """One federated training run on the real fabric; returns its
    trajectory and fault counters."""
    fed = FederatedDistributor(
        n_members, n_shards=2 * n_members, timeout=20.0,
        redistribute_min=0.02,
        sizer=sizer if sizer is not None
        else AdaptiveSizer(target_lease_time=0.05, max_size=8),
        watchdog_interval=0.01, grace=2.0,
        project_name="FederatedTraining")
    fed.register_task(TaskDef("grad_shard", grad_shard,
                              static_files=("weights",)))
    fed.spawn_clients(profiles)
    opt = adagrad(LR)
    if resume_from is not None:
        seed_state, start_round, _extra = load_round_checkpoint(resume_from)
    else:
        seed_state, start_round = fresh_state(opt), 0
    reb = Rebalancer(fed, steal_threshold=3, cooldown=1) \
        if use_rebalancer else None
    kill_task = None
    trainer = FederatedTrainer(fed, task_name="grad_shard",
                               barrier_k=barrier_k,
                               straggler_policy=straggler_policy,
                               timeout=30.0, rebalancer=reb)
    loop = FederatedTrainingLoop(trainer, opt, seed_state,
                                 round_index=start_round,
                                 checkpoint_dir=checkpoint_dir,
                                 checkpoint_every=1)
    complete_rounds = 0
    try:
        async with trainer:
            for _ in range(start_round, rounds):
                if (kill_member_at_round is not None
                        and loop.round_index == kill_member_at_round):
                    kill_task = asyncio.get_running_loop().create_task(
                        _kill_soon(fed, 0, 0.02))
                if plan == "equal":
                    args, work = equal_plan(n_shards_round)
                else:
                    args, work = rate_plan(trainer, n_shards_round)
                res = await loop.run_round(args, work)
                complete_rounds += res.complete
    finally:
        if kill_task is not None:
            await kill_task
        await trainer.aclose()       # idempotent after the context exit
        await fed.shutdown()
    return {"losses": loop.losses,
            "completed_rounds": loop.round_index - start_round,
            "complete_rounds": complete_rounds,
            "stale_executions": loop.stale_executions,
            "reticketed": trainer.reticketed_total,
            "folded": trainer.folded_total,
            "migrations": fed.migrations}


def _bimodal_profiles(n_fast: int, n_slow: int, *, straggler: bool = False):
    ps = [ClientProfile(name=f"fast{i}", speed=2000.0)
          for i in range(n_fast)]
    ps += [ClientProfile(name=f"slow{i}", speed=400.0)
           for i in range(n_slow)]
    if straggler:
        ps.append(ClientProfile(name="straggler", speed=30.0))
    return ps


def cell_equivalence(rounds: int) -> dict:
    fed = asyncio.run(train_async(
        n_members=4, profiles=_bimodal_profiles(4, 3), rounds=rounds,
        plan="rates", n_shards_round=8))
    ref = in_process_losses(rounds)
    delta = max(abs(a - b) for a, b in zip(fed["losses"], ref))
    return {"rounds": rounds, "max_loss_delta": float(delta),
            "stale_executions": fed["stale_executions"],
            "completed_rounds": fed["completed_rounds"],
            "final_loss": fed["losses"][-1]}


def cell_faults(rounds: int) -> dict:
    # more shards than clients + one-ticket leases: every client
    # (straggler included) holds work every round, so the K-of-N policies
    # genuinely trigger instead of the straggler never winning a ticket
    n_shards = 12
    k = n_shards - 2
    out = {}
    for policy in ("reticket", "fold"):
        run = asyncio.run(train_async(
            n_members=4, profiles=_bimodal_profiles(4, 3, straggler=True),
            rounds=rounds, straggler_policy=policy, barrier_k=k,
            plan="equal", n_shards_round=n_shards,
            kill_member_at_round=1, use_rebalancer=True,
            sizer=FixedSizer(1)))
        cell = {"completed_rounds": run["completed_rounds"],
                "complete_rounds": run["complete_rounds"],
                "stale_executions": run["stale_executions"],
                "reticketed": run["reticketed"],
                "folded": run["folded"],
                "migrations": run["migrations"]}
        if policy == "reticket":
            ref = in_process_losses(rounds)
            cell["max_loss_delta"] = float(max(
                abs(a - b) for a, b in zip(run["losses"], ref)))
        out[policy] = cell
    return out


# ---------------------------------------------------------------------------
# Cell 5: the paper's CNN as the round workload (real model on the fabric)
# ---------------------------------------------------------------------------

CNN_ROWS = 128     # synthetic clustered-images rows sharded per round
CNN_LR = 0.05


async def train_cnn_async(*, rounds: int, server_step: str,
                          n_members: int = 2, n_shards_round: int = 4
                          ) -> dict:
    """Federated rounds whose ticket work is the paper CNN's actual
    conv→pool→softmax gradient (``CnnGradShard``), aggregated through a
    selectable :class:`ServerStep` implementation."""
    fed = FederatedDistributor(
        n_members, n_shards=2 * n_members, timeout=20.0,
        redistribute_min=0.02, sizer=FixedSizer(1),
        watchdog_interval=0.01, grace=2.0, project_name="FabricCNN")
    fed.register_task(TaskDef(
        "cnn_grad_shard", CnnGradShard(FABRIC_CNN, n_rows=CNN_ROWS),
        static_files=("weights",)))
    fed.spawn_clients(_bimodal_profiles(n_members, n_members))
    opt = adagrad(CNN_LR)
    params = jax.device_get(
        values_tree(init_cnn(jax.random.PRNGKey(0), FABRIC_CNN)))
    state = TrainState(params=params, head={}, head_stale={},
                       opt_state=opt.init(params), head_opt_state={},
                       prev_features=(), prev_labels=(), prev_mask=(),
                       step=np.zeros((), np.int32))
    step_impl = (FusedServerStep(opt, lr=CNN_LR)
                 if server_step == "fused" else TreeServerStep(opt))
    bounds = np.linspace(0, CNN_ROWS, n_shards_round + 1).astype(int)
    args = [(int(lo), int(hi)) for lo, hi in zip(bounds[:-1], bounds[1:])]
    work = [float(hi - lo) for lo, hi in args]
    trainer = FederatedTrainer(fed, task_name="cnn_grad_shard",
                               timeout=30.0)
    loop = FederatedTrainingLoop(trainer, opt, state,
                                 server_step=step_impl)
    try:
        async with trainer:
            for _ in range(rounds):
                await loop.run_round(args, work)
    finally:
        await trainer.aclose()
        await fed.shutdown()
    return {"losses": loop.losses,
            "completed_rounds": loop.round_index,
            "stale_executions": loop.stale_executions,
            "model_params": param_count(loop.state.params)}


def cell_paper_cnn(rounds: int) -> dict:
    """Real paper-CNN rounds through the asyncio fabric: the fused
    server step's trajectory vs the tree_map reference's (bit-equal
    aggregation → identical losses), and actual convergence."""
    fused = asyncio.run(train_cnn_async(rounds=rounds,
                                        server_step="fused"))
    tree = asyncio.run(train_cnn_async(rounds=rounds, server_step="tree"))
    delta = max(abs(a - b) for a, b in zip(fused["losses"],
                                           tree["losses"]))
    return {"rounds": rounds, "model": FABRIC_CNN.name,
            "model_params": fused["model_params"],
            "loss_first": fused["losses"][0],
            "loss_final": fused["losses"][-1],
            "max_loss_delta_fused_vs_tree": float(delta),
            "stale_executions": (fused["stale_executions"]
                                 + tree["stale_executions"]),
            "completed_rounds": fused["completed_rounds"]}


def cell_resume(rounds: int, kill_at: int) -> dict:
    with tempfile.TemporaryDirectory() as ckdir:
        baseline = asyncio.run(train_async(
            n_members=2, profiles=_bimodal_profiles(2, 2), rounds=rounds,
            plan="equal", n_shards_round=6))
        # the "killed" run: same config, checkpoints every round, stops
        # (is killed) after `kill_at` rounds
        asyncio.run(train_async(
            n_members=2, profiles=_bimodal_profiles(2, 2), rounds=kill_at,
            plan="equal", n_shards_round=6, checkpoint_dir=ckdir))
        resumed = asyncio.run(train_async(
            n_members=2, profiles=_bimodal_profiles(2, 2), rounds=rounds,
            plan="equal", n_shards_round=6,
            resume_from=checkpoint_path(ckdir, kill_at)))
    tail_delta = max(abs(a - b) for a, b in
                     zip(baseline["losses"][kill_at:], resumed["losses"]))
    return {"rounds": rounds, "resumed_from_round": kill_at,
            "max_loss_delta": float(tail_delta),
            "stale_executions": resumed["stale_executions"]}


# ---------------------------------------------------------------------------


def run_sweep(*, smoke: bool = False) -> dict:
    rounds = 6 if smoke else 10
    out = {
        "throughput": cell_throughput(rounds),
        "equivalence": cell_equivalence(rounds),
        "faults": cell_faults(rounds),
        "paper_cnn": cell_paper_cnn(4 if smoke else 6),
        "resume": cell_resume(rounds, kill_at=rounds // 2),
        "workload": {"rows": N_ROWS, "d_in": D_IN, "lr": LR,
                     "sim_clients": N_SIM_CLIENTS,
                     "service_s": SERVICE, "rtt_s": RTT},
    }
    return out


def check(results: dict) -> None:
    """The acceptance bars (shared by main() and benchmarks/run.py)."""
    thr = results["throughput"]
    assert thr["speedup_4v1_rounds"] >= 2.0, \
        f"4-member federation must sustain >= 2x single-member round " \
        f"throughput (got {thr['speedup_4v1_rounds']}x)"
    for cell in ("fed-1", "fed-4"):
        assert thr[cell]["stale_executions"] == 0, (cell, thr[cell])

    eq = results["equivalence"]
    assert eq["completed_rounds"] == eq["rounds"], eq
    assert eq["stale_executions"] == 0, eq
    assert eq["max_loss_delta"] < 1e-4, \
        f"federated trajectory must match in-process: {eq}"

    faults = results["faults"]
    rt, fo = faults["reticket"], faults["fold"]
    assert rt["completed_rounds"] == eq["rounds"], rt
    assert rt["stale_executions"] == 0 and fo["stale_executions"] == 0, \
        faults
    assert rt["reticketed"] > 0, \
        f"the straggler must trigger re-ticketing: {rt}"
    assert rt["max_loss_delta"] < 1e-4, \
        f"reticket keeps the math exact even under faults: {rt}"
    assert rt["migrations"] >= 1, \
        f"the dead member's shards must fail over: {rt}"
    assert fo["completed_rounds"] == eq["rounds"], fo
    assert fo["folded"] > 0, \
        f"the fold policy must actually fold the straggler: {fo}"

    pc = results["paper_cnn"]
    assert pc["completed_rounds"] == pc["rounds"], pc
    assert pc["stale_executions"] == 0, pc
    assert pc["max_loss_delta_fused_vs_tree"] < 1e-6, \
        f"fused server step must track the tree_map reference: {pc}"
    assert pc["loss_final"] < pc["loss_first"], \
        f"the paper CNN must actually converge through the fabric: {pc}"

    rs = results["resume"]
    assert rs["max_loss_delta"] < 1e-6, \
        f"resume must reproduce the unkilled trajectory: {rs}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="write results here")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced size (CI smoke)")
    args = ap.parse_args()
    results = run_sweep(smoke=args.smoke)

    thr = results["throughput"]
    print(f"{'cell':<24}{'rounds/s':>10}{'makespan(s)':>13}{'stale':>7}")
    print("-" * 54)
    for cell in ("fed-1", "fed-4"):
        m = thr[cell]
        print(f"throughput {cell:<13}{m['rounds_per_s']:>10.3f}"
              f"{m['makespan_s']:>13.2f}{m['stale_executions']:>7}")
    print(f"\nbimodal mix: 4-member federation sustains "
          f"{thr['speedup_4v1_rounds']:.2f}x the single member's round "
          f"throughput")
    eq = results["equivalence"]
    print(f"equivalence: {eq['completed_rounds']} rounds, max |Δloss| vs "
          f"in-process = {eq['max_loss_delta']:.2e}, "
          f"{eq['stale_executions']} stale executions")
    rt = results["faults"]["reticket"]
    fo = results["faults"]["fold"]
    print(f"faults/reticket: {rt['completed_rounds']} rounds under member "
          f"death + straggler ({rt['reticketed']} re-ticketed, "
          f"{rt['migrations']} shard migrations, max |Δloss| "
          f"{rt['max_loss_delta']:.2e}, {rt['stale_executions']} stale)")
    print(f"faults/fold: {fo['completed_rounds']} rounds, "
          f"{fo['folded']} straggler shards folded at the K-of-N barrier, "
          f"{fo['stale_executions']} stale")
    pc = results["paper_cnn"]
    print(f"paper-cnn: {pc['completed_rounds']} rounds of "
          f"{pc['model']} ({pc['model_params']} params), loss "
          f"{pc['loss_first']:.4f} -> {pc['loss_final']:.4f}, fused vs "
          f"tree_map max |Δloss| {pc['max_loss_delta_fused_vs_tree']:.2e}")
    rs = results["resume"]
    print(f"resume: from round {rs['resumed_from_round']} checkpoint, "
          f"max |Δloss| vs unkilled = {rs['max_loss_delta']:.2e}")

    check(results)
    print("all training-fabric bars passed")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
