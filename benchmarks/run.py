"""Benchmark harness: one entry per paper table/figure + the roofline table
and the two virtual-clock scheduler benchmarks.

Prints ``name,us_per_call,derived`` CSV rows (plus the detailed records) so
results are machine-comparable across runs.  Scaled-down sizes run inside a
CPU budget; pass --full for paper-scale settings.

The ``scheduler``, ``federation``, ``cache``, ``transport``,
``training``, ``server_step``, ``obs`` and ``churn`` entries
additionally write machine-readable ``BENCH_<name>.json`` files
(throughput, speedup, stale-serve, egress, loss-equivalence,
kernel-fusion and churn-resilience numbers) so the perf trajectory is
tracked across PRs — CI uploads them as artifacts.  ``--out-dir``
relocates them.

A benchmark that raises is reported with its full traceback and the run
exits nonzero; JSON files are written atomically (temp file + rename)
only after their benchmark's own assertions pass, so a failed run can
never leave a partial or stale-looking BENCH_*.json behind.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

OUT_DIR = "."
WRITTEN: dict = {}     # bench name -> BENCH_*.json filename, this run


def _write_json(name: str, payload: dict) -> str:
    """Atomically write BENCH_<name>.json (temp + rename): readers and CI
    artifact uploads can never observe a half-written file."""
    os.makedirs(OUT_DIR, exist_ok=True)
    fname = f"BENCH_{name}.json"
    path = os.path.join(OUT_DIR, fname)
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2)
    os.replace(tmp, path)
    WRITTEN[name] = fname
    print(f"  wrote {path}")
    return path


def write_summary(statuses: dict) -> str:
    """Consolidated ``BENCH_summary.json``: one entry per benchmark with
    its gate verdict and the BENCH_*.json it wrote (null when its gates
    failed before the write).  **Merges** with an existing summary in
    ``OUT_DIR`` — CI invokes the harness once per ``--only`` entry, and
    each invocation must extend the index, not erase the others'
    results.  Written atomically, like every BENCH file."""
    path = os.path.join(OUT_DIR, "BENCH_summary.json")
    benches: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                benches = json.load(f).get("benches", {})
        except (OSError, ValueError):
            benches = {}          # corrupt summary: rebuild from here
    for name, status in statuses.items():
        benches[name] = {"ok": status["ok"],
                         "json": WRITTEN.get(name),
                         "error": status.get("error")}
    payload = {
        "benches": {k: benches[k] for k in sorted(benches)},
        "passed": sum(1 for b in benches.values() if b["ok"]),
        "failed": sum(1 for b in benches.values() if not b["ok"]),
    }
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2)
    os.replace(tmp, path)
    print(f"wrote {path} ({payload['passed']} pass / "
          f"{payload['failed']} fail across {len(benches)} indexed)")
    return path


def _csv(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}", flush=True)


def bench_table2(full: bool):
    from benchmarks import table2_knn

    kw = dict(n_train=20000, n_test=1000, image_size=28, tickets=50) \
        if full else {}
    t0 = time.perf_counter()
    rows = table2_knn.run(**kw)
    us = (time.perf_counter() - t0) * 1e6
    for r in rows:
        print(f"  {r}")
    ratios = "|".join(str(r["ratio"]) for r in rows)
    _csv("table2_knn_scaling", us, f"elapsed_ratios={ratios}")
    return rows


def bench_table4(full: bool):
    from benchmarks import table4_speed

    t0 = time.perf_counter()
    rows = table4_speed.run(seconds=20.0 if full else 6.0)
    us = (time.perf_counter() - t0) * 1e6
    for r in rows:
        print(f"  {r}")
    _csv("table4_sukiyaki_speedup", us,
         f"jit_over_eager={rows[-1]['batches_per_min']}x")
    return rows


def bench_fig3(full: bool):
    from benchmarks import fig3_convergence

    t0 = time.perf_counter()
    rows = fig3_convergence.run(batches=200 if full else 40)
    fabric = fig3_convergence.run_fabric(rounds=8 if full else 5)
    us = (time.perf_counter() - t0) * 1e6
    last = {r["optimizer"]: r["error_rate"] for r in rows}
    for r in rows:
        print(f"  {r}")
    print(f"  fabric: {fabric}")
    _csv("fig3_convergence", us,
         f"final_err={last}|"
         f"fabric_delta={fabric['max_loss_delta_vs_in_process']:.1e}")
    return rows


def bench_fig5(full: bool):
    from benchmarks import fig5_split

    t0 = time.perf_counter()
    rows = fig5_split.run(seconds=12.0 if full else 5.0, max_clients=4)
    us = (time.perf_counter() - t0) * 1e6
    for r in rows:
        print(f"  {r}")
    conv = [r["conv_batches_per_min"] for r in rows]
    _csv("fig5_split_scaling", us, f"conv_bpm={conv}")
    return rows


def bench_roofline(full: bool):
    from benchmarks import roofline

    t0 = time.perf_counter()
    rows = roofline.run()
    us = (time.perf_counter() - t0) * 1e6
    ok = [r for r in rows if "error" not in r]
    for r in ok[:5]:
        print(f"  {r}")
    if len(ok) > 5:
        print(f"  ... ({len(ok)} rows total; see EXPERIMENTS.md §Roofline)")
    _csv("roofline_table", us, f"rows={len(ok)}")
    return rows


def bench_scheduler(full: bool):
    """Distributor v2 policy sweep (virtual clock, deterministic); writes
    BENCH_scheduler.json with the per-mix makespans and the adaptive-vs-v1
    speedup on the bimodal mix."""
    from benchmarks import scheduler_throughput

    t0 = time.perf_counter()
    results = scheduler_throughput.run_sweep()
    us = (time.perf_counter() - t0) * 1e6
    bi = results["bimodal"]
    speedup = round(bi["v1-fixed-1"]["makespan_s"]
                    / bi["adaptive"]["makespan_s"], 2)
    payload = {
        "results": results,
        "speedup_adaptive_v_fixed1_bimodal": speedup,
        "client_mix": {"clients": scheduler_throughput.N_CLIENTS,
                       "tickets": scheduler_throughput.N_TICKETS,
                       "base_rate": scheduler_throughput.BASE_RATE,
                       "rtt_s": scheduler_throughput.RTT},
    }
    _write_json("scheduler", payload)
    _csv("scheduler_policies", us, f"adaptive_speedup={speedup}x")
    return results


def bench_federation(full: bool):
    """Federation fabric sweep (virtual clock, deterministic); writes
    BENCH_federation.json with per-member-count throughput, the 4v1
    speedup, and the member-death recovery cell."""
    from benchmarks import federation_throughput

    t0 = time.perf_counter()
    results = federation_throughput.run_sweep(
        n_tickets=600 if full else 200)
    us = (time.perf_counter() - t0) * 1e6
    _write_json("federation", results)
    death = results["bimodal+death"]["fed-4-kill-m0"]
    _csv("federation_throughput", us,
         f"speedup_4v1={results['speedup_4v1_bimodal']}x|"
         f"death_completed={death['completed']}/{death['total']}")
    return results


def bench_cache(full: bool):
    """Cache-coherence storm (virtual clock, deterministic); writes
    BENCH_cache.json with per-strategy stale-serve counts and the egress
    saved by versioned invalidation vs clear()-everything."""
    from benchmarks import cache_coherence

    t0 = time.perf_counter()
    results = cache_coherence.run_sweep()
    us = (time.perf_counter() - t0) * 1e6
    v = results["versioned"]
    # assert BEFORE writing: a failed coherence bar must not leave a
    # fresh-looking BENCH_cache.json behind
    assert v["stale_serves"] == 0, v
    _write_json("cache", results)
    _csv("cache_coherence", us,
         f"stale_serves={v['stale_serves']}|"
         f"egress_saved_vs_clear={results['egress_saved_vs_clear_pct']}%")
    return results


def bench_transport(full: bool):
    """Wire-protocol overhead (real loopback sockets, wall clock); writes
    BENCH_transport.json with serialized-vs-in-process round throughput,
    the wire byte ledger, and the over-the-wire re-register storm."""
    from benchmarks import transport_overhead

    t0 = time.perf_counter()
    results = transport_overhead.run_sweep()
    us = (time.perf_counter() - t0) * 1e6
    # acceptance bars first (see transport_overhead.main): coherence
    # survives serialization; wire costs <= half the round throughput
    assert results["storm"]["stale_serves"] == 0, results["storm"]
    assert results["throughput_ratio"] >= 0.5, results
    _write_json("transport", results)
    _csv("transport_overhead", us,
         f"throughput_ratio={results['throughput_ratio']}x|"
         f"storm_stale={results['storm']['stale_serves']}")
    return results


def bench_training(full: bool):
    """Training-fabric sweep (virtual-clock throughput sim + real asyncio
    trainer cells); writes BENCH_training.json with the 4v1 round-
    throughput speedup, loss-equivalence deltas, fault-tolerance
    counters, and the kill/resume reproduction delta."""
    from benchmarks import federated_training

    t0 = time.perf_counter()
    results = federated_training.run_sweep(smoke=not full)
    us = (time.perf_counter() - t0) * 1e6
    # acceptance bars BEFORE writing (a failed bar must not leave a
    # fresh-looking BENCH_training.json behind)
    federated_training.check(results)
    _write_json("training", results)
    _csv("federated_training", us,
         f"speedup_4v1_rounds={results['throughput']['speedup_4v1_rounds']}x|"
         f"equiv_delta={results['equivalence']['max_loss_delta']:.1e}|"
         f"resume_delta={results['resume']['max_loss_delta']:.1e}")
    return results


def bench_server_step(full: bool):
    """Fused server-step kernel vs the seed's unfused tree_map pipeline
    (wall clock); writes BENCH_server_step.json with the three medians
    and the fused/baseline ratio, gated against the checked-in
    benchmarks/baselines/server_step_baseline.json with x1.2 headroom
    (plus the interpret-mode bit-equivalence bar)."""
    from benchmarks import server_step_fusion

    t0 = time.perf_counter()
    results = server_step_fusion.run(trials=50 if full else 20)
    us = (time.perf_counter() - t0) * 1e6
    # acceptance bars BEFORE writing (a regressed ratio must not leave a
    # fresh-looking BENCH_server_step.json behind)
    server_step_fusion.check(results)
    _write_json("server_step", results)
    _csv("server_step_fusion", us,
         f"fused_over_tree={results['fused_over_tree_ratio']}|"
         f"mode={results['fused_mode']}")
    return results


def bench_obs(full: bool):
    """Observability layer: trace determinism, span balance, the
    tracing-overhead gate, fleet-export determinism, and the SLO gate
    (which must trip on an injected regression — a gate that cannot
    fail is not a gate); writes BENCH_obs.json."""
    import sys as _sys
    if "src" not in _sys.path:
        _sys.path.insert(0, "src")
    from benchmarks import scheduler_throughput
    from repro.obs import (DEFAULT_ROUND_SLOS, FleetAggregator,
                           MetricsRegistry, SloMonitor, Tracer,
                           collect_queue)

    t0 = time.perf_counter()
    # determinism: two same-seed virtual-clock runs must serialize to
    # byte-identical Perfetto JSON (the tracer never reads wall time)
    sizer, watchdog = scheduler_throughput.POLICIES["adaptive"]
    traces = []
    for _ in range(2):
        tr = Tracer()
        scheduler_throughput.simulate("churn", sizer, watchdog=watchdog,
                                      tracer=tr)
        assert tr.balanced(), tr.open_spans()
        traces.append(tr.to_json())
    assert traces[0] == traces[1], "same-seed traces differ"
    events = traces[0].count('"ph"')

    # metrics registry absorbs a live queue snapshot without error
    reg = MetricsRegistry()
    clock = scheduler_throughput.SimClock()
    from repro.core.tickets import TicketQueue
    q = TicketQueue(timeout=300.0, clock=clock)
    q.add_many("work", list(range(16)))
    collect_queue(reg, q)
    assert reg.get("queue.tickets_count").value() == 16, reg.snapshot()

    # fleet-export determinism: two identically-fed aggregators (same
    # synthetic remote batch, same skew sample) must serialize the
    # merged skew-corrected timeline byte-identically
    batch = {"metrics": {"client.executed_total": {
                 "kind": "counter", "help": "Tickets executed",
                 "values": [{"labels": {}, "value": 7}]}},
             "spans": [{"ph": "X", "name": "client.execute",
                        "cat": "client", "track": "client:tab-0",
                        "ts": 3.0, "dur": 0.5, "args": {}}],
             "dropped": 0, "local_drops": 0}
    fleet_json = []
    for _ in range(2):
        fl = FleetAggregator()
        fl.clock_sample("tab-0", offset=2.5, rtt=0.01)
        assert fl.ingest("tab-0", dict(batch)), "synthetic batch refused"
        fleet_json.append(fl.to_json())
    assert fleet_json[0] == fleet_json[1], "fleet exports differ"
    remote_ts = json.loads(fleet_json[0])["traceEvents"]
    corrected = [e for e in remote_ts if e["name"] == "client.execute"]
    assert corrected and corrected[0]["ts"] == 5.5e6, corrected  # 3.0+2.5 s→us

    # SLO gate: clean registry passes; an injected latency regression
    # (rounds past the histogram's 60 s edge) MUST trip it
    def slo_eval(durations):
        reg2 = MetricsRegistry()
        h = reg2.histogram("round.duration_seconds",
                           "Virtual-clock duration of each closed round")
        for d in durations:
            h.observe(d)
        mon = SloMonitor(reg2, DEFAULT_ROUND_SLOS)
        results = mon.evaluate()
        return results, mon
    clean, _ = slo_eval([0.4, 0.6, 0.8, 1.2])
    assert all(r.ok for r in clean), [r.as_dict() for r in clean]
    regressed, mon = slo_eval([0.4, 0.6] + [120.0] * 18)
    tripped = [r for r in regressed if not r.ok]
    assert tripped and mon.breaches_total > 0, \
        "injected regression did NOT trip the SLO gate"
    assert {r.slo.name for r in tripped} == {"round-latency-p95"}, tripped

    gate = scheduler_throughput.overhead_gate()
    us = (time.perf_counter() - t0) * 1e6
    # acceptance bars BEFORE writing (a failed gate must not leave a
    # fresh-looking BENCH_obs.json behind)
    assert gate["ok"], gate
    payload = {"determinism": {"runs": 2, "identical": True,
                               "events": events},
               "fleet_determinism": {"runs": 2, "identical": True},
               "slo_gate": {"clean_ok": True, "regression_tripped": True,
                            "tripped": [r.as_dict() for r in tripped]},
               "overhead": gate,
               "metric_series": len(reg.names())}
    _write_json("obs", payload)
    _csv("obs_layer", us,
         f"overhead_ratio={gate['ratio']}x|trace_events={events}|"
         f"slo_gate=trips_on_regression")
    return payload


def bench_churn(full: bool):
    """Browser-scale churn sim (virtual clock, deterministic): 10k
    clients (1k without --full) at 20%/round churn under admission
    control + heartbeat eviction; writes BENCH_churn.json gated on zero
    stalled rounds, zero lost/duplicated tickets, and churned throughput
    >= 0.9x the no-churn ceiling."""
    from benchmarks import churn_scale

    t0 = time.perf_counter()
    results = churn_scale.run_sweep(
        population=churn_scale.POPULATION if full
        else churn_scale.SMOKE_POPULATION)
    us = (time.perf_counter() - t0) * 1e6
    # acceptance bars BEFORE writing (a stalled or lossy run must not
    # leave a fresh-looking BENCH_churn.json behind)
    churn_scale.check(results)
    _write_json("churn", results)
    ch = results["churned"]
    _csv("churn_scale", us,
         f"ratio_vs_ceiling={results['throughput_ratio_vs_ceiling']}|"
         f"stalled={ch['stalled_rounds']}|lost={ch['lost_tickets']}|"
         f"dup={ch['duplicate_completions']}|"
         f"speedup_4v1={results['speedup_4v1']}x")
    return results


BENCHES = {
    "table2": bench_table2,
    "table4": bench_table4,
    "fig3": bench_fig3,
    "fig5": bench_fig5,
    "roofline": bench_roofline,
    "scheduler": bench_scheduler,
    "federation": bench_federation,
    "cache": bench_cache,
    "transport": bench_transport,
    "training": bench_training,
    "server_step": bench_server_step,
    "obs": bench_obs,
    "churn": bench_churn,
}


def main() -> None:
    global OUT_DIR
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(BENCHES))
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow on CPU)")
    ap.add_argument("--out-dir", default=".",
                    help="where BENCH_*.json files land")
    args = ap.parse_args()
    OUT_DIR = args.out_dir
    print("name,us_per_call,derived")
    names = [args.only] if args.only else list(BENCHES)
    failures = 0
    statuses: dict = {}
    for name in names:
        print(f"== {name} ==", flush=True)
        try:
            BENCHES[name](args.full)
            statuses[name] = {"ok": True}
        except Exception as e:
            # keep the harness going so one broken benchmark doesn't hide
            # the others' results, but fail LOUDLY: full traceback now,
            # nonzero exit at the end (no BENCH json is written for a
            # failed entry — _write_json runs after a bench's assertions)
            failures += 1
            statuses[name] = {"ok": False,
                              "error": f"{type(e).__name__}: {e}"[:500]}
            print(f"  FAILED: {name}")
            traceback.print_exc()
    write_summary(statuses)
    if failures:
        print(f"{failures} benchmark(s) failed", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
