"""Roofline benchmark: consumes the dry-run JSONL records (produced by
``python -m repro.launch.dryrun --all``) and emits the per-(arch x shape)
roofline table used by EXPERIMENTS.md §Roofline, plus the three hillclimb
candidates (worst roofline fraction / most collective-bound / most
representative of the paper's technique)."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load(path: str) -> list[dict]:
    recs = []
    with open(path) as f:
        for line in f:
            if line.strip():
                recs.append(json.loads(line))
    return recs


def summarize(recs: list[dict]) -> list[dict]:
    rows = []
    for r in recs:
        t = {"compute": r["t_compute_s"], "memory": r["t_memory_s"],
             "collective": r["t_collective_s"]}
        bound = max(t.values())
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "strategy": r["strategy"],
            "t_compute_s": round(r["t_compute_s"], 5),
            "t_memory_s": round(r["t_memory_s"], 5),
            "t_collective_s": round(r["t_collective_s"], 5),
            "dominant": r["dominant"],
            "useful_flops_ratio": round(r["useful_ratio"], 3),
            "roofline_fraction": round(
                r["t_compute_s"] / bound if bound else 0.0, 3),
            "peak_GiB_per_dev": round(r["peak_bytes_per_device"] / 2**30, 2),
        })
    return rows


def pick_hillclimb(rows: list[dict]) -> dict:
    train = [r for r in rows if r["shape"] == "train_4k"]
    worst = min(rows, key=lambda r: r["roofline_fraction"]
                if r["t_compute_s"] else 1.0)
    coll = max(rows, key=lambda r: r["t_collective_s"])
    rep = max(train, key=lambda r: r["t_memory_s"]) if train else rows[0]
    return {"worst_roofline": worst, "most_collective_bound": coll,
            "paper_representative": rep}


def run(path: str | None = None):
    path = path or os.path.join(RESULTS, "dryrun_single_pod.jsonl")
    if not os.path.exists(path):
        return [{"error": f"run the dry-run first: {path} missing"}]
    rows = summarize(load(path))
    return rows


if __name__ == "__main__":
    rows = run()
    for r in rows:
        print(r)
    print("\nhillclimb candidates:")
    for k, v in pick_hillclimb(rows).items():
        print(f"  {k}: {v['arch']} x {v['shape']} (dominant={v['dominant']})")
