"""Optimized-decode sweep: re-runs the decode combos for the weight-heavy
architectures with ``decode_layout="auto"`` (replicated-batch + 2D-KV
resident-weight layout, §Perf pair 2) and emits the baseline-vs-optimized
comparison appended to EXPERIMENTS.md §Perf.

  PYTHONPATH=src python -m benchmarks.optimized_decode_sweep
"""
from __future__ import annotations

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import json

ARCHS = ["dbrx-132b", "command-r-35b", "internvl2-26b",
         "jamba-1.5-large-398b", "qwen3-moe-30b-a3b"]
SHAPES = ["decode_32k", "long_500k"]


def main():
    from repro.launch.dryrun import run_one

    baseline = {}
    with open("results/dryrun_single_pod.jsonl") as f:
        for line in f:
            r = json.loads(line)
            baseline[(r["arch"], r["shape"])] = r

    out = []
    for arch in ARCHS:
        for shape in SHAPES:
            rec = run_one(arch, shape, verbose=True)
            rec["layout"] = "auto(optimized)"
            out.append(rec)
            b = baseline.get((arch, shape))
            if b:
                print(f"  vs baseline: coll {b['t_collective_s']:.3f}->"
                      f"{rec['t_collective_s']:.3f}s  mem "
                      f"{b['t_memory_s']:.3f}->{rec['t_memory_s']:.3f}s  "
                      f"peak {b['peak_bytes_per_device']/2**30:.1f}->"
                      f"{rec['peak_bytes_per_device']/2**30:.1f}GiB",
                      flush=True)
    with open("results/dryrun_optimized_decode.jsonl", "w") as f:
        for r in out:
            f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main()
