"""Figure 3 reproduction: error rate vs training batches for the Figure-2
CNN with the paper's modified AdaGrad (β) versus unmodified AdaGrad —
demonstrating the stabilisation the paper introduced β for."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.paper_cnn import FIG2_CNN
from repro.data import clustered_images
from repro.models import cnn
from repro.optim import adagrad
from repro.sharding.spec import values_tree


def train_curve(beta: float, *, batches: int = 60, lr: float = 0.02,
                eval_every: int = 10):
    ccfg = FIG2_CNN
    params = values_tree(cnn.init_cnn(jax.random.PRNGKey(0), ccfg))
    opt = adagrad(lr, beta=beta)
    opt_state = opt.init(params)
    images, labels = clustered_images(2048, image_size=ccfg.image_size,
                                      channels=ccfg.in_channels, seed=0)
    test_x, test_y = clustered_images(256, image_size=ccfg.image_size,
                                      channels=ccfg.in_channels, seed=7)
    test_x, test_y = jnp.asarray(test_x), jnp.asarray(test_y)

    @jax.jit
    def step(params, opt_state, x, y):
        def loss_fn(p):
            return cnn.nll_loss(cnn.forward(p, ccfg, x), y)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    @jax.jit
    def err(params):
        return cnn.error_rate(cnn.forward(params, ccfg, test_x), test_y)

    bs = ccfg.batch_size
    curve = []
    for i in range(batches):
        j = (i * bs) % (len(images) - bs)
        params, opt_state, loss = step(
            params, opt_state, jnp.asarray(images[j:j + bs]),
            jnp.asarray(labels[j:j + bs]))
        if (i + 1) % eval_every == 0:
            curve.append((i + 1, float(err(params)), float(loss)))
    return curve


def run(*, batches: int = 60):
    out = []
    for beta, name in [(1.0, "modified adagrad (beta=1)"),
                       (1e-8, "plain adagrad (beta~0)")]:
        curve = train_curve(beta, batches=batches)
        for step_i, e, loss in curve:
            out.append({"optimizer": name, "batch": step_i,
                        "error_rate": round(e, 4), "loss": round(loss, 4)})
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
